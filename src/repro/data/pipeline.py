"""Data pipeline: deterministic, restartable, per-host shardable token
streams.

Two sources:
  * SyntheticLM   -- seeded Zipfian token stream (offline container default)
  * ByteCorpus    -- byte-level tokenization of a text file (tokenizer-free,
                     used by the quality benchmark to compare fp32 vs W8A8
                     on identical data, standing in for WikiText-2)

The iterator state is one integer (step) + the static config, so checkpoint
/restart (ft/) serializes trivially and elastic re-sharding just changes
(host_index, num_hosts).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM:
    """Zipf-distributed tokens with a learnable bigram-ish structure: token
    t+1 = (a*t + noise) mod V so a model can actually reduce loss on it."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, cfg.host_index))
        b, s = cfg.host_batch, cfg.seq_len
        first = rng.zipf(1.3, size=(b, 1)).clip(max=cfg.vocab_size - 1)
        noise = rng.integers(0, 3, size=(b, s))
        toks = np.zeros((b, s + 1), np.int64)
        toks[:, :1] = first
        for i in range(s):
            toks[:, i + 1] = (toks[:, i] * 31 + 7 + noise[:, i]) % cfg.vocab_size
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ByteCorpus:
    """Byte-level LM over a text blob; vocab 256 (+pad to model vocab ok)."""

    def __init__(self, text: bytes, cfg: DataConfig):
        self.data = np.frombuffer(text, dtype=np.uint8).astype(np.int32)
        self.cfg = cfg
        if len(self.data) < cfg.seq_len + 1:
            raise ValueError("corpus shorter than one sequence")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, cfg.host_index))
        b, s = cfg.host_batch, cfg.seq_len
        starts = rng.integers(0, len(self.data) - s - 1, size=b)
        toks = np.stack([self.data[i : i + s] for i in starts])
        labs = np.stack([self.data[i + 1 : i + s + 1] for i in starts])
        return {"tokens": toks, "labels": labs}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_source(name: str, cfg: DataConfig, text: bytes | None = None):
    if name == "synthetic":
        return SyntheticLM(cfg)
    if name == "bytes":
        assert text is not None
        return ByteCorpus(text, cfg)
    raise ValueError(name)
