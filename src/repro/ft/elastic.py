"""Fault tolerance & elasticity.

Large-fleet failure model and how each piece maps to this framework:

  failure                         mechanism here
  ------------------------------- -------------------------------------------
  pod/host loss mid-run           atomic checkpoints (checkpoint/ckpt.py) +
                                  ``elastic_mesh()`` rebuilding the mesh from
                                  the devices that are still alive; restore
                                  re-lays-out host arrays onto the new mesh
  slow straggler step             rolling-median step-time flagging in
                                  train/loop.py (feeds a health controller)
  data-loss on restart            data iterator state == integer step stored
                                  in the checkpoint manifest (exact resume)
  collective hang                 per-step deadline via block_until_ready in
                                  the driver; a missed deadline triggers
                                  checkpoint-restart on the surviving mesh
  inter-pod bandwidth brownout    int8-group gradient compression
                                  (optim/compress.py) halves/quarters wire
                                  bytes; hierarchical reduce keeps cross-pod
                                  traffic to one reduce-scatter per step

Elasticity contract: sharding rules are written against AXIS NAMES
(dist/sharding.py), never device counts, so any mesh reshape that preserves
axis names revalidates the same pjit programs.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]


def plan_mesh(num_devices: int, *, model_parallel: int = 16,
              multi_pod_threshold: int = 512) -> MeshPlan:
    """Choose a (pod, data, model) factorization for whatever devices remain.

    model_parallel is capped at the device count; data absorbs the rest;
    a pod axis appears only when there are enough devices for >1 pod.
    """
    mp = math.gcd(model_parallel, num_devices)
    rest = num_devices // mp
    if num_devices >= multi_pod_threshold and rest % 2 == 0:
        return MeshPlan((2, rest // 2, mp), ("pod", "data", "model"))
    return MeshPlan((rest, mp), ("data", "model"))


def elastic_mesh(devices=None, **kw) -> Mesh:
    """Build the best mesh for the currently-alive device set."""
    devices = list(devices if devices is not None else jax.devices())
    plan = plan_mesh(len(devices), **kw)
    arr = np.array(devices).reshape(plan.shape)
    return Mesh(arr, plan.axes)


def survivors_after_failure(devices, failed_indices: set[int]):
    """Simulate losing devices (tests); returns the surviving list truncated
    to the largest power-of-two-friendly count for remeshing."""
    alive = [d for i, d in enumerate(devices) if i not in failed_indices]
    # keep the largest count with a clean (data, model) factorization
    n = len(alive)
    while n > 0 and math.gcd(n, 16) not in (1, 2, 4, 8, 16):
        n -= 1
    return alive[:n]
