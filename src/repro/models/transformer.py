"""Decoder-only transformer LM covering the dense / MoE / MLA / VLM archs:
internlm2, deepseek-coder-33b, pixtral-12b (backbone), gemma2-2b,
minicpm3-4b, dbrx-132b, deepseek-v2-lite-16b, plus the paper's TinyLlama.

Layers are homogeneous and stacked: init via vmap, forward via lax.scan
(keeps HLO size O(1) in depth — essential for the 62-layer dry-runs).
Per-layer local/global alternation (gemma2) is a scanned boolean driving the
mask, not a structural branch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import flags
from repro.core.qlinear import embedding_lookup, linear
from repro.dist import logical
from repro.models import attention as attn
from repro.models import mlp as mlpmod
from repro.models.common import dense_init, embed_init, rmsnorm, softcap


def _layer_windows(cfg: ModelConfig) -> jax.Array:
    """(L,) bool: True where the layer uses the sliding window (gemma2 'L')."""
    if not cfg.layer_pattern or not cfg.sliding_window:
        return jnp.zeros((cfg.num_layers,), jnp.bool_)
    pat = (cfg.layer_pattern * cfg.num_layers)[: cfg.num_layers]
    return jnp.asarray([c == "L" for c in pat])


def init_layer(key, cfg: ModelConfig) -> dict:
    ka, km = jax.random.split(key)
    dt = cfg.pdtype()
    p = {
        "att_norm": jnp.ones((cfg.d_model,), dt) * (0.0 if cfg.gemma_norms else 1.0),
        "attn": attn.init_mla(ka, cfg) if cfg.mla else attn.init_gqa(ka, cfg),
        "ffn_norm": jnp.ones((cfg.d_model,), dt) * (0.0 if cfg.gemma_norms else 1.0),
        "mlp": mlpmod.init_moe(km, cfg) if cfg.moe else mlpmod.init_mlp(km, cfg),
    }
    if cfg.gemma_norms:
        p["post_att_norm"] = jnp.zeros((cfg.d_model,), dt)
        p["post_ffn_norm"] = jnp.zeros((cfg.d_model,), dt)
    return p


def init_lm(key, cfg: ModelConfig) -> dict:
    ke, kl, kc = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, cfg.pdtype()),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype()) * (0.0 if cfg.gemma_norms else 1.0),
    }
    if not cfg.tie_embeddings:
        params["classifier"] = dense_init(kc, cfg.vocab_padded, cfg.d_model, cfg.pdtype())
    return params


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg: ModelConfig, frontend_embeds=None):
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())
    if cfg.gemma_norms:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if frontend_embeds is not None:
        # VLM stub (pixtral): precomputed patch embeddings replace the first
        # P positions of the sequence (input_specs supplies them).
        pfx = frontend_embeds.astype(x.dtype)
        x = jnp.concatenate([pfx, x[:, pfx.shape[1] :, :]], axis=1)
    return logical.constrain(x, *(["dp"] + [None] * (x.ndim - 1)))


def _logits(params, x, cfg: ModelConfig):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.gemma_norms)
    w = params["embed"] if cfg.tie_embeddings else params["classifier"]
    logits = linear(w, x)
    logits = logical.constrain(logits, *(["dp"] + [None] * (logits.ndim - 2) + ["tp"]))
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits


def _block(lp, x, cfg: ModelConfig, attn_fn):
    """One residual block given an attention closure; shared by all paths."""
    g = cfg.gemma_norms
    h = rmsnorm(x, lp["att_norm"], cfg.norm_eps, plus_one=g)
    a = attn_fn(h)
    if g:
        a = rmsnorm(a, lp["post_att_norm"], cfg.norm_eps, plus_one=True)
    x = x + a
    h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps, plus_one=g)
    f = mlpmod.moe_forward(lp["mlp"], h, cfg) if cfg.moe else mlpmod.mlp_forward(lp["mlp"], h)
    if g:
        f = rmsnorm(f, lp["post_ffn_norm"], cfg.norm_eps, plus_one=True)
    return x + f


# ---------------------------------------------------------------------------
# training / scoring forward
# ---------------------------------------------------------------------------

def lm_forward(params, tokens, cfg: ModelConfig, frontend_embeds=None, *, remat=True):
    """tokens (b, s) -> logits (b, s, vocab_padded)."""
    x = _embed(params, tokens, cfg, frontend_embeds)
    windows = _layer_windows(cfg)

    def body(x, scanned):
        lp, use_window = scanned

        def attn_fn(h):
            if cfg.mla:
                return attn.mla_forward(lp["attn"], h, cfg)
            return attn.gqa_forward(
                lp["attn"], h, cfg, window=cfg.sliding_window, use_window=use_window
            )

        return _block(lp, x, cfg, attn_fn), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["layers"], windows))
    return _logits(params, x, cfg)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def lm_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    if cfg.mla:
        return {
            "ckv": jnp.zeros((cfg.num_layers, batch, cache_len, cfg.mla.kv_lora_rank), dtype),
            "krope": jnp.zeros((cfg.num_layers, batch, cache_len, cfg.mla.qk_rope_dim), dtype),
        }
    hd = cfg.resolved_head_dim
    kvq = attn.kv_quant_format(cfg)
    if kvq:
        sdt = attn.KV_STORE_DTYPES[kvq]
        qshape = (cfg.num_layers, batch, cfg.num_kv_heads, cache_len, hd)
        sshape = (cfg.num_layers, batch, cfg.num_kv_heads, cache_len)
        return {"k_q": jnp.zeros(qshape, sdt), "k_s": jnp.zeros(sshape, jnp.float32),
                "v_q": jnp.zeros(qshape, sdt), "v_s": jnp.zeros(sshape, jnp.float32)}
    if flags.get("kvt_cache_layout"):
        shape = (cfg.num_layers, batch, cfg.num_kv_heads, cache_len, hd)
    else:
        shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_insert_slots(cache, rows, slots):
    """Scatter per-request prefill cache ``rows`` into decode ``slots`` of a
    batched contiguous cache. Every decoder_lm cache layout — base, MLA,
    kvt, int8 quantized — keeps batch on axis 1 of each (layers, b, ...)
    leaf, so one axis-1 scatter covers them all (the serving core's
    slot-admission contract, serving/core.py)."""
    return jax.tree.map(
        lambda big, small: big.at[:, slots].set(small), cache, rows
    )


def lm_gather_slots(cache, slots):
    """Inverse of ``lm_insert_slots``: the per-slot cache rows for ``slots``
    (snapshot/preemption path)."""
    return jax.tree.map(lambda big: big[:, slots], cache)


def lm_init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int, dtype):
    """Block-pool KV cache: (L, NB, BS, KV, hd) leaves named ``*_pages`` so
    the sharding policy can keep the block axis whole (dist/sharding.py).
    Block 0 is conventionally the allocator's write-off sink
    (serving/paged.py); the paged attention path never reads an unmasked
    stale slot, so pool memory is recycled without zeroing."""
    if cfg.mla:
        raise ValueError(
            f"{cfg.arch_id}: paged KV cache covers the GQA layouts; the MLA "
            "latent cache keeps the contiguous path (supports_paged=False)"
        )
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads, hd)
    kvq = attn.kv_quant_format(cfg)
    if kvq:
        # quantized pool: blocks at storage width plus per-row f32 scale
        # leaves named ``*_scales`` (group = head_dim; dist/sharding.py keeps
        # the block axis whole and puts KV heads on the model axis)
        sdt = attn.KV_STORE_DTYPES[kvq]
        sshape = shape[:-1]
        return {"k_pages": jnp.zeros(shape, sdt),
                "k_scales": jnp.zeros(sshape, jnp.float32),
                "v_pages": jnp.zeros(shape, sdt),
                "v_scales": jnp.zeros(sshape, jnp.float32)}
    return {"k_pages": jnp.zeros(shape, dtype), "v_pages": jnp.zeros(shape, dtype)}


def lm_decode_paged(params, token, cache, block_table, pos, cfg: ModelConfig):
    """One paged decode step. token (b,) int32; cache the ``*_pages`` block
    pool; block_table (b, MB) int32 physical block ids per virtual block;
    pos (b,) int32 virtual positions. Returns (logits, new cache).

    Always deferred: the layer scan emits only the new K/V rows, committed
    after the scan with one scatter at each row's (physical block, offset)
    (attention.commit_layers_paged). The attention reads the pool through
    the block table (Pallas kernel on TPU, gather oracle elsewhere)."""
    if flags.get("kvt_cache_layout") or flags.get("int8_kv_cache"):
        raise ValueError("paged KV cache supports the base float KV layout "
                         "(kvt_cache_layout / int8_kv_cache flags off)")
    kvq = attn.kv_quant_format(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    if not pos.ndim:
        pos = jnp.full((token.shape[0],), pos, jnp.int32)
    x = embedding_lookup(params["embed"], token, cfg.cdtype())
    if cfg.gemma_norms:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    windows = _layer_windows(cfg)

    def body(x, scanned):
        lp, use_window, layer_cache = scanned
        new_cache = {}

        def attn_fn(h):
            scales = ((layer_cache["k_scales"], layer_cache["v_scales"])
                      if kvq else None)
            y, rows = attn.gqa_decode_paged(
                lp["attn"], h, (layer_cache["k_pages"], layer_cache["v_pages"]),
                block_table, pos, cfg,
                window=cfg.sliding_window, use_window=use_window,
                scales=scales,
            )
            if kvq:
                (new_cache["k"], new_cache["k_s"],
                 new_cache["v"], new_cache["v_s"]) = rows
            else:
                new_cache["k"], new_cache["v"] = rows
            return y

        g = cfg.gemma_norms
        h = rmsnorm(x, lp["att_norm"], cfg.norm_eps, plus_one=g)
        a = attn_fn(h)
        if g:
            a = rmsnorm(a, lp["post_att_norm"], cfg.norm_eps, plus_one=True)
        x = x + a
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps, plus_one=g)
        if cfg.moe:
            f = mlpmod.moe_forward(lp["mlp"], h[:, None, :], cfg)[:, 0, :]
        else:
            f = mlpmod.mlp_forward(lp["mlp"], h)
        if g:
            f = rmsnorm(f, lp["post_ffn_norm"], cfg.norm_eps, plus_one=True)
        return x + f, new_cache

    x, new_rows = jax.lax.scan(body, x, (params["layers"], windows, cache))
    new_cache = {
        "k_pages": attn.commit_layers_paged(cache["k_pages"], new_rows["k"],
                                            block_table, pos),
        "v_pages": attn.commit_layers_paged(cache["v_pages"], new_rows["v"],
                                            block_table, pos),
    }
    if kvq:
        # scale rows (L, b, KV) land in the (L, NB, BS, KV) scale pool at the
        # same (physical block, offset) as their quantized rows
        new_cache["k_scales"] = attn.commit_layers_paged(
            cache["k_scales"], new_rows["k_s"], block_table, pos)
        new_cache["v_scales"] = attn.commit_layers_paged(
            cache["v_scales"], new_rows["v_s"], block_table, pos)
    return _logits(params, x, cfg), new_cache


# ---------------------------------------------------------------------------
# speculative-verify: k-token chunked decode (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _verify_embed(params, tokens, cfg: ModelConfig):
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())
    if cfg.gemma_norms:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def _check_verify_layout(cfg: ModelConfig):
    if cfg.mla:
        raise ValueError(
            f"{cfg.arch_id}: speculative verify covers the GQA layouts; the "
            "MLA latent cache keeps the single-token path (supports_spec=False)"
        )
    if flags.get("kvt_cache_layout") or attn.kv_quant_format(cfg):
        raise ValueError("speculative verify supports the base float KV "
                         "layout (kvt_cache_layout / int8_kv_cache flags and "
                         "kv_quant off)")


def lm_verify(params, tokens, cache, pos, cfg: ModelConfig):
    """Chunked multi-token decode for speculative verification. tokens
    (b, k) int32 — the current token followed by k-1 drafted candidates;
    cache the contiguous {k, v} layout (slots >= pos zero); pos (b,) or
    scalar int32 virtual position of tokens[:, 0]. Returns
    (logits (b, k, vocab_padded), rows {k, v} (L, b, k, KV, hd)).

    The cache is NOT written: row j attends over committed history plus
    chunk rows 0..j (intra-chunk causal, scattered into the columns the
    sequential decode would occupy), and the caller commits only the
    accepted prefix with :func:`lm_commit_verify` — one forward pass
    streams each weight block once for up to k tokens (the GQMM
    amortization LlamaF §II-B prices per token)."""
    _check_verify_layout(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    if not pos.ndim:
        pos = jnp.full((tokens.shape[0],), pos, jnp.int32)
    x = _verify_embed(params, tokens, cfg)
    windows = _layer_windows(cfg)

    def body(x, scanned):
        lp, use_window, layer_cache = scanned
        rows = {}

        def attn_fn(h):
            y, (k, v) = attn.gqa_verify_deferred(
                lp["attn"], h, (layer_cache["k"], layer_cache["v"]), pos, cfg,
                window=cfg.sliding_window, use_window=use_window,
            )
            rows["k"], rows["v"] = k, v
            return y

        return _block(lp, x, cfg, attn_fn), rows

    x, rows = jax.lax.scan(body, x, (params["layers"], windows, cache))
    return _logits(params, x, cfg), rows


def lm_commit_verify(cache, rows, pos, n_commit):
    """Commit the accepted prefix of a verify chunk: rows[:, :, :n_commit[b]]
    land at positions pos[b]..pos[b]+n_commit[b]-1; rejected rows are
    DROPPED (redirected out of bounds), so the cache is bit-identical to a
    trajectory that never drafted them — rollback is ``pos + n_commit``."""
    return {
        "k": attn.commit_layers_verify(cache["k"], rows["k"], pos, n_commit),
        "v": attn.commit_layers_verify(cache["v"], rows["v"], pos, n_commit),
    }


def lm_verify_paged(params, tokens, cache, block_table, pos, cfg: ModelConfig):
    """Paged sibling of :func:`lm_verify`: the chunk attends through each
    row's block table over the ``*_pages`` pool (kernels/ops.py
    ``paged_verify``). Same return contract; commit via
    :func:`lm_commit_verify_paged` (rejected rows dropped out of bounds)."""
    _check_verify_layout(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    if not pos.ndim:
        pos = jnp.full((tokens.shape[0],), pos, jnp.int32)
    x = _verify_embed(params, tokens, cfg)
    windows = _layer_windows(cfg)

    def body(x, scanned):
        lp, use_window, layer_cache = scanned
        rows = {}

        def attn_fn(h):
            y, (k, v) = attn.gqa_verify_paged(
                lp["attn"], h, (layer_cache["k_pages"], layer_cache["v_pages"]),
                block_table, pos, cfg,
                window=cfg.sliding_window, use_window=use_window,
            )
            rows["k"], rows["v"] = k, v
            return y

        return _block(lp, x, cfg, attn_fn), rows

    x, rows = jax.lax.scan(body, x, (params["layers"], windows, cache))
    return _logits(params, x, cfg), rows


def lm_commit_verify_paged(cache, rows, block_table, pos, n_commit):
    return {
        "k_pages": attn.commit_layers_paged_verify(
            cache["k_pages"], rows["k"], block_table, pos, n_commit),
        "v_pages": attn.commit_layers_paged_verify(
            cache["v_pages"], rows["v"], block_table, pos, n_commit),
    }


def contiguous_to_paged(cache, block_size: int):
    """Reshape a contiguous (L, b, T, KV, hd) cache into a block pool plus
    the identity block tables: row i owns blocks [i*MB, (i+1)*MB). T must be
    a multiple of ``block_size``. The paged decode over this pool is
    bit-exact against the contiguous deferred path (tests/test_paged.py).

    A quantized contiguous cache ({k_q, k_s, v_q, v_s}, kvt layout
    (L, b, KV, T, ...)) maps to the quantized pool layout
    ({k_pages, k_scales, v_pages, v_scales}, time-major blocks)."""
    if "k_q" in cache:
        kq = cache["k_q"]                                 # (L, b, KV, T, hd)
        L, b, _, t = kq.shape[:4]
        if t % block_size:
            raise ValueError(f"cache_len {t} not a multiple of block_size {block_size}")
        mb = t // block_size

        def pool_kvt(leaf):                               # (L,b,KV,T,...) -> blocks
            x = jnp.moveaxis(leaf, 3, 2)                  # (L,b,T,KV,...)
            return x.reshape(L, b * mb, block_size, *x.shape[3:])

        table = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
        return {"k_pages": pool_kvt(kq), "k_scales": pool_kvt(cache["k_s"]),
                "v_pages": pool_kvt(cache["v_q"]),
                "v_scales": pool_kvt(cache["v_s"])}, table
    k = cache["k"]
    L, b, t = k.shape[:3]
    if t % block_size:
        raise ValueError(f"cache_len {t} not a multiple of block_size {block_size}")
    mb = t // block_size
    def pool(leaf):
        return leaf.reshape(L, b * mb, block_size, *leaf.shape[3:])
    table = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
    return {"k_pages": pool(k), "v_pages": pool(cache["v"])}, table


def lm_prefill(params, tokens, cfg: ModelConfig, cache_len: int, frontend_embeds=None,
               lengths=None):
    """Prompt pass: returns (last-position logits, populated cache).

    ``lengths`` (b,) enables ragged right-padded prompts: attention masks pad
    keys (and zeroes their cached K/V rows), and the returned logits are
    gathered per row at position lengths[i]-1 instead of the shared last
    column — the fix for sampling the first token from pad-position logits."""
    x = _embed(params, tokens, cfg, frontend_embeds)
    windows = _layer_windows(cfg)

    def body(x, scanned):
        lp, use_window = scanned
        cache_out = {}

        def attn_fn(h):
            if cfg.mla:
                y, (ckv, krope) = attn.mla_prefill(lp["attn"], h, cfg, cache_len,
                                                   lengths=lengths)
                cache_out["ckv"], cache_out["krope"] = ckv, krope
                return y
            out = attn.gqa_prefill(
                lp["attn"], h, cfg, cache_len,
                window=cfg.sliding_window, use_window=use_window, lengths=lengths,
            )
            if attn.kv_quant_format(cfg):
                y, (cache_out["k_q"], cache_out["k_s"],
                    cache_out["v_q"], cache_out["v_s"]) = out
            else:
                y, (cache_out["k"], cache_out["v"]) = out
            return y

        x = _block(lp, x, cfg, attn_fn)
        return x, cache_out

    x, cache = jax.lax.scan(body, x, (params["layers"], windows))
    if lengths is None:
        last = x[:, -1, :]
    else:
        last = x[jnp.arange(x.shape[0]), lengths - 1]
    return _logits(params, last, cfg), cache


def lm_decode(params, token, cache, pos, cfg: ModelConfig):
    """One decode step. token (b,) int32; pos scalar int32 OR (b,) int32
    per-request positions (ragged continuous batching: each row's RoPE
    angle, decode mask, and cache-commit slot follow its own counter).
    Returns (logits (b, vocab_padded), new cache).

    With flags.deferred_decode_cache the layer scan emits only the new K/V
    rows; they are committed with one donated dynamic-update-slice (scalar
    pos) or one per-row scatter (vector pos) at the end (§Perf decode
    optimization)."""
    int8kv = attn.kv_quant_format(cfg) is not None and not cfg.mla
    kvt = (bool(flags.get("kvt_cache_layout")) or int8kv) and not cfg.mla
    deferred = bool(flags.get("deferred_decode_cache")) or kvt or (
        cfg.mla and (flags.get("deferred_decode_cache") or flags.get("kvt_cache_layout")
                     or flags.get("int8_kv_cache"))
    )
    x = embedding_lookup(params["embed"], token, cfg.cdtype())
    if cfg.gemma_norms:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    windows = _layer_windows(cfg)

    def body(x, scanned):
        lp, use_window, layer_cache = scanned
        new_cache = {}

        def attn_fn(h):
            if cfg.mla:
                mla_fn = attn.mla_decode_deferred if deferred else attn.mla_decode
                y, (ckv, krope) = mla_fn(
                    lp["attn"], h, (layer_cache["ckv"], layer_cache["krope"]), pos, cfg
                )
                new_cache["ckv"], new_cache["krope"] = ckv, krope
                return y
            if int8kv:
                c = (layer_cache["k_q"], layer_cache["k_s"],
                     layer_cache["v_q"], layer_cache["v_s"])
                y, rows = attn.gqa_decode_deferred_quant(
                    lp["attn"], h, c, pos, cfg,
                    window=cfg.sliding_window, use_window=use_window,
                )
                (new_cache["k_q"], new_cache["k_s"],
                 new_cache["v_q"], new_cache["v_s"]) = rows
                return y
            c = (layer_cache["k"], layer_cache["v"])
            decode_fn = attn.gqa_decode_deferred if deferred else attn.gqa_decode
            y, (k, v) = decode_fn(
                lp["attn"], h, c, pos, cfg,
                window=cfg.sliding_window, use_window=use_window,
            )
            new_cache["k"], new_cache["v"] = k, v
            return y

        # decode blocks operate on (b, d): reuse _block via a 1-seq view
        g = cfg.gemma_norms
        h = rmsnorm(x, lp["att_norm"], cfg.norm_eps, plus_one=g)
        a = attn_fn(h)
        if g:
            a = rmsnorm(a, lp["post_att_norm"], cfg.norm_eps, plus_one=True)
        x = x + a
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps, plus_one=g)
        if cfg.moe:
            f = mlpmod.moe_forward(lp["mlp"], h[:, None, :], cfg)[:, 0, :]
        else:
            f = mlpmod.mlp_forward(lp["mlp"], h)
        if g:
            f = rmsnorm(f, lp["post_ffn_norm"], cfg.norm_eps, plus_one=True)
        return x + f, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], windows, cache))
    if deferred and cfg.mla:
        new_cache = {
            "ckv": attn.commit_layers_bt(cache["ckv"], new_cache["ckv"], pos),
            "krope": attn.commit_layers_bt(cache["krope"], new_cache["krope"], pos),
        }
    elif deferred:
        # commit all layers' new rows with one in-place (donated) update
        if int8kv:
            new_cache = {
                "k_q": attn.commit_layers_bkt(cache["k_q"], new_cache["k_q"], pos),
                "k_s": attn.commit_layers_bkt(cache["k_s"], new_cache["k_s"], pos),
                "v_q": attn.commit_layers_bkt(cache["v_q"], new_cache["v_q"], pos),
                "v_s": attn.commit_layers_bkt(cache["v_s"], new_cache["v_s"], pos),
            }
        else:
            commit = attn.commit_layers_bkt if kvt else attn.commit_layers_bt
            new_cache = {
                "k": commit(cache["k"], new_cache["k"], pos),
                "v": commit(cache["v"], new_cache["v"], pos),
            }
    return _logits(params, x, cfg), new_cache
