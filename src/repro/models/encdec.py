"""Encoder-decoder backbone for seamless-m4t-large-v2 ([audio]).

The speech frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (b, s_enc, d_model); the backbone is a standard
transformer enc-dec (bidirectional encoder; decoder with causal self-attn +
cross-attn). All projections are quantizable -> the paper's GQMV applies to
enc/dec/cross projections and FFNs alike.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import embedding_lookup, linear, split_fused
from repro.models import attention as attn
from repro.models import mlp as mlpmod
from repro.models.common import dense_init, embed_init, rmsnorm

# cross-attention encoder-memory length used by decode-shape input specs
DEFAULT_MEMORY_LEN = 4096


def init_cross_attn(key, cfg: ModelConfig) -> dict:
    kq, kkv, ko = jax.random.split(key, 3)
    dt = cfg.pdtype()
    return {
        "wq": dense_init(kq, cfg.q_dim, cfg.d_model, dt),
        "wkv": dense_init(kkv, 2 * cfg.kv_dim, cfg.d_model, dt),  # fused (C4)
        "wo": dense_init(ko, cfg.d_model, cfg.q_dim, dt),
    }


def cross_kv(p, memory, cfg: ModelConfig):
    """Precompute cross K/V from encoder output (done once per request)."""
    b, t, _ = memory.shape
    hd = cfg.resolved_head_dim
    kv = linear(p["wkv"], memory)
    k, v = split_fused(kv, (cfg.kv_dim, cfg.kv_dim))
    return k.reshape(b, t, cfg.num_kv_heads, hd), v.reshape(b, t, cfg.num_kv_heads, hd)


def cross_attend(p, x, k, v, cfg: ModelConfig, memory_mask=None):
    """x: (b, s, d) decoder stream attending to encoder memory (b, t, ...)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    mask = jnp.zeros((s, k.shape[1]), jnp.float32) if memory_mask is None else memory_mask
    ctx = attn._mha(q, k, v, mask, cfg)
    return linear(p["wo"], ctx)


def init_encdec(key, cfg: ModelConfig) -> dict:
    ke, kenc, kdec, kc = jax.random.split(key, 4)
    dt = cfg.pdtype()

    def enc_layer(k):
        ka, km = jax.random.split(k)
        return {
            "att_norm": jnp.ones((cfg.d_model,), dt),
            "attn": attn.init_gqa(ka, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp": mlpmod.init_mlp(km, cfg),
        }

    def dec_layer(k):
        ka, kx, km = jax.random.split(k, 3)
        return {
            "att_norm": jnp.ones((cfg.d_model,), dt),
            "attn": attn.init_gqa(ka, cfg),
            "cross_norm": jnp.ones((cfg.d_model,), dt),
            "cross": init_cross_attn(kx, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp": mlpmod.init_mlp(km, cfg),
        }

    return {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(kenc, cfg.encoder_layers)),
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(kdec, cfg.num_layers)),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "classifier": dense_init(kc, cfg.vocab_padded, cfg.d_model, dt),
    }


def encode(params, frames, cfg: ModelConfig, *, remat=True):
    """frames: (b, s_enc, d_model) precomputed frontend embeddings."""
    x = frames.astype(cfg.cdtype())

    def body(x, lp):
        h = rmsnorm(x, lp["att_norm"], cfg.norm_eps)
        x = x + attn.gqa_forward(lp["attn"], h, cfg, causal=False)
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        return x + mlpmod.mlp_forward(lp["mlp"], h), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, tokens, memory, cfg: ModelConfig, *, remat=True):
    """Teacher-forced decoder pass. tokens (b, s_dec); memory (b, t, d)."""
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())

    def body(x, lp):
        h = rmsnorm(x, lp["att_norm"], cfg.norm_eps)
        x = x + attn.gqa_forward(lp["attn"], h, cfg)
        h = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
        k, v = cross_kv(lp["cross"], memory, cfg)
        x = x + cross_attend(lp["cross"], h, k, v, cfg)
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        return x + mlpmod.mlp_forward(lp["mlp"], h), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x)


def encdec_forward(params, batch, cfg: ModelConfig, *, remat=True):
    """Full seq2seq forward: frames + decoder tokens -> logits."""
    memory = encode(params, batch["frames"], cfg, remat=remat)
    return decode_train(params, batch["tokens"], memory, cfg, remat=remat)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
                      memory_len: int = DEFAULT_MEMORY_LEN):
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((L, batch, memory_len, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((L, batch, memory_len, cfg.num_kv_heads, hd), dtype),
    }


def encdec_prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Encode source frames, precompute cross-K/V, prime decoder with BOS.

    batch = {"frames": (b, s_enc, d), "tokens": (b, s_dec)} -- the decoder
    prompt (usually just BOS) is teacher-forced to populate the self cache.
    """
    memory = encode(params, batch["frames"], cfg, remat=False)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())

    def body(x, lp):
        cache_out = {}
        h = rmsnorm(x, lp["att_norm"], cfg.norm_eps)
        y, (k, v) = attn.gqa_prefill(lp["attn"], h, cfg, cache_len)
        cache_out["k"], cache_out["v"] = k, v
        x = x + y
        h = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
        ck, cv = cross_kv(lp["cross"], memory, cfg)
        cache_out["cross_k"], cache_out["cross_v"] = ck, cv
        x = x + cross_attend(lp["cross"], h, ck, cv, cfg)
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        return x + mlpmod.mlp_forward(lp["mlp"], h), cache_out

    x, cache = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x[:, -1, :], params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x), cache


def encdec_decode(params, token, cache, pos, cfg: ModelConfig):
    """One decoder step against self-cache + precomputed cross-K/V."""
    x = embedding_lookup(params["embed"], token, cfg.cdtype())

    def body(x, scanned):
        lp, lc = scanned
        new_cache = {"cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}
        h = rmsnorm(x, lp["att_norm"], cfg.norm_eps)
        y, (k, v) = attn.gqa_decode(lp["attn"], h, (lc["k"], lc["v"]), pos, cfg)
        new_cache["k"], new_cache["v"] = k, v
        x = x + y
        h = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
        x = x + cross_attend(
            lp["cross"], h[:, None, :], lc["cross_k"], lc["cross_v"], cfg
        )[:, 0, :]
        h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
        return x + mlpmod.mlp_forward(lp["mlp"], h), new_cache

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x), new_cache
