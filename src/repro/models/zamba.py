"""Zamba2 hybrid (arXiv:2411.15242): Mamba2 backbone + a SHARED attention
block applied every ``shared_attn_every`` SSM layers.

The shared block has ONE parameter set reused at every application (Zamba's
parameter-saving trick). Structure here: groups of k Mamba2 layers scanned,
shared GQA+MLP block applied between groups (params closed over, not
scanned), plus a tail of remaining Mamba2 layers.

Simplification vs the released model (noted in DESIGN.md): Zamba2
concatenates the original embedding into the shared-block input and applies
per-application LoRA deltas; we feed the running stream only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import flags
from repro.core.qlinear import embedding_lookup, linear
from repro.models import attention as attn
from repro.models import mlp as mlpmod
from repro.models.common import dense_init, embed_init, rmsnorm
from repro.models.ssm import init_mamba2, mamba2_decode, mamba2_forward, ssm_dims


def _layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(groups, per_group, tail): num_layers = groups*per_group + tail."""
    k = cfg.shared_attn_every
    return cfg.num_layers // k, k, cfg.num_layers % k


def init_zamba(key, cfg: ModelConfig) -> dict:
    groups, per, tail = _layout(cfg)
    ke, km, ka, kmlp, kc, kt = jax.random.split(key, 6)
    mkeys = jax.random.split(km, groups * per).reshape(groups, per, 2)
    dt = cfg.pdtype()

    def init_mamba_layer(k):
        return {
            "norm": jnp.ones((cfg.d_model,), dt),
            "mamba": init_mamba2(k, cfg),
        }

    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        # (groups, per, ...) stacked mamba layers
        "mamba_layers": jax.vmap(jax.vmap(init_mamba_layer))(mkeys),
        "shared": {
            "att_norm": jnp.ones((cfg.d_model,), dt),
            "attn": attn.init_gqa(ka, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp": mlpmod.init_mlp(kmlp, cfg),
        },
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "classifier": dense_init(kc, cfg.vocab_padded, cfg.d_model, dt),
    }
    if tail:
        tkeys = jax.random.split(kt, tail)
        params["tail_layers"] = jax.vmap(init_mamba_layer)(tkeys)
    return params


def _shared_block(sp, x, cfg: ModelConfig, attn_fn):
    h = rmsnorm(x, sp["att_norm"], cfg.norm_eps)
    x = x + attn_fn(h)
    h = rmsnorm(x, sp["ffn_norm"], cfg.norm_eps)
    return x + mlpmod.mlp_forward(sp["mlp"], h)


def zamba_forward(params, tokens, cfg: ModelConfig, *, remat=True):
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())
    sp = params["shared"]

    def mamba_body(x, lp):
        y, _ = mamba2_forward(lp["mamba"], rmsnorm(x, lp["norm"], cfg.norm_eps), cfg)
        return x + y, None

    mb = jax.checkpoint(mamba_body) if remat else mamba_body

    def group_body(x, glp):
        x, _ = jax.lax.scan(mb, x, glp)
        x = _shared_block(sp, x, cfg, lambda h: attn.gqa_forward(sp["attn"], h, cfg))
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["mamba_layers"])
    if "tail_layers" in params:
        x, _ = jax.lax.scan(mb, x, params["tail_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def zamba_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    groups, per, tail = _layout(cfg)
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    s = cfg.ssm
    hd = cfg.resolved_head_dim

    def mamba_state(n):
        return {
            "conv": jnp.zeros((n, batch, s.conv_kernel - 1, conv_ch), dtype),
            "h": jnp.zeros((n, batch, nheads, s.head_dim, s.state_dim), jnp.float32),
        }

    if flags.get("kvt_cache_layout") or flags.get("int8_kv_cache"):
        kv_shape = (groups, batch, cfg.num_kv_heads, cache_len, hd)
    else:
        kv_shape = (groups, batch, cache_len, cfg.num_kv_heads, hd)
    cache = {
        "mamba": jax.tree.map(
            lambda t: t.reshape(groups, per, *t.shape[1:]), mamba_state(groups * per)
        ),
        # one KV cache per shared-block application
        "shared_k": jnp.zeros(kv_shape, dtype),
        "shared_v": jnp.zeros(kv_shape, dtype),
    }
    if tail:
        cache["tail"] = mamba_state(tail)
    return cache


def _slot_axis(path) -> int:
    """Batch axis of a zamba cache leaf by its pytree path: the mamba
    conv/h states are stacked (groups, per, batch, ...) so batch sits on
    axis 2; shared_k/shared_v ((groups, batch, ...) in both KV layouts) and
    the tail states ((tail, batch, ...)) keep it on axis 1."""
    return 2 if path[0].key == "mamba" else 1


def zamba_insert_slots(cache, rows, slots):
    """Scatter per-request prefill ``rows`` (SSM state + shared-attention
    KV) into decode ``slots`` of a batched cache — the slot-state
    continuous-batching contract (serving/core.py RecurrentAdapter). The
    batch axis is path-dependent, hence the keyed tree map."""
    def put(path, big, small):
        idx = (slice(None),) * _slot_axis(path) + (slots,)
        return big.at[idx].set(small)

    return jax.tree_util.tree_map_with_path(put, cache, rows)


def zamba_gather_slots(cache, slots):
    """Inverse of ``zamba_insert_slots``: per-slot state rows for ``slots``."""
    def take(path, big):
        idx = (slice(None),) * _slot_axis(path) + (slots,)
        return big[idx]

    return jax.tree_util.tree_map_with_path(take, cache)


def zamba_prefill(params, tokens, cfg: ModelConfig, cache_len: int):
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())
    sp = params["shared"]

    def mamba_body(x, lp):
        y, st = mamba2_forward(lp["mamba"], rmsnorm(x, lp["norm"], cfg.norm_eps), cfg)
        return x + y, {"conv": st[0], "h": st[1]}

    def group_body(x, glp):
        x, mstate = jax.lax.scan(mamba_body, x, glp)
        kv = {}

        def attn_fn(h):
            # zamba's shared cache supports the kvt layout but not int8
            with flags.overrides(int8_kv_cache=False):
                y, (k, v) = attn.gqa_prefill(sp["attn"], h, cfg, cache_len)
            kv["k"], kv["v"] = k, v
            return y

        x = _shared_block(sp, x, cfg, attn_fn)
        return x, {"mamba": mstate, "k": kv["k"], "v": kv["v"]}

    x, gstate = jax.lax.scan(group_body, x, params["mamba_layers"])
    cache = {"mamba": gstate["mamba"], "shared_k": gstate["k"], "shared_v": gstate["v"]}
    if "tail_layers" in params:
        x, tstate = jax.lax.scan(mamba_body, x, params["tail_layers"])
        cache["tail"] = tstate
    x = rmsnorm(x[:, -1, :], params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x), cache


def zamba_decode(params, token, cache, pos, cfg: ModelConfig):
    x = embedding_lookup(params["embed"], token, cfg.cdtype())
    sp = params["shared"]
    kvt = bool(flags.get("kvt_cache_layout") or flags.get("int8_kv_cache"))
    deferred = bool(flags.get("deferred_decode_cache")) or kvt

    def mamba_body(x, scanned):
        lp, st = scanned
        y, (conv, h) = mamba2_decode(
            lp["mamba"], rmsnorm(x, lp["norm"], cfg.norm_eps), (st["conv"], st["h"]), cfg
        )
        return x + y, {"conv": conv, "h": h}

    def group_body(x, scanned):
        glp, gst = scanned
        x, mstate = jax.lax.scan(mamba_body, x, (glp, gst["mamba"]))
        kv = {}

        def attn_fn(h):
            decode_fn = attn.gqa_decode_deferred if deferred else attn.gqa_decode
            with flags.overrides(int8_kv_cache=False,
                                 kvt_cache_layout=kvt):
                y, (k, v) = decode_fn(sp["attn"], h, (gst["k"], gst["v"]), pos, cfg)
            kv["k"], kv["v"] = k, v
            return y

        h = rmsnorm(x, sp["att_norm"], cfg.norm_eps)
        x = x + attn_fn(h)
        h = rmsnorm(x, sp["ffn_norm"], cfg.norm_eps)
        x = x + mlpmod.mlp_forward(sp["mlp"], h)
        return x, {"mamba": mstate, "k": kv["k"], "v": kv["v"]}

    gcache = {"mamba": cache["mamba"], "k": cache["shared_k"], "v": cache["shared_v"]}
    x, gstate = jax.lax.scan(group_body, x, (params["mamba_layers"], gcache))
    new_k, new_v = gstate["k"], gstate["v"]
    if deferred:
        # commit all groups' rows with one in-place update each (per-row
        # scatter when pos is a (b,) vector — ragged batches)
        commit = attn.commit_layers_bkt if kvt else attn.commit_layers_bt
        new_k = commit(cache["shared_k"], new_k, pos)
        new_v = commit(cache["shared_v"], new_v, pos)
    new_cache = {"mamba": gstate["mamba"], "shared_k": new_k, "shared_v": new_v}
    if "tail_layers" in params:
        x, tstate = jax.lax.scan(mamba_body, x, (params["tail_layers"], cache["tail"]))
        new_cache["tail"] = tstate
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x), new_cache
