"""FFN blocks: SwiGLU MLP (fused W1+W3, paper Alg. 2 line 12) and MoE."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import linear, split_fused
from repro.dist import logical
from repro.models.common import dense_init, swiglu


# ---------------------------------------------------------------------------
# dense SwiGLU
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    k1, k2 = jax.random.split(key)
    dt = cfg.pdtype()
    f = d_ff or cfg.d_ff
    return {
        "w13": dense_init(k1, 2 * f, cfg.d_model, dt),   # fused gate+up (C4)
        "w2": dense_init(k2, cfg.d_model, f, dt),
    }


def mlp_forward(p, x):
    f = p["w2"].shape[-1]  # QuantizedTensor.shape is the LOGICAL shape
    y13 = linear(p["w13"], x)
    y13 = logical.constrain(y13, *(["dp"] + [None] * (y13.ndim - 2) + ["tp"]))
    gate, up = split_fused(y13, (f, f))
    h = logical.constrain(swiglu(gate, up), *(["dp"] + [None] * (y13.ndim - 2) + ["tp"]))
    return linear(p["w2"], h)


# ---------------------------------------------------------------------------
# MoE (dbrx: 16e top-4; deepseek-v2-lite: 64e top-6 + 2 shared)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    dt = cfg.pdtype()
    kr, ke, ks = jax.random.split(key, 3)
    ekeys = jax.random.split(ke, m.num_experts)
    experts = jax.vmap(lambda k: {
        "w13": dense_init(jax.random.fold_in(k, 0), 2 * m.d_expert, cfg.d_model, dt),
        "w2": dense_init(jax.random.fold_in(k, 1), cfg.d_model, m.d_expert, dt),
    })(ekeys)
    p = {
        "router_w": dense_init(kr, m.num_experts, cfg.d_model, jnp.float32),
        "experts": experts,   # stacked (E, ...) -> expert-parallel shardable
    }
    if m.num_shared:
        p["shared"] = init_mlp(ks, cfg, d_ff=m.d_expert * m.num_shared)
    return p


def moe_forward(p, x, cfg: ModelConfig):
    """Dense-dispatch MoE: top-k routing with a one-hot combine einsum.

    All experts compute on all tokens and the combine mask selects — the
    standard compile-friendly SPMD formulation when experts are sharded over
    the 'model' axis (EP). Token-dropping dispatch is a serving optimization
    left to the perf log.
    """
    m = cfg.moe
    b, s, d = x.shape
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32), p["router_w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)            # (b,s,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalize
    # combine weights (b,s,E): sum of top-k one-hots * gate prob
    combine = jnp.sum(
        jax.nn.one_hot(top_idx, m.num_experts, dtype=x.dtype) * top_p[..., None].astype(x.dtype),
        axis=2,
    )

    def expert_fn(ep, xe):
        gate, up = split_fused(linear(ep["w13"], xe), (m.d_expert, m.d_expert))
        return linear(ep["w2"], swiglu(gate, up))

    expert_out = jax.vmap(expert_fn, in_axes=(0, None))(p["experts"], x)  # (E,b,s,d)
    expert_out = logical.constrain(expert_out, "tp", "dp", None, None)
    y = jnp.einsum("ebsd,bse->bsd", expert_out, combine)
    y = logical.constrain(y, "dp", None, None)
    if m.num_shared:
        y = y + mlp_forward(p["shared"], x)
    return y


def moe_aux_loss(p, x, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (framework substrate)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32), p["router_w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_idx = jax.lax.top_k(probs, m.top_k)[1]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32), axis=(0, 1, 2)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
