"""Shared model building blocks: norms, RoPE, activations, init, masks.

Everything is functional: params are plain dict pytrees, layers are pure
functions. Weight matrices use the paper's (out, in) layout so that the
quantization groups run along the contraction axis (see core/qlinear.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def dense_init(key, out_dim: int, in_dim: int, dtype) -> jax.Array:
    scale = in_dim ** -0.5
    return (jax.random.normal(key, (out_dim, in_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5, *, plus_one: bool = False) -> jax.Array:
    """RMSNorm (paper's unquantized component, Table I). gemma2 stores w-1
    and applies (1+w) — ``plus_one`` selects that convention."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (x32 * inv * scale).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate-half RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                              # (dim/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, dim/2)
    cos = jnp.cos(angles)[..., None, :]                         # (..., seq, 1, dim/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask(seq: int, window: int | None = None) -> jax.Array:
    """(seq, seq) additive mask; ``window`` enables sliding-window locality
    (gemma2 local layers)."""
    q = jnp.arange(seq)[:, None]
    k = jnp.arange(seq)[None, :]
    ok = k <= q
    if window is not None:
        ok &= (q - k) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def decode_mask(cache_len: int, pos: jax.Array, window: int | None = None) -> jax.Array:
    """Additive mask for a single decode step (entries > pos are future or
    still-unwritten slots). ``pos`` scalar -> (cache_len,); ``pos`` (b,)
    per-request positions -> (b, cache_len) row-wise masks.

    Cache slots in (length_i, pos_i] hold the tokens decode itself wrote (it
    overwrites right-pad slots in order), so `k <= pos_i` alone is a correct
    per-request mask for ragged batches."""
    pos = jnp.asarray(pos)
    k = jnp.arange(cache_len)
    if pos.ndim:
        k = k[None, :]
        pos = pos[:, None]
    ok = k <= pos
    if window is not None:
        ok &= (pos - k) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def length_mask(lengths: jax.Array, kv_len: int) -> jax.Array:
    """(b, kv_len) additive mask hiding right-pad keys at positions >= each
    row's true length (ragged prefill)."""
    ok = jnp.arange(kv_len)[None, :] < lengths[:, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
