"""Uniform model API over all architecture families + input_specs.

Every architecture exposes the same five entry points so the training loop,
serving engine, and dry-run are family-agnostic:

  init(key)                         -> params
  forward(params, batch)            -> logits (b, s, vocab_padded)
  init_cache(batch, cache_len, dt)  -> cache pytree
  prefill(params, batch, cache_len) -> (last logits, cache)
  decode(params, token, cache, pos) -> (logits, cache)

``cache_kind`` ("kv" / "state" / "none") drives two lint ledgers: the
registry-coverage slot-hook contract and the shadow-coverage sanitizer
sweep (every kv/state family must appear in ``SANITIZED_ARCHS``,
tests/arch_matrix.py).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec as _encdec
from repro.models import rwkv as _rwkv
from repro.models import transformer as _tf
from repro.models import zamba as _zamba

ARCH_IDS = [
    "tinyllama-1.1b",
    "pixtral-12b",
    "rwkv6-7b",
    "minicpm3-4b",
    "deepseek-coder-33b",
    "gemma2-2b",
    "internlm2-1.8b",
    "dbrx-132b",
    "deepseek-v2-lite-16b",
    "zamba2-7b",
    "seamless-m4t-large-v2",
]


def load_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable            # (params, batch) -> logits
    init_cache: Callable         # (batch_size, cache_len, dtype) -> cache
    prefill: Callable            # (params, batch, cache_len) -> (logits, cache)
    decode: Callable             # (params, token, cache, pos) -> (logits, cache)
    # Ragged-serving contract: prefill honors batch["lengths"] (per-row true
    # prompt lengths in a right-padded batch: pad keys masked, first-token
    # logits gathered at lengths[i]-1) and decode accepts a (b,) position
    # vector. Families with sequential prefill state (rwkv6, zamba2's SSM
    # backbone, encdec) cannot skip pad tokens mid-recurrence, so the serving
    # front-end batches them by exact length instead.
    supports_lengths: bool = False
    # Paged-KV contract: the family exposes a block-pool cache
    # (init_paged_cache(num_blocks, block_size, dtype)) and a block-table
    # decode step (decode_paged(params, token, cache, block_table, pos)).
    # GQA decoder_lm families only: the MLA latent cache and the recurrent
    # families keep their contiguous/stateful layouts.
    supports_paged: bool = False
    init_paged_cache: Callable | None = None   # (num_blocks, block_size, dt) -> pool
    decode_paged: Callable | None = None       # (params, tok, pool, table, pos) -> (logits, pool)
    # Speculative-verify contract (DESIGN.md §10): a chunked k-token decode
    # that returns per-position logits WITHOUT writing the cache, plus a
    # commit that writes only the accepted prefix (rejected drafts dropped
    # by out-of-bounds scatter). GQA decoder_lm families only — the same
    # layout class as supports_paged.
    supports_spec: bool = False
    verify: Callable | None = None             # (params, toks (b,k), cache, pos) -> (logits (b,k,V), rows)
    commit_verify: Callable | None = None      # (cache, rows, pos, n_commit) -> cache
    verify_paged: Callable | None = None       # (params, toks, pool, table, pos) -> (logits, rows)
    commit_verify_paged: Callable | None = None  # (pool, rows, table, pos, n_commit) -> pool
    # Cache-kind contract (serving/core.py): which CacheAdapter family can
    # hold this model's per-request decode state.
    #   "kv"    — contiguous KV/latent rows, batch on axis 1 of every leaf
    #             (decoder_lm; the paged pool is an optional layout on top)
    #   "state" — O(1)-ish per-slot recurrent state served by slot
    #             gather/scatter (rwkv6, zamba2): continuous batching with
    #             exact-length admission groups, no paging
    #   "none"  — no slot-addressable cache: encdec's encoder output is
    #             per-request state the slot schedulers don't carry
    # "kv" and "state" families must ship both slot hooks; "none" neither.
    cache_kind: str = "none"
    insert_slots: Callable | None = None       # (cache, rows, slots) -> cache
    gather_slots: Callable | None = None       # (cache, slots) -> per-slot rows


def build(cfg: ModelConfig) -> Model:
    if cfg.model_type == "decoder_lm":
        def forward(params, batch, remat=True):
            return _tf.lm_forward(
                params, batch["tokens"], cfg,
                frontend_embeds=batch.get("patch_embeds"), remat=remat,
            )

        def prefill(params, batch, cache_len):
            return _tf.lm_prefill(
                params, batch["tokens"], cfg, cache_len,
                frontend_embeds=batch.get("patch_embeds"),
                lengths=batch.get("lengths"),
            )

        paged = not cfg.mla
        return Model(
            cfg=cfg,
            init=lambda key: _tf.init_lm(key, cfg),
            forward=forward,
            init_cache=lambda b, t, dt: _tf.lm_init_cache(cfg, b, t, dt),
            prefill=prefill,
            decode=lambda p, tok, cache, pos: _tf.lm_decode(p, tok, cache, pos, cfg),
            supports_lengths=True,
            supports_paged=paged,
            init_paged_cache=(
                (lambda nb, bs, dt: _tf.lm_init_paged_cache(cfg, nb, bs, dt))
                if paged else None),
            decode_paged=(
                (lambda p, tok, cache, table, pos:
                 _tf.lm_decode_paged(p, tok, cache, table, pos, cfg))
                if paged else None),
            supports_spec=paged,
            verify=(
                (lambda p, toks, cache, pos: _tf.lm_verify(p, toks, cache, pos, cfg))
                if paged else None),
            commit_verify=(
                (lambda cache, rows, pos, n: _tf.lm_commit_verify(cache, rows, pos, n))
                if paged else None),
            verify_paged=(
                (lambda p, toks, cache, table, pos:
                 _tf.lm_verify_paged(p, toks, cache, table, pos, cfg))
                if paged else None),
            commit_verify_paged=(
                (lambda cache, rows, table, pos, n:
                 _tf.lm_commit_verify_paged(cache, rows, table, pos, n))
                if paged else None),
            cache_kind="kv",
            insert_slots=_tf.lm_insert_slots,
            gather_slots=_tf.lm_gather_slots,
        )

    if cfg.model_type == "rwkv6":
        return Model(
            cfg=cfg,
            init=lambda key: _rwkv.init_rwkv(key, cfg),
            forward=lambda p, batch, remat=True: _rwkv.rwkv_forward(p, batch["tokens"], cfg),
            init_cache=lambda b, t, dt: _rwkv.rwkv_init_state(cfg, b, dt),
            prefill=lambda p, batch, t: _rwkv.rwkv_prefill(p, batch["tokens"], cfg, t),
            decode=lambda p, tok, cache, pos: _rwkv.rwkv_decode(p, tok, cache, pos, cfg),
            # recurrent state: prefill cannot skip pad tokens, no paged
            # layout, no uncommitted k-token verify — all deliberate. The
            # slot-state hooks make continuous batching a state scatter
            # instead (serving/core.py RecurrentAdapter).
            supports_lengths=False,
            supports_paged=False,
            supports_spec=False,
            cache_kind="state",
            insert_slots=_rwkv.rwkv_insert_slots,
            gather_slots=_rwkv.rwkv_gather_slots,
        )

    if cfg.model_type == "zamba2":
        return Model(
            cfg=cfg,
            init=lambda key: _zamba.init_zamba(key, cfg),
            forward=lambda p, batch, remat=True: _zamba.zamba_forward(
                p, batch["tokens"], cfg, remat=remat
            ),
            init_cache=lambda b, t, dt: _zamba.zamba_init_cache(cfg, b, t, dt),
            prefill=lambda p, batch, t: _zamba.zamba_prefill(p, batch["tokens"], cfg, t),
            decode=lambda p, tok, cache, pos: _zamba.zamba_decode(p, tok, cache, pos, cfg),
            # SSM backbone carries sequential scan state through prefill:
            # same exclusions as rwkv6 (see Model docstring); the slot-state
            # hooks cover both the SSM states and the shared-attention KV
            supports_lengths=False,
            supports_paged=False,
            supports_spec=False,
            cache_kind="state",
            insert_slots=_zamba.zamba_insert_slots,
            gather_slots=_zamba.zamba_gather_slots,
        )

    if cfg.model_type == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: _encdec.init_encdec(key, cfg),
            forward=lambda p, batch, remat=True: _encdec.encdec_forward(p, batch, cfg, remat=remat),
            init_cache=lambda b, t, dt: _encdec.encdec_init_cache(cfg, b, t, dt),
            prefill=lambda p, batch, t: _encdec.encdec_prefill(p, batch, cfg, t),
            decode=lambda p, tok, cache, pos: _encdec.encdec_decode(p, tok, cache, pos, cfg),
            # encoder output is per-request state the slot/paged schedulers
            # don't carry; decoder cache stays contiguous and bucket-served
            supports_lengths=False,
            supports_paged=False,
            supports_spec=False,
            cache_kind="none",
        )

    raise ValueError(f"unknown model_type: {cfg.model_type}")


def build_arch(arch_id: str, *, reduced: bool = False) -> Model:
    cfg = load_config(arch_id)
    return build(cfg.reduced() if reduced else cfg)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation) + smoke batches
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStructs for the step function inputs of one (arch, shape)
    cell. ``decode`` kinds describe only (token, pos); the cache struct comes
    from ``cache_specs``."""
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), tok), "labels": _sds((b, s), tok)}
        if cfg.model_type == "encdec":
            batch["frames"] = _sds((b, s, cfg.d_model), cfg.cdtype())
        if cfg.frontend == "patch_embed":
            batch["patch_embeds"] = _sds((b, cfg.num_frontend_tokens, cfg.d_model), cfg.cdtype())
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), tok)}
        if cfg.model_type == "encdec":
            # encoder consumes the full source; decoder is primed with BOS-ish
            # short prompt (64) -- the 32k prefill cost is the encoder pass
            batch = {"frames": _sds((b, s, cfg.d_model), cfg.cdtype()),
                     "tokens": _sds((b, 64), tok)}
        if cfg.frontend == "patch_embed":
            batch["patch_embeds"] = _sds((b, cfg.num_frontend_tokens, cfg.d_model), cfg.cdtype())
        return batch
    # decode: one new token against a cache of seq_len
    return {"token": _sds((b,), tok), "pos": _sds((), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Cache ShapeDtypeStruct tree for decode cells (eval_shape, no alloc)."""
    model = build(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, cfg.cdtype())
    )


def smoke_batch(cfg: ModelConfig, *, batch: int = 2, seq: int = 16, seed: int = 0):
    """Small concrete batch for CPU smoke tests (reduced configs)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq)).astype(np.int32)
    out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
    if cfg.model_type == "encdec":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
        )
    if cfg.frontend == "patch_embed":
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.num_frontend_tokens, cfg.d_model)).astype(np.float32)
        )
    return out
