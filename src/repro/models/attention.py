"""Attention blocks: GQA (llama-family, gemma2 local/global+softcap) and
MLA (multi-head latent attention: minicpm3, deepseek-v2).

Each block exposes:
  init_*          -> param dict (weights in (out, in) layout, quantizable)
  *_forward       -> full-sequence self-attention (training / naive prefill)
  *_prefill       -> forward + returns the cache tensors for decode
  *_decode        -> single-token step against the cache

Projections go through ``linear`` so the same code runs fp32/bf16 (training,
"PS baseline") or W8A8 GQMV (paper path) depending on the weight leaf type.
QKV is one fused projection (paper Alg. 2 line 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import flags
from repro.core.qlinear import linear, split_fused
from repro.core.quant import FP8_MAX, QuantizedTensor
from repro.dist import logical
from repro.models.common import (
    NEG_INF,
    apply_rope,
    causal_mask,
    decode_mask,
    dense_init,
    length_mask,
    rmsnorm,
    softcap,
)

# ---------------------------------------------------------------------------
# per-request decode positions
#
# Every decode entry point accepts ``pos`` as either a scalar (uniform batch,
# the original contract) or a (b,) vector of per-request positions (ragged
# continuous batching). The helpers below keep one code path for RoPE rows,
# score updates at the current column, and per-row cache commits.
# ---------------------------------------------------------------------------

def _pos_rows(pos, b: int):
    """(b, 1) int32 RoPE position rows from scalar or (b,) ``pos``."""
    pos = jnp.asarray(pos, jnp.int32)
    return pos.reshape(b, 1) if pos.ndim else jnp.full((b, 1), pos, jnp.int32)


def _commit_bt(cache, rows, pos):
    """Write rows (b, 1, ...) into cache (b, T, ...) at time ``pos``."""
    if jnp.asarray(pos).ndim:
        return cache.at[jnp.arange(cache.shape[0]), pos].set(rows[:, 0])
    return jax.lax.dynamic_update_slice_in_dim(cache, rows, pos, axis=1)


def _commit_bkt(cache, rows, pos):
    """Write rows (b, KV, 1, ...) into cache (b, KV, T, ...) at ``pos``."""
    if jnp.asarray(pos).ndim:
        b, kv = cache.shape[:2]
        return cache.at[
            jnp.arange(b)[:, None], jnp.arange(kv)[None, :], pos[:, None]
        ].set(rows[:, :, 0])
    start = (0, 0, pos) + (0,) * (cache.ndim - 3)
    return jax.lax.dynamic_update_slice(cache, rows, start)


def _col_update(scores, cur, pos):
    """scores (b, ..., t): overwrite column ``pos`` (per-row when vector)
    with cur (b, ...)."""
    if jnp.asarray(pos).ndim:
        idx = (jnp.arange(scores.shape[0]),) + (slice(None),) * (scores.ndim - 2) + (pos,)
        return scores.at[idx].set(cur)
    return jax.lax.dynamic_update_slice(
        scores, cur[..., None], (0,) * (scores.ndim - 1) + (pos,)
    )


def _col_at(attn, pos):
    """attn (b, ..., t) -> (b, ..., 1) column at ``pos`` (per-row when vector)."""
    if jnp.asarray(pos).ndim:
        idx = (jnp.arange(attn.shape[0]),) + (slice(None),) * (attn.ndim - 2) + (pos,)
        return attn[idx][..., None]
    return jax.lax.dynamic_slice(
        attn, (0,) * (attn.ndim - 1) + (pos,), attn.shape[:-1] + (1,)
    )


def _bcast_decode_mask(m):
    """decode mask (t,) or (b, t) -> broadcastable over (b, s=1, t) scores."""
    return m[None, None, :] if m.ndim == 1 else m[:, None, :]


def commit_layers_bt(cache, rows, pos):
    """Deferred-decode commit, (L, b, T, ...) layout: write rows (L, b, 1, ...)
    at time ``pos`` — one donated dynamic-update-slice (scalar pos) or one
    per-row scatter (vector pos, ragged batches)."""
    if jnp.asarray(pos).ndim:
        return cache.at[:, jnp.arange(cache.shape[1]), pos].set(rows[:, :, 0])
    return jax.lax.dynamic_update_slice(
        cache, rows, (0, 0, pos) + (0,) * (cache.ndim - 3)
    )


def commit_layers_paged(pages, rows, block_table, pos):
    """Deferred paged commit: write rows (L, b, KV, hd) into the block pool
    (L, NB, BS, KV, hd) at each row's (physical block, offset) for virtual
    position ``pos`` (b,). One scatter for all layers. The block index is
    clamped to the table width so a frozen/overflowed position can never
    escape its own table row (live positions are host-asserted in range)."""
    bs = pages.shape[2]
    b = rows.shape[1]
    idx = jnp.minimum(pos // bs, block_table.shape[1] - 1)
    phys = block_table[jnp.arange(b), idx]                    # (b,)
    return pages.at[:, phys, pos % bs].set(rows)


def commit_layers_verify(cache, rows, pos, n_commit):
    """Speculative-verify commit, (L, b, T, KV, hd) layout: write the chunk's
    K/V rows (L, b, k, KV, hd) at times ``pos + j`` for the ACCEPTED prefix
    ``j < n_commit[b]`` only. Rejected rows are redirected to column ``T``
    (out of bounds — scatter updates there are dropped), so the cache after a
    partial accept is bit-identical to one that never saw the rejected
    drafts: rollback is a position rewind, no zeroing pass (DESIGN.md §10)."""
    b, k = rows.shape[1], rows.shape[2]
    t = cache.shape[2]
    j = jnp.arange(k, dtype=jnp.int32)[None, :]
    cols = jnp.where(j < n_commit[:, None], pos[:, None] + j, t)      # (b, k)
    return cache.at[:, jnp.arange(b)[:, None], cols].set(rows)


def commit_layers_paged_verify(pages, rows, block_table, pos, n_commit):
    """Speculative-verify commit into the block pool (L, NB, BS, KV, hd):
    row j of the chunk lands at virtual position ``pos + j``'s (physical
    block, offset); rejected rows (``j >= n_commit``) are redirected past
    the pool's block axis, where the scatter drops them — NOT to the
    scheduler's sink block 0, which under the engine's identity tables is
    a live block. The pool after a partial accept is therefore
    bit-identical to one that never saw the rejected drafts."""
    nb, bs = pages.shape[1], pages.shape[2]
    b, k = rows.shape[1], rows.shape[2]
    j = jnp.arange(k, dtype=jnp.int32)[None, :]
    vpos = pos[:, None] + j                                           # (b, k)
    idx = jnp.minimum(vpos // bs, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(block_table, idx, axis=1)              # (b, k)
    phys = jnp.where(j < n_commit[:, None], phys, nb)                 # dropped
    return pages.at[:, phys, vpos % bs].set(rows)


def commit_layers_bkt(cache, rows, pos):
    """Deferred-decode commit, (L, b, KV, T, ...) layout (kvt / int8 caches)."""
    if jnp.asarray(pos).ndim:
        b, kv = cache.shape[1], cache.shape[2]
        return cache.at[
            :, jnp.arange(b)[:, None], jnp.arange(kv)[None, :], pos[:, None]
        ].set(rows[:, :, :, 0])
    return jax.lax.dynamic_update_slice(
        cache, rows, (0, 0, 0, pos) + (0,) * (cache.ndim - 4)
    )


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig) -> dict:
    kq, ko = jax.random.split(key)
    dt = cfg.pdtype()
    return {
        "wqkv": dense_init(kq, cfg.q_dim + 2 * cfg.kv_dim, cfg.d_model, dt),
        "wo": dense_init(ko, cfg.d_model, cfg.q_dim, dt),
    }


def _gqa_scale(cfg: ModelConfig) -> float:
    base = cfg.query_scale if cfg.query_scale is not None else cfg.resolved_head_dim
    return base ** -0.5


def _qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    qkv = linear(p["wqkv"], x)
    q, k, v = split_fused(qkv, (cfg.q_dim, cfg.kv_dim, cfg.kv_dim))
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mha_blockwise(q, k, v, cfg: ModelConfig, *, causal=True, window=None,
                   use_window=None, lengths=None):
    """Chunked online-softmax attention (flash-style), XLA fallback of
    kernels/flash_attn.py. Streams K/V in chunks of flags.attention_chunk;
    never materializes the (b,kv,g,s,t) score tensor. Used for train/prefill
    under flags.blockwise_attention; TPU deployment uses the Pallas kernel."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    tp = logical.size("tp")
    if kv % tp == 0:
        q = logical.constrain(q, "dp", None, "tp", None)
        k = logical.constrain(k, "dp", None, "tp", None)
        v = logical.constrain(v, "dp", None, "tp", None)
        cspec = ("dp", "tp", None, None, None)
    else:
        cspec = ("dp", None, None, None, None)
    chunk = int(flags.get("attention_chunk"))
    chunk = min(chunk, t)
    while t % chunk:
        chunk //= 2
    nchunks = t // chunk
    qg = q.reshape(b, s, kv, g, hd)
    scale = _gqa_scale(cfg)
    q_pos = jnp.arange(s)

    def body(carry, ic):
        m_prev, l_prev, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ic * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ic * chunk, chunk, axis=1)
        sc = jnp.einsum("bskgh,btkh->bkgst", qg, ks).astype(jnp.float32) * scale
        sc = logical.constrain(sc, *cspec)
        if cfg.attn_logit_softcap:
            sc = softcap(sc, cfg.attn_logit_softcap)
        k_pos = ic * chunk + jnp.arange(chunk)
        ok = jnp.ones((s, chunk), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            okw = ok & ((q_pos[:, None] - k_pos[None, :]) < window)
            ok = okw if use_window is None else jnp.where(use_window, okw, ok)
        if lengths is not None:
            # ragged prefill: hide right-pad keys per row -> (b, s, chunk)
            okb = ok[None] & (k_pos[None, None, :] < lengths[:, None, None])
            sc = jnp.where(okb[:, None, None], sc, NEG_INF)
        else:
            sc = jnp.where(ok[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(q.dtype), vs
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, kv, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nchunks))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h * hd)  # (b,s,kv,g,hd)->(b,s,h*hd)
    return logical.constrain(out, "dp", None, "tp" if kv % tp == 0 else None)


def _mha(q, k, v, mask, cfg: ModelConfig):
    """q: (b,s,H,hd); k,v: (b,t,KV,hd); mask additive (s,t) or (b,s,t).

    Logical sharding: kv-head-parallel when KV divides the model axis, else
    q-sequence-parallel (train/prefill) or cache-sequence-parallel (decode).
    Without annotations XLA SPMD replicates the (b,kv,g,s,t) score buffer
    (measured: 120 GB/layer on deepseek-coder train_4k).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    tp = logical.size("tp")
    # batch-1 decode: shard the cache length over the FULL mesh (seq axes)
    seq_ax = "seq" if (b == 1 and s == 1 and t % max(logical.size("seq"), 1) == 0
                       and logical.size("seq") > 1) else "tp"
    mode = "head" if kv % tp == 0 else ("seq" if s % tp == 0 else
                                        ("cache" if t % tp == 0 else "none"))
    if b == 1 and s == 1 and seq_ax == "seq":
        mode = "cache"
    if mode == "head":
        q = logical.constrain(q, "dp", None, "tp", None)
        k = logical.constrain(k, "dp", None, "tp", None)
        v = logical.constrain(v, "dp", None, "tp", None)
    elif mode == "seq":
        q = logical.constrain(q, "dp", "tp", None, None)
        k = logical.constrain(k, "dp", None, None, None)
        v = logical.constrain(v, "dp", None, None, None)
    elif mode == "cache":
        k = logical.constrain(k, None if seq_ax == "seq" else "dp", seq_ax, None, None)
        v = logical.constrain(v, None if seq_ax == "seq" else "dp", seq_ax, None, None)
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    score_spec = {
        "head": ("dp", "tp", None, None, None),
        "seq": ("dp", None, None, "tp", None),
        "cache": (None if seq_ax == "seq" else "dp", None, None, None, seq_ax),
        "none": ("dp", None, None, None, None),
    }[mode]
    scores = logical.constrain(scores, *score_spec)
    scores *= _gqa_scale(cfg)
    if cfg.attn_logit_softcap:
        scores = softcap(scores, cfg.attn_logit_softcap)
    scores = scores + mask[..., None, None, :, :] if mask.ndim == 2 else scores + mask[:, None, None]
    attn = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    attn = logical.constrain(attn, *score_spec)
    out = jnp.einsum("bkgst,btkh->bskgh", attn, v)
    out = out.reshape(b, s, h, hd).reshape(b, s, h * hd)
    return logical.constrain(
        out, "dp", "tp" if mode == "seq" else None, "tp" if mode == "head" else None
    )


def _flag_mask(s: int, window, use_window):
    """(s, s) additive mask; ``use_window`` may be a traced bool selecting the
    sliding-window variant per layer (gemma2 L/G alternation inside scan)."""
    full = causal_mask(s, None)
    if window is None:
        return full
    local = causal_mask(s, window)
    if use_window is None:
        return local
    return jnp.where(use_window, local, full)


def _flag_decode_mask(cache_len: int, pos, window, use_window):
    full = decode_mask(cache_len, pos, None)
    if window is None:
        return full
    local = decode_mask(cache_len, pos, window)
    if use_window is None:
        return local
    return jnp.where(use_window, local, full)


def gqa_forward(p, x, cfg: ModelConfig, *, window=None, use_window=None, causal=True):
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    if flags.get("blockwise_attention") and s > 1:
        ctx = _mha_blockwise(q, k, v, cfg, causal=causal, window=window,
                             use_window=use_window)
    else:
        mask = _flag_mask(s, window, use_window) if causal else jnp.zeros((s, s), jnp.float32)
        ctx = _mha(q, k, v, mask, cfg)
    return linear(p["wo"], ctx)


def gqa_prefill(p, x, cfg: ModelConfig, cache_len: int, *, window=None, use_window=None,
                lengths=None):
    """Returns (y, (k_cache, v_cache)) with caches padded to cache_len.

    ``lengths`` (b,) marks each row's true prompt length in a right-padded
    ragged batch: keys at positions >= lengths[i] are masked out so pad
    tokens never leak into valid positions' attention, and pad K/V rows are
    zeroed before caching — decode's `k <= pos` mask hides them until the
    per-request decode positions overwrite them in order, and the deferred
    decode paths' cache-slot-at-pos-is-zero invariant keeps holding."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    if lengths is not None:
        valid = (jnp.arange(s)[None, :] < lengths[:, None])[..., None, None]
        k = jnp.where(valid, k, 0)
        v = jnp.where(valid, v, 0)
    if flags.get("blockwise_attention") and s > 1:
        ctx = _mha_blockwise(q, k, v, cfg, window=window, use_window=use_window,
                             lengths=lengths)
    else:
        mask = _flag_mask(s, window, use_window)
        if lengths is not None:
            mask = mask[None] + length_mask(lengths, s)[:, None, :]   # (b, s, s)
        ctx = _mha(q, k, v, mask, cfg)
    kvq = kv_quant_format(cfg)
    if kvq:
        pad = [(0, 0), (0, 0), (0, cache_len - s), (0, 0)]
        pad_s = [(0, 0), (0, 0), (0, cache_len - s)]
        kq, ks = _quantize_rows(k.transpose(0, 2, 1, 3), kvq)  # (b,KV,s,hd)/(b,KV,s)
        vq, vs = _quantize_rows(v.transpose(0, 2, 1, 3), kvq)
        return linear(p["wo"], ctx), (jnp.pad(kq, pad), jnp.pad(ks, pad_s),
                                      jnp.pad(vq, pad), jnp.pad(vs, pad_s))
    if flags.get("kvt_cache_layout"):
        pad = [(0, 0), (0, 0), (0, cache_len - s), (0, 0)]
        kc = jnp.pad(k.transpose(0, 2, 1, 3), pad)       # (b,KV,T,hd)
        vc = jnp.pad(v.transpose(0, 2, 1, 3), pad)
        return linear(p["wo"], ctx), (kc, vc)
    pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
    return linear(p["wo"], ctx), (jnp.pad(k, pad), jnp.pad(v, pad))


def gqa_decode(p, x, cache, pos, cfg: ModelConfig, *, window=None, use_window=None):
    """x: (b, d_model) single token; cache: (k, v) each (b, T, KV, hd);
    pos: scalar int32 or (b,) per-request positions. Returns (y, new_cache)."""
    k_cache, v_cache = cache
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    q, k, v = _qkv(p, x[:, None, :], cfg, _pos_rows(pos, b))
    k_cache = _commit_bt(k_cache, k, pos)
    v_cache = _commit_bt(v_cache, v, pos)
    mask = _bcast_decode_mask(_flag_decode_mask(k_cache.shape[1], pos, window, use_window))
    ctx = _mha(q, k_cache, v_cache, mask, cfg)                        # (b,1,q_dim)
    return linear(p["wo"], ctx[:, 0, :]), (k_cache, v_cache)


# KV-cache quantization storage dtypes (cfg.kv_quant / serve --kv-quant).
# One scale per (position, kv head) row, group = head_dim — the paper's
# group-wise symmetric scheme (Eq. 1) applied to the cache stream.
KV_STORE_DTYPES = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn}


def kv_quant_format(cfg: ModelConfig) -> str | None:
    """Active KV-cache quantization format for the GQA layouts: the
    engine-threaded ``cfg.kv_quant`` or the legacy int8_kv_cache flag."""
    kvq = cfg.kv_quant or ("int8" if flags.get("int8_kv_cache") else None)
    if kvq is not None and kvq not in KV_STORE_DTYPES:
        raise ValueError(
            f"unknown kv_quant format {kvq!r}; supported: "
            f"{sorted(KV_STORE_DTYPES)}")
    return kvq


def _quantize_rows(t: jax.Array, fmt: str = "int8"):
    """Symmetric quantization over the last axis (head_dim = one group),
    Eq. 1. t: (..., hd) -> (storage rows, f32 scales (...)). int8 rounds to
    the integer grid; fp8 casts onto the e4m3 float grid after normalizing
    the row absmax to FP8_MAX."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    if fmt == "fp8":
        scales = absmax / FP8_MAX
        safe = jnp.where(scales > 0, scales, 1.0)
        q = (t.astype(jnp.float32) / safe[..., None]).astype(jnp.float8_e4m3fn)
        return q, scales
    scales = absmax * (2.0 / 255.0)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / safe[..., None]), -127, 127)
    return q.astype(jnp.int8), scales


def gqa_decode_deferred_quant(p, x, cache, pos, cfg: ModelConfig, *, window=None,
                              use_window=None):
    """Quantized-KV-cache decode (paper's group-wise quantization applied to
    the cache, kvt layout, int8 or fp8 storage):
    scores = (q . k_q) * k_s; ctx = (attn * v_s) . v_q.
    The per-position scales factor out of the sums exactly like the GQMV
    group scales factor out of Alg. 1's group sums."""
    kq_c, ks_c, vq_c, vs_c = cache      # (b,KV,T,hd) int8, (b,KV,T) f32
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kv_heads = cfg.num_kv_heads
    h = cfg.num_heads
    g = h // kv_heads
    t = kq_c.shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x[:, None, :], cfg, _pos_rows(pos, b))

    tp = logical.size("tp")
    tp_t = t % tp == 0
    cspec = ("dp", None, "tp" if tp_t else None, None)
    kq_c = logical.constrain(kq_c, *cspec)
    vq_c = logical.constrain(vq_c, *cspec)
    qg = q.reshape(b, kv_heads, g, hd)
    scores = jnp.einsum("bkgh,bkth->bkgt", qg, kq_c.astype(x.dtype)).astype(jnp.float32)
    scores = scores * ks_c[:, :, None, :]
    cur = jnp.einsum("bkgh,bkh->bkg", qg, k_new[:, 0]).astype(jnp.float32)
    scores = _col_update(scores, cur, pos)
    scores = logical.constrain(scores, "dp", None, None, "tp" if tp_t else None)
    scores *= _gqa_scale(cfg)
    if cfg.attn_logit_softcap:
        scores = softcap(scores, cfg.attn_logit_softcap)
    dm = _flag_decode_mask(t, pos, window, use_window)
    scores = scores + (dm[None, None, None, :] if dm.ndim == 1 else dm[:, None, None, :])
    attn = jax.nn.softmax(scores, axis=-1)                    # f32 (b,kv,g,t)
    ctx = jnp.einsum("bkgt,bkth->bkgh",
                     (attn * vs_c[:, :, None, :]).astype(x.dtype),
                     vq_c.astype(x.dtype))
    attn_cur = _col_at(attn, pos)
    ctx = ctx + attn_cur.astype(x.dtype) * v_new[:, 0][:, :, None, :]
    ctx = ctx.reshape(b, h * hd)
    kvq = kv_quant_format(cfg) or "int8"
    kq_n, ks_n = _quantize_rows(k_new[:, 0], kvq)             # (b,kv,hd)/(b,kv)
    vq_n, vs_n = _quantize_rows(v_new[:, 0], kvq)
    rows = (kq_n[:, :, None, :], ks_n[:, :, None],
            vq_n[:, :, None, :], vs_n[:, :, None])
    return linear(p["wo"], ctx), rows


# Backwards-compat alias (the int8_kv_cache flag path predates cfg.kv_quant).
gqa_decode_deferred_int8 = gqa_decode_deferred_quant


def gqa_decode_deferred(p, x, cache, pos, cfg: ModelConfig, *, window=None,
                        use_window=None):
    """Decode WITHOUT writing the cache: attends over the read-only cache
    (whose slot at ``pos`` is still zero) plus the freshly-computed K/V row,
    and returns that row for the caller to commit with ONE donated
    dynamic-update-slice after the layer scan.

    The baseline path funnels the full per-layer cache through the scan's
    ys stack — a full cache read+write per step. This variant's per-layer
    cache traffic is the attention read only (hillclimb: decode cells).

    Supports both cache layouts: (b,T,KV,hd) baseline and (b,KV,T,hd)
    attention-native (flags.kvt_cache_layout — the dots then contract the
    trailing axis of both operands, no transpose materialization).
    """
    k_cache, v_cache = cache
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kv_heads = cfg.num_kv_heads
    kvt = bool(flags.get("kvt_cache_layout"))
    pos = jnp.asarray(pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x[:, None, :], cfg, _pos_rows(pos, b))  # (b,1,H/KV,hd)

    h = cfg.num_heads
    g = h // kv_heads
    t = k_cache.shape[2] if kvt else k_cache.shape[1]
    # batch-1: shard the cache length over the FULL mesh ("seq"); else model
    seq_sz = logical.size("seq")
    if b == 1 and seq_sz > 1 and t % seq_sz == 0:
        t_ax, b_ax, tp_t = "seq", None, True
    else:
        tp_t = t % logical.size("tp") == 0
        t_ax, b_ax = ("tp" if tp_t else None), "dp"
    cache_spec = (b_ax, None, t_ax, None) if kvt else (b_ax, t_ax, None, None)
    k_cache = logical.constrain(k_cache, *cache_spec)
    v_cache = logical.constrain(v_cache, *cache_spec)
    qg = q.reshape(b, kv_heads, g, hd)
    if kvt:
        scores = jnp.einsum("bkgh,bkth->bkgt", qg, k_cache).astype(jnp.float32)
    else:
        scores = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    cur = jnp.einsum("bkgh,bkh->bkg", qg, k_new[:, 0]).astype(jnp.float32)
    # overwrite the (zero-keyed) slot at pos with the current-token score
    scores = _col_update(scores, cur, pos)
    scores = logical.constrain(scores, b_ax, None, None, t_ax if tp_t else None)
    scores *= _gqa_scale(cfg)
    if cfg.attn_logit_softcap:
        scores = softcap(scores, cfg.attn_logit_softcap)
    mask = _flag_decode_mask(t, pos, window, use_window)
    scores = scores + (mask[None, None, None, :] if mask.ndim == 1 else mask[:, None, None, :])
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)   # (b,kv,g,t)
    # v_cache slot at pos is zero, so its contribution is exactly the
    # explicit current-token term below
    if kvt:
        ctx = jnp.einsum("bkgt,bkth->bkgh", attn, v_cache)
    else:
        ctx = jnp.einsum("bkgt,btkh->bkgh", attn, v_cache)
    attn_cur = _col_at(attn, pos)
    ctx = ctx + attn_cur * v_new[:, 0][:, :, None, :]   # (b,kv,g,1)x(b,kv,1,hd)
    ctx = ctx.reshape(b, h * hd)
    if kvt:
        rows = (k_new[:, 0][:, :, None, :], v_new[:, 0][:, :, None, :])  # (b,kv,1,hd)
    else:
        rows = (k_new, v_new)                                            # (b,1,kv,hd)
    return linear(p["wo"], ctx), rows


def _verify_mask(t: int, pos, k: int, window, use_window):
    """(b, k, t) additive mask for a k-token verify chunk starting at
    ``pos`` (b,): query j (virtual position pos+j) sees columns <= pos+j —
    exactly ``decode_mask`` row-by-row, so each chunk row reproduces the
    single-token decode step's mask arrangement bit-for-bit."""
    qpos = pos[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]     # (b, k)
    col = jnp.arange(t)[None, None, :]
    ok_full = col <= qpos[..., None]
    if window is None:
        ok = ok_full
    else:
        ok_local = ok_full & ((qpos[..., None] - col) < window)
        ok = ok_local if use_window is None else jnp.where(use_window, ok_local, ok_full)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_verify_deferred(p, x, cache, pos, cfg: ModelConfig, *, window=None,
                        use_window=None):
    """Speculative-verify attention: k chunk tokens x (b, k, d_model) attend
    over the read-only contiguous cache (slots >= pos still zero) plus the
    chunk's own K/V rows with an intra-chunk causal mask, WITHOUT writing
    the cache. Returns (y (b, k, q_dim), (k_rows, v_rows) (b, k, KV, hd))
    for the caller to commit the accepted prefix via commit_layers_verify.

    Row j of the chunk reproduces the arithmetic of the single-token decode
    step that would run after committing rows 0..j-1 (same score columns,
    same mask, same softmax arrangement), which is what makes greedy
    speculative decoding token-identical to vanilla decode."""
    k_cache, v_cache = cache
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    kv_heads = cfg.num_kv_heads
    g = cfg.num_heads // kv_heads
    t = k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if not pos.ndim:
        pos = jnp.full((b,), pos, jnp.int32)
    positions = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _qkv(p, x, cfg, positions)        # (b,s,H,hd)/(b,s,KV,hd)

    tp_t = t % max(logical.size("tp"), 1) == 0
    k_cache = logical.constrain(k_cache, "dp", "tp" if tp_t else None, None, None)
    v_cache = logical.constrain(v_cache, "dp", "tp" if tp_t else None, None, None)
    qg = q.reshape(b, s, kv_heads, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k_cache).astype(jnp.float32)
    cur = jnp.einsum("bskgh,bmkh->bkgsm", qg, k_new).astype(jnp.float32)
    mask = _verify_mask(t, pos, s, window, use_window)
    # the scatter/zero/explicit-chunk-V arrangement is shared with the
    # paged gather path so the two verify flavors cannot drift
    from repro.kernels import ref as _kref

    ctx = _kref.verify_attend(scores, cur, v_new, v_cache, pos, mask,
                              scale=_gqa_scale(cfg),
                              softcap=cfg.attn_logit_softcap or None)
    return linear(p["wo"], ctx), (k_new, v_new)


def gqa_verify_paged(p, x, pages, block_table, pos, cfg: ModelConfig, *,
                     window=None, use_window=None):
    """Paged speculative-verify attention: the chunk attends over the block
    pool through each row's block table (kernels/ops.py::paged_verify).
    Same contract as gqa_verify_deferred; rows are committed by the caller
    via commit_layers_paged_verify (rejected rows -> sink block)."""
    from repro.kernels import ops as _kops

    k_pages, v_pages = pages
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    kv_heads = cfg.num_kv_heads
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _qkv(p, x, cfg, positions)
    g = cfg.num_heads // kv_heads
    t = block_table.shape[1] * k_pages.shape[1]
    tp_kv = kv_heads % max(logical.size("tp"), 1) == 0
    pspec = (None, None, "tp" if tp_kv else None, None)
    k_pages = logical.constrain(k_pages, *pspec)
    v_pages = logical.constrain(v_pages, *pspec)
    qg = q.reshape(b, s, kv_heads, g, hd)
    mask = _verify_mask(t, pos, s, window, use_window)
    ctx = _kops.paged_verify(
        qg, k_pages, v_pages, block_table, pos, k_new, v_new, mask,
        scale=_gqa_scale(cfg), softcap=cfg.attn_logit_softcap or None,
    )
    ctx = logical.constrain(ctx, "dp", None, None)
    return linear(p["wo"], ctx), (k_new, v_new)


def gqa_decode_paged(p, x, pages, block_table, pos, cfg: ModelConfig, *,
                     window=None, use_window=None, scales=None):
    """Paged decode step: attention over the block pool through each row's
    block table (kernels/ops.py::paged_attention), current token handled
    explicitly so the pool is read-only here. x: (b, d_model); pages:
    (k_pages, v_pages) each (NB, BS, KV, hd); block_table (b, MB);
    pos (b,) int32 virtual positions. Returns (y, (k_new, v_new)) — the
    caller commits the rows with commit_layers_paged after the layer scan.

    With cfg.kv_quant the pool rows are int8/fp8 storage and ``scales`` is
    the (k_scales, v_scales) pool leaves (NB, BS, KV); dequantization is
    fused into the attention read and the returned rows are quantized —
    (k_q, k_s, v_q, v_s) — ready for the pool commit."""
    from repro.kernels import ops as _kops

    k_pages, v_pages = pages
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kv_heads = cfg.num_kv_heads
    pos = jnp.asarray(pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x[:, None, :], cfg, _pos_rows(pos, b))
    g = cfg.num_heads // kv_heads
    t = block_table.shape[1] * k_pages.shape[1]
    # pool sharding: kv heads -> model axis; the block axis is NEVER sharded
    # (blocks migrate between requests; dist/sharding.py `_pages` rule)
    tp_kv = kv_heads % max(logical.size("tp"), 1) == 0
    pspec = (None, None, "tp" if tp_kv else None, None)
    k_pages = logical.constrain(k_pages, *pspec)
    v_pages = logical.constrain(v_pages, *pspec)
    k_scales = v_scales = None
    if scales is not None:
        k_scales, v_scales = scales
        k_scales = logical.constrain(k_scales, *pspec[:-1])
        v_scales = logical.constrain(v_scales, *pspec[:-1])
    qg = q.reshape(b, kv_heads, g, hd)
    mask = _flag_decode_mask(t, pos, window, use_window)       # (b, t)
    ctx = _kops.paged_attention(
        qg, k_pages, v_pages, block_table, pos, k_new[:, 0], v_new[:, 0],
        mask, scale=_gqa_scale(cfg), softcap=cfg.attn_logit_softcap or None,
        k_scales=k_scales, v_scales=v_scales,
    )
    ctx = logical.constrain(ctx, "dp", None)
    kvq = cfg.kv_quant
    if kvq:
        kq, ks = _quantize_rows(k_new[:, 0], kvq)              # (b,KV,hd)/(b,KV)
        vq, vs = _quantize_rows(v_new[:, 0], kvq)
        return linear(p["wo"], ctx), (kq, ks, vq, vs)
    return linear(p["wo"], ctx), (k_new[:, 0], v_new[:, 0])


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    dt = cfg.pdtype()
    keys = jax.random.split(key, 5)
    h = cfg.num_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    p = {
        # fused latent-kv + rope-k projection (paper C4 fusion style)
        "wdkv": dense_init(keys[0], m.kv_lora_rank + m.qk_rope_dim, cfg.d_model, dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wukv": dense_init(keys[1], h * (m.qk_nope_dim + m.v_head_dim), m.kv_lora_rank, dt),
        "wo": dense_init(keys[2], cfg.d_model, h * m.v_head_dim, dt),
    }
    if m.q_lora_rank:
        p["wdq"] = dense_init(keys[3], m.q_lora_rank, cfg.d_model, dt)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dt)
        p["wuq"] = dense_init(keys[4], h * qk_dim, m.q_lora_rank, dt)
    else:
        p["wq"] = dense_init(keys[3], h * qk_dim, cfg.d_model, dt)
    return p


def _mla_q(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    if m.q_lora_rank:
        q = linear(p["wuq"], rmsnorm(linear(p["wdq"], x), p["q_norm"], cfg.norm_eps))
    else:
        q = linear(p["wq"], x)
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    c = linear(p["wdkv"], x)
    c_kv, k_rope = c[..., : m.kv_lora_rank], c[..., m.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _mla_scale(m) -> float:
    return (m.qk_nope_dim + m.qk_rope_dim) ** -0.5


def mla_forward(p, x, cfg: ModelConfig, *, window=None, lengths=None):
    """Naive (materialized) MLA for training/prefill. ``lengths`` (b,) masks
    right-pad keys per row (ragged prefill)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    kv = linear(p["wukv"], c_kv).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    tp = logical.size("tp")
    mode = "head" if h % tp == 0 else ("seq" if s % tp == 0 else "none")
    hspec = ("dp", None, "tp" if mode == "head" else None, None)
    q_nope = logical.constrain(q_nope, *hspec)
    k_nope = logical.constrain(k_nope, *hspec)
    v = logical.constrain(v, *hspec)
    scores = (
        jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * _mla_scale(m)
    sspec = {"head": ("dp", "tp", None, None), "seq": ("dp", None, "tp", None),
             "none": ("dp", None, None, None)}[mode]
    scores = logical.constrain(scores, *sspec)
    mask = causal_mask(s, window)
    if lengths is not None:
        mask = mask[None] + length_mask(lengths, s)[:, None, :]       # (b, s, s)
        mask = mask[:, None]                                          # (b, 1, s, s)
    scores = scores + mask
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    attn = logical.constrain(attn, *sspec)
    ctx = jnp.einsum("bhst,bthd->bshd", attn, v).reshape(b, s, h * m.v_head_dim)
    ctx = logical.constrain(ctx, "dp", None, "tp" if mode == "head" else None)
    return linear(p["wo"], ctx)


def mla_prefill(p, x, cfg: ModelConfig, cache_len: int, *, window=None, lengths=None):
    """Cache = (c_kv, k_rope): the low-rank latent (MLA's memory saving).
    ``lengths`` (b,): mask + zero right-pad latent rows (see gqa_prefill)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    y = mla_forward(p, x, cfg, window=window, lengths=lengths)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    if lengths is not None:
        valid = (jnp.arange(s)[None, :] < lengths[:, None])[..., None]
        c_kv = jnp.where(valid, c_kv, 0)
        k_rope = jnp.where(valid, k_rope, 0)
    pad = [(0, 0), (0, cache_len - s), (0, 0)]
    return y, (jnp.pad(c_kv, pad), jnp.pad(k_rope, pad))


def _maybe_dequant(w):
    return w.dequantize() if isinstance(w, QuantizedTensor) else w


def mla_decode_deferred(p, x, cache, pos, cfg: ModelConfig, *, window=None):
    """Absorbed MLA decode WITHOUT writing the latent cache: attends over the
    read-only cache (slot ``pos`` still zero) plus the current latent row and
    returns (c_new, r_new) for a single donated commit after the layer scan
    (same dataflow as gqa_decode_deferred)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    c_cache, r_cache = cache                        # (b,T,kvr) / (b,T,rope)
    t = c_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    positions = _pos_rows(pos, b)
    q_nope, q_rope = _mla_q(p, x[:, None, :], cfg, positions)
    c_new, r_new = _mla_latent(p, x[:, None, :], cfg, positions)   # (b,1,.)

    seq_sz = logical.size("seq")
    if b == 1 and seq_sz > 1 and t % seq_sz == 0:
        t_ax, b_ax = "seq", None
    else:
        t_ax = "tp" if t % max(logical.size("tp"), 1) == 0 else None
        b_ax = "dp"
    c_cache = logical.constrain(c_cache, b_ax, t_ax, None)
    r_cache = logical.constrain(r_cache, b_ax, t_ax, None)

    wukv = _maybe_dequant(p["wukv"]).reshape(h, m.qk_nope_dim + m.v_head_dim, m.kv_lora_rank)
    wuk, wuv = wukv[:, : m.qk_nope_dim, :], wukv[:, m.qk_nope_dim :, :]
    q_abs = jnp.einsum("bhd,hdc->bhc", q_nope[:, 0], wuk.astype(x.dtype))
    scores = (
        jnp.einsum("bhc,btc->bht", q_abs, c_cache)
        + jnp.einsum("bhd,btd->bht", q_rope[:, 0], r_cache)
    ).astype(jnp.float32)
    cur = (
        jnp.einsum("bhc,bc->bh", q_abs, c_new[:, 0])
        + jnp.einsum("bhd,bd->bh", q_rope[:, 0], r_new[:, 0])
    ).astype(jnp.float32)
    scores = _col_update(scores, cur, pos)
    scores = logical.constrain(scores, b_ax, None, t_ax)
    dm = decode_mask(t, pos, window)
    scores = scores * _mla_scale(m) + (dm[None, None, :] if dm.ndim == 1 else dm[:, None, :])
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    # cache slot at pos is zero -> its contribution is the explicit term
    ctx = jnp.einsum("bht,btc->bhc", attn, c_cache)
    attn_cur = _col_at(attn, pos)
    ctx = ctx + attn_cur * c_new[:, 0][:, None, :]
    out = jnp.einsum("bhc,hvc->bhv", ctx, wuv.astype(x.dtype)).reshape(b, h * m.v_head_dim)
    return linear(p["wo"], out), (c_new, r_new)


def mla_decode(p, x, cache, pos, cfg: ModelConfig, *, window=None):
    """Absorbed-matrix decode: attends directly over the latent cache without
    materializing per-position K/V (beyond-paper efficiency; the on-the-fly
    dequantization of wukv mirrors what the GQMV kernel does in VMEM)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    c_cache, r_cache = cache                       # (b,T,kvr), (b,T,rope)
    pos = jnp.asarray(pos, jnp.int32)
    positions = _pos_rows(pos, b)
    q_nope, q_rope = _mla_q(p, x[:, None, :], cfg, positions)
    c_kv, k_rope = _mla_latent(p, x[:, None, :], cfg, positions)
    c_cache = _commit_bt(c_cache, c_kv, pos)
    r_cache = _commit_bt(r_cache, k_rope, pos)

    wukv = _maybe_dequant(p["wukv"]).reshape(h, m.qk_nope_dim + m.v_head_dim, m.kv_lora_rank)
    wuk, wuv = wukv[:, : m.qk_nope_dim, :], wukv[:, m.qk_nope_dim :, :]
    c_cache = logical.constrain(c_cache, "dp", "tp", None)   # latent cache: seq-parallel
    r_cache = logical.constrain(r_cache, "dp", "tp", None)
    q_abs = jnp.einsum("bhd,hdc->bhc", q_nope[:, 0], wuk.astype(x.dtype))
    scores = (
        jnp.einsum("bhc,btc->bht", q_abs, c_cache)
        + jnp.einsum("bhd,btd->bht", q_rope[:, 0], r_cache)
    ).astype(jnp.float32) * _mla_scale(m)
    scores = logical.constrain(scores, "dp", None, "tp")
    dm = decode_mask(c_cache.shape[1], pos, window)
    scores = scores + (dm[None, None, :] if dm.ndim == 1 else dm[:, None, :])
    attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    attn = logical.constrain(attn, "dp", None, "tp")
    ctx = jnp.einsum("bht,btc->bhc", attn, c_cache)
    out = jnp.einsum("bhc,hvc->bhv", ctx, wuv.astype(x.dtype)).reshape(b, h * m.v_head_dim)
    return linear(p["wo"], out), (c_cache, r_cache)
