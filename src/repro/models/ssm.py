"""Mamba2 (SSD) blocks for the zamba2 hybrid.

Scalar-decay-per-head state-space recurrence:

    h_t = exp(-exp(A_log) * dt_t) * h_{t-1} + dt_t * (x_t outer B_t)
    y_t = h_t @ C_t + D * x_t

In/out projections (the large matrices) are quantizable; SSM scan parameters
(A_log, dt_bias, D) and the depthwise conv stay fp32 (paper's norm-exemption
class). Decode state is O(1): (conv tail, h) — zamba2 runs long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import flags
from repro.core.qlinear import linear, split_fused
from repro.dist import logical
from repro.models.common import dense_init, rmsnorm


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return d_inner, nheads, conv_ch


def init_mamba2(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    dt = cfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    in_dim = 2 * d_inner + 2 * s.state_dim + nheads  # z, x, B, C, dt (fused)
    return {
        "win": dense_init(k1, in_dim, cfg.d_model, dt),
        "conv_w": (jax.random.normal(k2, (s.conv_kernel, conv_ch), jnp.float32) * 0.1).astype(dt),
        "a_log": jnp.zeros((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "gate_norm": jnp.ones((d_inner,), dt),
        "wout": dense_init(k3, cfg.d_model, d_inner, dt),
    }


def _split_in(p, xin, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, _ = ssm_dims(cfg)
    return split_fused(linear(p["win"], xin), (d_inner, d_inner, s.state_dim, s.state_dim, nheads))


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv. x: (b, s, c); w: (k, c); tail: (b, k-1, c)."""
    k = w.shape[0]
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype) if tail is None else tail
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out), xp[:, -(k - 1) :, :]


def _ssd_step(h, inputs, a_neg):
    """h: (b, H, hd, N). xs: (b,H,hd); B,C: (b,N); dt: (b,H).

    The carry sharding is pinned every step: without it XLA reshards the
    state each scan iteration (measured: one collective-permute of the full
    state per (layer x time step) on zamba2 prefill_32k = 9.8 TB/device)."""
    xs, B, C, dtv = inputs
    h = logical.constrain(h, "dp", "tp", None, None)
    xs = logical.constrain(xs, "dp", "tp", None)
    decay = jnp.exp(a_neg[None, :] * dtv)                       # (b,H)
    dx = dtv[..., None] * xs                                    # (b,H,hd)
    h = h * decay[..., None, None] + jnp.einsum("bhd,bn->bhdn", dx, B)
    h = logical.constrain(h, "dp", "tp", None, None)
    y = jnp.einsum("bhdn,bn->bhd", h, C)
    return h, y


def _ssd_chunked(xs, Bv, Cv, dtv, a_neg, h0, chunk: int):
    """Mamba2's chunked SSD (matmul duality). xs: (b,s,H,hd); B,C: (b,s,N);
    dt: (b,s,H) (post-softplus, f32). Returns (y (b,s,H,hd), h_last).

    Per chunk of length Q (with P = inclusive cumsum of log-decay):
      intra:  y[t] += sum_{s<=t} exp(P_t - P_s) * dt_s * (C_t.B_s) * x_s
      inter:  y[t] += exp(P_t) * C_t . h_in
      carry:  h_out = exp(P_Q) h_in + sum_s exp(P_Q - P_s) dt_s x_s (x) B_s
    All contractions are MXU matmuls; the state is carried once per CHUNK,
    dividing its HBM round-trips by Q vs the per-step recurrence."""
    b, s, H, hd = xs.shape
    n = Bv.shape[-1]
    nchunks = s // chunk

    def ck(t):  # (b, s, ...) -> (b, nchunks, chunk, ...)
        return t.reshape(b, nchunks, chunk, *t.shape[2:])

    xs_c, B_c, C_c, dt_c = ck(xs), ck(Bv), ck(Cv), ck(dtv)

    def body(h, inputs):
        xq, Bq, Cq, dtq = inputs                   # (b,Q,H,hd)/(b,Q,N)/(b,Q,H)
        h = logical.constrain(h, "dp", "tp", None, None)
        la = a_neg[None, None, :] * dtq            # (b,Q,H) log-decay, <= 0
        P = jnp.cumsum(la, axis=1)                 # inclusive
        G = jnp.einsum("btn,bsn->bts", Cq, Bq)     # (b,Q,Q)
        W = jnp.exp(P[:, :, None, :] - P[:, None, :, :]) * dtq[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        M = jnp.where(tri[None, :, :, None], G[..., None] * W, 0.0)
        y = jnp.einsum("btsh,bshd->bthd", M, xq)                 # intra
        y = y + jnp.exp(P)[..., None] * jnp.einsum("bhdn,btn->bthd", h, Cq)
        wfull = jnp.exp(P[:, -1:, :] - P) * dtq                  # (b,Q,H)
        h = jnp.exp(P[:, -1, :])[:, :, None, None] * h + jnp.einsum(
            "bsh,bshd,bsn->bhdn", wfull, xq, Bq
        )
        h = logical.constrain(h, "dp", "tp", None, None)
        return h, y

    seq = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), (xs_c, B_c, C_c, dt_c))
    h_last, ys = jax.lax.scan(body, h0, seq)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, H, hd)
    return y, h_last


def mamba2_forward(p, x, cfg: ModelConfig, state=None):
    """x: (b, s, d). Returns (y, (conv_tail, h_last))."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    z, xc, Bv, Cv, dtv = _split_in(p, x, cfg)
    conv_in = jnp.concatenate([xc, Bv, Cv], axis=-1)
    conv_tail_in = None if state is None else state[0]
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"].astype(x.dtype), conv_tail_in)
    xc, Bv, Cv = split_fused(conv_out, (d_inner, s_cfg.state_dim, s_cfg.state_dim))

    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])            # (b,s,H)
    xs = xc.reshape(b, s, nheads, s_cfg.head_dim).astype(jnp.float32)
    xs = logical.constrain(xs, "dp", None, "tp", None)
    a_neg = -jnp.exp(p["a_log"])

    h0 = (
        jnp.zeros((b, nheads, s_cfg.head_dim, s_cfg.state_dim), jnp.float32)
        if state is None
        else state[1]
    )
    chunk = int(flags.get("ssd_chunk"))
    if flags.get("chunked_ssd") and s % chunk == 0 and s > chunk:
        y, h_last = _ssd_chunked(
            xs, Bv.astype(jnp.float32), Cv.astype(jnp.float32), dtv,
            a_neg, h0, chunk,
        )
    else:
        seq = jax.tree.map(
            lambda t: jnp.moveaxis(t.astype(jnp.float32), 1, 0), (xs, Bv, Cv, dtv)
        )
        h_last, ys = jax.lax.scan(lambda c, i: _ssd_step(c, i, a_neg), h0, seq)
        y = jnp.moveaxis(ys, 0, 1)                               # (b,s,H,hd)
    y = logical.constrain(y, "dp", None, "tp", None)
    y = y + p["d_skip"][None, None, :, None] * xs
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return linear(p["wout"], y), (conv_tail, h_last)


def mamba2_decode(p, x, state, cfg: ModelConfig):
    """x: (b, d) one token; state: (conv_tail (b,k-1,c), h (b,H,hd,N))."""
    s_cfg = cfg.ssm
    b, d = x.shape
    d_inner, nheads, conv_ch = ssm_dims(cfg)
    conv_tail, h = state
    z, xc, Bv, Cv, dtv = _split_in(p, x[:, None, :], cfg)
    conv_in = jnp.concatenate([xc, Bv, Cv], axis=-1)
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"].astype(x.dtype), conv_tail)
    xc, Bv, Cv = split_fused(conv_out[:, 0, :], (d_inner, s_cfg.state_dim, s_cfg.state_dim))

    dt1 = jax.nn.softplus(dtv[:, 0, :].astype(jnp.float32) + p["dt_bias"])   # (b,H)
    xs = xc.reshape(b, nheads, s_cfg.head_dim).astype(jnp.float32)
    a_neg = -jnp.exp(p["a_log"])
    h, y = _ssd_step(h, (xs, Bv.astype(jnp.float32), Cv.astype(jnp.float32), dt1), a_neg)
    y = y + p["d_skip"][None, :, None] * xs
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z[:, 0, :]), p["gate_norm"], cfg.norm_eps)
    return linear(p["wout"], y), (conv_tail, h)
