"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Structure per layer: time-mixing (WKV6 recurrence) + channel-mixing (gated
FFN), both with token-shift. The large mixing matrices (R/K/V/G/O, FFN) are
quantizable (out, in) weights — the paper's GQMV applies unchanged; the tiny
data-dependent decay LoRA and token-shift mixes stay fp32 (same exemption
class as the paper's RMSNorm weights).

State per layer (decode): x_prev for both mixers + per-head (hd x hd) WKV
matrix — O(1) in sequence length, which is why rwkv6 runs the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import embedding_lookup, linear
from repro.dist import logical
from repro.models.common import dense_init, embed_init, rmsnorm

DECAY_LORA_RANK = 64


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.resolved_head_dim
    return cfg.d_model // hd, hd


def init_rwkv_layer(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = _heads(cfg)
    dt = cfg.pdtype()
    ks = jax.random.split(key, 10)
    return {
        "att_norm": jnp.ones((d,), dt),
        "mix_r": jnp.full((d,), 0.5, dt),
        "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt),
        "mix_g": jnp.full((d,), 0.5, dt),
        "mix_w": jnp.full((d,), 0.5, dt),
        "decay_w0": jnp.full((d,), -6.0, dt),
        "decay_lora_a": dense_init(ks[0], DECAY_LORA_RANK, d, dt),
        "decay_lora_b": dense_init(ks[1], d, DECAY_LORA_RANK, dt),
        "bonus_u": (jax.random.normal(ks[2], (h, hd), jnp.float32) * 0.1).astype(dt),
        "wr": dense_init(ks[3], d, d, dt),
        "wk": dense_init(ks[4], d, d, dt),
        "wv": dense_init(ks[5], d, d, dt),
        "wg": dense_init(ks[6], d, d, dt),
        "wout": dense_init(ks[7], d, d, dt),
        "ffn_norm": jnp.ones((d,), dt),
        "mix_ffn": jnp.full((d,), 0.5, dt),
        "wffr": dense_init(ks[8], d, d, dt),
        "wff1": dense_init(ks[9], f, d, dt),
        "wff2": dense_init(jax.random.fold_in(key, 99), d, f, dt),
    }


def _token_shift(x, x_prev_first):
    """x: (b, s, d). Shift right by one; position 0 sees x_prev_first."""
    shifted = jnp.roll(x, 1, axis=1)
    return shifted.at[:, 0, :].set(x_prev_first)


def _ddlerp(x, shifted, mix):
    return x + (shifted - x) * mix


def _decay(p, xw):
    """Data-dependent per-channel decay in (0, 1): w = exp(-exp(w0 + lora))."""
    lora = linear(p["decay_lora_b"], jnp.tanh(linear(p["decay_lora_a"], xw)))
    return jnp.exp(-jnp.exp((p["decay_w0"] + lora).astype(jnp.float32)))


def _wkv_step(state, inputs, u):
    """One WKV6 step. state: (b,h,hd,hd) [k-dim x v-dim];
    r,k,v: (b,h,hd); w: (b,h,hd) decay on the k dimension.

    Carry sharding pinned per step (same scan-resharding hazard as the
    Mamba2 state — see models/ssm.py:_ssd_step)."""
    r, k, v, w = inputs
    state = logical.constrain(state, "dp", "tp", None, None)
    a = jnp.einsum("bhi,bhj->bhij", k, v)                 # outer product
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * a)
    state = w[..., None] * state + a
    state = logical.constrain(state, "dp", "tp", None, None)
    return state, y


def time_mix_forward(p, x, cfg: ModelConfig, state=None):
    """Full-sequence WKV6 via lax.scan over time.

    Returns (y, (x_last, wkv_state)) so the same code serves training
    (state ignored) and prefill (state kept for decode).
    """
    b, s, d = x.shape
    h, hd = _heads(cfg)
    if state is None:
        x_first = jnp.zeros((b, d), x.dtype)
        wkv0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    else:
        x_first, wkv0 = state

    shifted = _token_shift(x, x_first)
    hspec = ("dp", None, "tp", None)
    r = logical.constrain(linear(p["wr"], _ddlerp(x, shifted, p["mix_r"])).reshape(b, s, h, hd), *hspec)
    k = logical.constrain(linear(p["wk"], _ddlerp(x, shifted, p["mix_k"])).reshape(b, s, h, hd), *hspec)
    v = logical.constrain(linear(p["wv"], _ddlerp(x, shifted, p["mix_v"])).reshape(b, s, h, hd), *hspec)
    g = logical.constrain(linear(p["wg"], _ddlerp(x, shifted, p["mix_g"])), "dp", None, "tp")
    w = logical.constrain(_decay(p, _ddlerp(x, shifted, p["mix_w"])).reshape(b, s, h, hd), *hspec)

    u = p["bonus_u"].astype(jnp.float32)
    seq_inputs = jax.tree.map(
        lambda t: jnp.moveaxis(t.astype(jnp.float32), 1, 0), (r, k, v, w)
    )
    wkv_last, ys = jax.lax.scan(lambda c, i: _wkv_step(c, i, u), wkv0, seq_inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(y.reshape(b, s, h, hd), jnp.ones((hd,), x.dtype), cfg.norm_eps).reshape(b, s, d)
    out = linear(p["wout"], y * jax.nn.silu(g))
    return out, (x[:, -1, :], wkv_last)


def time_mix_decode(p, x, state, cfg: ModelConfig):
    """x: (b, d) one token; state: (x_prev, wkv (b,h,hd,hd))."""
    b, d = x.shape
    h, hd = _heads(cfg)
    x_prev, wkv = state
    r = linear(p["wr"], _ddlerp(x, x_prev, p["mix_r"])).reshape(b, h, hd)
    k = linear(p["wk"], _ddlerp(x, x_prev, p["mix_k"])).reshape(b, h, hd)
    v = linear(p["wv"], _ddlerp(x, x_prev, p["mix_v"])).reshape(b, h, hd)
    g = linear(p["wg"], _ddlerp(x, x_prev, p["mix_g"]))
    w = _decay(p, _ddlerp(x, x_prev, p["mix_w"])).reshape(b, h, hd)
    u = p["bonus_u"].astype(jnp.float32)
    wkv, y = _wkv_step(
        wkv, (r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w), u
    )
    y = y.reshape(b, h, hd)
    y = rmsnorm(y, jnp.ones((hd,), x.dtype), cfg.norm_eps).reshape(b, d).astype(x.dtype)
    return linear(p["wout"], y * jax.nn.silu(g)), (x, wkv)


def channel_mix_forward(p, x, state=None):
    b = x.shape[0]
    x_first = jnp.zeros((b, x.shape[-1]), x.dtype) if state is None else state
    shifted = _token_shift(x, x_first)
    xm = _ddlerp(x, shifted, p["mix_ffn"])
    kk = jnp.square(jax.nn.relu(linear(p["wff1"], xm)))
    kk = logical.constrain(kk, *(["dp"] + [None] * (kk.ndim - 2) + ["tp"]))
    out = jax.nn.sigmoid(linear(p["wffr"], xm)) * linear(p["wff2"], kk)
    return out, x[:, -1, :]


def channel_mix_decode(p, x, x_prev):
    xm = _ddlerp(x, x_prev, p["mix_ffn"])
    kk = jnp.square(jax.nn.relu(linear(p["wff1"], xm)))
    return jax.nn.sigmoid(linear(p["wffr"], xm)) * linear(p["wff2"], kk), x


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg: ModelConfig) -> dict:
    ke, kl, kc = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    return {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, cfg.pdtype()),
        "layers": jax.vmap(lambda k: init_rwkv_layer(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype()),
        "classifier": dense_init(kc, cfg.vocab_padded, cfg.d_model, cfg.pdtype()),
    }


def rwkv_forward(params, tokens, cfg: ModelConfig):
    """tokens (b, s) -> logits (b, s, vocab_padded)."""
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())

    def body(x, lp):
        att, _ = time_mix_forward(lp, rmsnorm(x, lp["att_norm"], cfg.norm_eps), cfg)
        x = x + att
        ffn, _ = channel_mix_forward(lp, rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
        return x + ffn, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x)


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype):
    h, hd = _heads(cfg)
    return {
        "att_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((cfg.num_layers, batch, h, hd, hd), jnp.float32),
        "ffn_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dtype),
    }


def rwkv_insert_slots(state, rows, slots):
    """Scatter per-request prefill ``rows`` into decode ``slots`` of a
    batched recurrent state: every leaf is (layers, b, ...), so continuous
    batching for rwkv6 is a single axis-1 state scatter — O(1) per slot, no
    KV rows, no paging (serving/core.py RecurrentAdapter)."""
    return jax.tree.map(
        lambda big, small: big.at[:, slots].set(small), state, rows
    )


def rwkv_gather_slots(state, slots):
    """Inverse of ``rwkv_insert_slots``: the per-slot state for ``slots``."""
    return jax.tree.map(lambda big: big[:, slots], state)


def rwkv_prefill(params, tokens, cfg: ModelConfig, cache_len: int):
    """Run the prompt, returning last-token logits + decode state.
    cache_len is unused (state is O(1)) but kept for interface parity."""
    x = embedding_lookup(params["embed"], tokens, cfg.cdtype())

    def body(x, lp):
        att, (ax, wkv) = time_mix_forward(lp, rmsnorm(x, lp["att_norm"], cfg.norm_eps), cfg)
        x = x + att
        ffn, fx = channel_mix_forward(lp, rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
        return x + ffn, {"att_x": ax, "wkv": wkv, "ffn_x": fx}

    x, state = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x[:, -1, :], params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x), state


def rwkv_decode(params, token, state, pos, cfg: ModelConfig):
    """token (b,) int32 -> (logits (b, vocab), new state). pos unused
    (state carries all positional information)."""
    x = embedding_lookup(params["embed"], token, cfg.cdtype())

    def body(x, scanned):
        lp, st = scanned
        att, (ax, wkv) = time_mix_decode(
            lp, rmsnorm(x, lp["att_norm"], cfg.norm_eps), (st["att_x"], st["wkv"]), cfg
        )
        x = x + att
        ffn, fx = channel_mix_decode(
            lp, rmsnorm(x, lp["ffn_norm"], cfg.norm_eps), st["ffn_x"]
        )
        return x + ffn, {"att_x": ax, "wkv": wkv, "ffn_x": fx}

    x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return linear(params["classifier"], x), new_state
