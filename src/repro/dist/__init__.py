"""Distribution layer: logical axes + path-keyed placement policy.

Two sub-modules, deliberately small and dependency-free so every model
family (transformer/GQA, MLA, MoE, SSM, RWKV, enc-dec) can import them
without touching device state:

  logical   logical axis names ("dp"/"tp"/"seq") bound to physical mesh
            axes by a context manager; ``constrain`` pins activation
            shardings inside jit and degrades to a no-op off-mesh.
  sharding  parameter/cache/batch PartitionSpec policy keyed on pytree
            paths — the elasticity contract (ft/elastic.py) is that rules
            name AXES, never device counts.
"""

from repro.dist import logical, sharding

__all__ = ["logical", "sharding"]
