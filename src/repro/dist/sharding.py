"""Placement policy: pytree paths + shapes -> PartitionSpecs.

The rules are written against mesh AXIS NAMES ("data", "model", optional
leading "pod"), never device counts — the elasticity contract that lets one
set of pjit programs revalidate on any surviving mesh (ft/elastic.py).

Parameter rules (Megatron-style, path-keyed):

  column-parallel (wqkv, w13, wq, ...; (..., out, in))
        out -> model; in -> data (FSDP, TRAIN ONLY — serving keeps weights
        fully materialized along the contraction so GQMV shards stay local)
  row-parallel (wo, w2, wout, wff2)
        in -> model; out -> data (train-only FSDP)
  MoE experts (path contains "experts"; (..., E, out, in))
        E -> model (expert parallel); the within-expert contraction is NEVER
        sharded so quantization groups stay whole; FSDP (data) still applies
  quantized leaves (qvalues / scales under a weight)
        qvalues inherit the parent weight's rule unchanged; scales inherit
        it except the trailing GROUP axis, which follows "model" only when
        the parent contraction does (row-parallel serve) and never takes
        FSDP — the LlamaF invariant that a quantization group is never split
        across shards (core/policy.py sizes groups to n/tp for this reason).
        PACKED formats (int4: two nibbles/byte, core/quant.py registry)
        shard qvalues on the PACKED dim: the rules are pure divisibility on
        the storage shape, and since a leaf's group size divides n/tp and is
        a multiple of the pack factor, every shard chunk of n/(pack*tp)
        storage elements holds whole groups — validate_quant_partition
        checks the invariant for an assembled (params, mesh) pair
  embed: vocab -> model, d_model -> data (train only); norms, routers,
  SSM scan params, conv kernels, token-shift mixes, biases: replicated.

Any assignment whose axis size does not divide the dimension degrades to
None (unsharded) instead of erroring, so reduced/CPU configs and odd dims
run everywhere.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.treepath import path_str

DATA_AXIS = "data"
MODEL_AXIS = "model"

# Weights whose CONTRACTION (trailing) axis is model-sharded when serving;
# shared with the quantization group-size policy (core/policy.py).
ROW_PARALLEL = ("wo", "w2", "wout", "wff2")

# Leaf-name fragments that are always replicated (norms + the paper's
# "small/accuracy-critical" exemption class; mirrors policy.EXCLUDE_PATTERNS).
REPLICATED = ("norm", "router", "a_log", "dt_bias", "d_skip", "conv",
              "decay", "bonus", "mix", "bias", "lora")

QUANT_LEAVES = ("qvalues", "scales")


def _sizes(mesh) -> dict[str, int]:
    return {a: int(mesh.shape[a]) for a in mesh.axis_names}


def _fit(dim: int, axis: str | None, sizes: dict[str, int]) -> str | None:
    """axis if it exists, is >1-way, and divides dim; else None."""
    if axis is None:
        return None
    n = sizes.get(axis, 1)
    return axis if n > 1 and dim % n == 0 else None


def dp_axes(mesh) -> tuple[str, ...]:
    """All data-parallel-like axes (everything except the model axis)."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def _dp_size(mesh) -> int:
    s = _sizes(mesh)
    return int(math.prod(s[a] for a in dp_axes(mesh)))


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_spec(path: str, shape, *, mesh, mode: str = "train") -> P:
    """PartitionSpec for one parameter leaf.

    ``path`` is the '/'-joined pytree path (core/treepath.py), ``shape`` the
    leaf shape, ``mode`` "train" (adds FSDP over the data axis) or "serve".
    """
    sizes = _sizes(mesh)
    parts = [p for p in str(path).split("/") if p]
    leaf = parts[-1].lower() if parts else ""
    quant_leaf = leaf if leaf in QUANT_LEAVES else None
    name = (parts[-2].lower() if len(parts) >= 2 else "") if quant_leaf else leaf
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    train = mode == "train"

    if ndim < 2 or any(pat in name for pat in REPLICATED):
        return P(*spec)

    if name == "embed":
        # (vocab, d_model): vocab -> model; embedding dim -> train-only FSDP.
        spec[-2] = _fit(shape[-2], MODEL_AXIS, sizes)
        if quant_leaf != "scales":  # group axis of a quantized embed: whole
            spec[-1] = _fit(shape[-1], DATA_AXIS if train else None, sizes)
        return P(*spec)

    if name in ROW_PARALLEL:
        out_ax: str | None = DATA_AXIS if train else None
        in_ax: str | None = MODEL_AXIS
    else:  # column-parallel default for every large (..., out, in) matrix
        out_ax = MODEL_AXIS
        in_ax = DATA_AXIS if train else None

    if "experts" in parts:
        # Expert-parallel: the stacked E axis (just before out/in) takes the
        # model axis; the per-expert matmul axes must not reuse it, and the
        # within-expert contraction stays whole (groups never split).
        out_ax = None if out_ax == MODEL_AXIS else out_ax
        in_ax = None if in_ax == MODEL_AXIS else in_ax
        if ndim >= 3:
            spec[ndim - 3] = _fit(shape[ndim - 3], MODEL_AXIS, sizes)

    spec[-2] = _fit(shape[-2], out_ax, sizes)
    if quant_leaf == "scales":
        # Trailing axis is the GROUP axis: model-follow only (no FSDP).
        spec[-1] = _fit(shape[-1], in_ax if in_ax == MODEL_AXIS else None, sizes)
    else:
        spec[-1] = _fit(shape[-1], in_ax, sizes)
    return P(*spec)


def validate_quant_partition(params, mesh, mode: str = "serve") -> None:
    """Assert the group-never-straddles invariant for quantized leaves.

    For every QuantizedTensor in ``params``, any sharding of the trailing
    (storage/packed) qvalues axis must leave each shard with a whole number
    of quantization groups — group_size // pack * pack_storage STORAGE
    elements per group (int4: GS/2 bytes, int3: 3*GS/8 bytes).
    The PTQ policy guarantees this by construction (per-leaf group sizes
    divide n/tp); this check catches drift between policy and placement,
    e.g. a new packed format or a hand-built mesh that breaks the geometry.
    """
    from repro.core.quant import QuantizedTensor, get_format  # no import cycle

    sizes = _sizes(mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )
    for path, leaf in flat:
        if not isinstance(leaf, QuantizedTensor):
            continue
        p = path_str(path)
        spec = param_spec(f"{p}/qvalues", leaf.qvalues.shape, mesh=mesh, mode=mode)
        last = spec[-1] if len(spec) else None
        if last is None:
            continue
        axes = last if isinstance(last, tuple) else (last,)
        ways = int(math.prod(sizes.get(a, 1) for a in axes))
        fmt = get_format(leaf.fmt)
        per_group = leaf.group_size // fmt.pack * fmt.pack_storage
        dim = leaf.qvalues.shape[-1]
        if ways > 1 and (dim // ways) % per_group:
            raise ValueError(
                f"{p}: {ways}-way sharding of the packed qvalues axis "
                f"({dim} storage elements) splits quantization groups of "
                f"{per_group} storage elements ({leaf.fmt}, GS={leaf.group_size})"
            )


def param_specs(params, mesh, mode: str = "train"):
    """param_spec over a whole parameter pytree (QuantizedTensor leaves
    descend to their qvalues/scales children via the keyed pytree paths)."""

    def one(path, leaf):
        return param_spec(path_str(path), leaf.shape, mesh=mesh, mode=mode)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# caches / batches / outputs
# ---------------------------------------------------------------------------

def cache_spec(name: str, shape, *, mesh, batch: int) -> P:
    """KV/state-cache placement: batch -> data, the axis after it (sequence
    for KV caches, heads for RWKV/SSM states) -> model. The batch-1
    long-context case spreads the sequence over the FULL mesh instead —
    there is no batch to shard, and a 512k cache is the dominant tensor.
    ``name`` is the leaf name or its full ``/``-joined pytree path (as
    produced by :func:`cache_specs`). ``*_pages`` leaves are the paged block
    pool (L, NB, BS, KV, hd) — kv heads -> model, and the BLOCK axis is
    NEVER sharded (blocks migrate between requests through the block tables;
    splitting the pool would turn every table lookup into a cross-shard
    gather and every block free/alloc into a resharding event). Leaves under
    a ``mamba`` subtree are zamba's double-stacked SSM states
    (groups, per_group, batch, ...): the batch is PINNED to axis 2 — the
    value search below cannot tell per_group from batch when they collide,
    which is exactly the slot-state serving case (per-slot rows gathered and
    scattered on that axis must stay on their data shard)."""
    sizes = _sizes(mesh)
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    if name.endswith("_pages") or name.endswith("_scales"):
        # Quantized-pool scale leaves (L, NB, BS, KV) follow their pages:
        # kv heads -> model, block axis never sharded. Without this rule the
        # batch search below could hand the BLOCK axis to the data axis.
        if ndim >= 2:
            idx = -2 if name.endswith("_pages") else -1
            spec[idx] = _fit(shape[idx], MODEL_AXIS, sizes)
        return P(*spec)
    parents = name.split("/")[:-1]
    # Locate the batch dim. Every cache leaf leads with at least one stack
    # axis (layers or layer-groups), so the search starts at index 1 — a
    # leading L equal to the batch size must not be mistaken for the batch.
    if "mamba" in parents and ndim >= 4:
        b_idx = 2
    elif ndim >= 3:
        search = range(1, max(2, ndim - 2))
        b_idx = next((i for i in search if shape[i] == batch), 1)
    else:
        b_idx = 0 if ndim and shape[0] == batch else min(1, ndim - 1)
    if batch > 1:
        spec[b_idx] = _fit(batch, DATA_AXIS, sizes)
    seq_idx = b_idx + 1
    if seq_idx < ndim:
        d = shape[seq_idx]
        full = int(math.prod(sizes.values()))
        if batch == 1 and full > 1 and d % full == 0 and len(sizes) > 1:
            spec[seq_idx] = tuple(mesh.axis_names)
        else:
            spec[seq_idx] = _fit(d, MODEL_AXIS, sizes)
    return P(*spec)


def cache_specs(cache, mesh, batch: int):
    """cache_spec over a cache pytree keyed by each leaf's full path, so
    path-dependent layouts (zamba's ``mamba/*`` double-stacked states)
    resolve their batch axis correctly."""

    def one(path, leaf):
        return cache_spec(path_str(path), leaf.shape, mesh=mesh, batch=batch)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs(batch, mesh):
    """Data-parallel input batches: leading axis over every non-model axis
    when it divides evenly, else fully replicated (divisibility-checked so
    odd eval batches never error)."""
    dp = dp_axes(mesh)
    dp_sz = _dp_size(mesh)

    def one(leaf):
        shape = leaf.shape
        if shape and dp and dp_sz > 1 and shape[0] % dp_sz == 0:
            return P(dp, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree.map(one, batch)


def logits_spec(mesh, ndim: int, batch: int) -> P:
    """Output logits: batch -> dp axes (when divisible), vocab -> model.
    The vocab axis is vocab_padded (multiple of 32) so it shards evenly on
    the production meshes; XLA pads gracefully if it ever does not."""
    sizes = _sizes(mesh)
    dp = dp_axes(mesh)
    dp_sz = _dp_size(mesh)
    first = dp if (dp and dp_sz > 1 and batch % dp_sz == 0) else None
    last = MODEL_AXIS if sizes.get(MODEL_AXIS, 1) > 1 else None
    return P(first, *([None] * (ndim - 2)), last)


def verify_logits_spec(mesh, batch: int) -> P:
    """Speculative-verify logits (b, k, vocab): batch -> dp, vocab -> model,
    the chunk axis replicated — the k verify positions of one request live
    on one data shard (the accept/reject scan over them is sequential), so
    splitting k would only add collectives to a length-<=8 axis."""
    return logits_spec(mesh, 3, batch)


def shardings(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
