"""Logical sharding axes for model code.

Model code annotates activations with LOGICAL names — "dp" (data/batch),
"tp" (tensor/model), "seq" (sequence spread over the whole mesh for the
batch-1 long-context decode path) — and this module binds them to whatever
physical mesh is active:

    with mesh, logical.use_mesh_rules(mesh):
        step = jax.jit(...)

Outside ``use_mesh_rules`` (CPU smoke tests, single-process examples)
``size()`` returns 1 and ``constrain`` is the identity, so every model runs
unsharded with zero code changes. Inside a mesh, ``constrain`` drops any
axis whose size does not divide the corresponding dimension instead of
erroring — the same degrade-don't-fail contract as sharding.param_spec.
"""

from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"

_ACTIVE: "_Rules | None" = None


class _Rules:
    """Logical-name -> physical-axes binding for one mesh."""

    def __init__(self, mesh):
        self.mesh = mesh
        names = tuple(mesh.axis_names)
        dp = tuple(a for a in names if a != MODEL_AXIS)
        tp = (MODEL_AXIS,) if MODEL_AXIS in names else ()
        # "seq" spreads one dimension over the FULL mesh (batch-1 decode).
        self.axes = {"dp": dp, "tp": tp, "seq": dp + tp}

    def size(self, name: str) -> int:
        return int(math.prod(self.mesh.shape[a] for a in self.axes.get(name, ())))


@contextlib.contextmanager
def use_mesh_rules(mesh):
    """Bind logical names to ``mesh`` for the enclosed scope (re-entrant)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _Rules(mesh)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def active_mesh():
    """The mesh bound by the innermost ``use_mesh_rules``, or None."""
    return _ACTIVE.mesh if _ACTIVE is not None else None


def size(name: str) -> int:
    """Total device count behind logical axis ``name`` (1 when off-mesh)."""
    return _ACTIVE.size(name) if _ACTIVE is not None else 1


def spec(shape, *axes) -> P:
    """Resolve logical ``axes`` against the active rules for ``shape``.

    Each entry is a logical name or None. An axis is dropped (-> None) when
    no rules are active, the name is unknown, its size is 1, it does not
    divide the dimension, or its physical axes were already consumed by an
    earlier dimension (a mesh axis may shard at most one dim).
    """
    if _ACTIVE is None:
        return P(*([None] * len(shape)))
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        phys = _ACTIVE.axes.get(ax, ()) if ax else ()
        sz = math.prod(_ACTIVE.mesh.shape[a] for a in phys) if phys else 1
        if not phys or sz <= 1 or dim % sz or any(a in used for a in phys):
            out.append(None)
            continue
        used.update(phys)
        out.append(phys[0] if len(phys) == 1 else phys)
    out += [None] * (len(shape) - len(out))
    return P(*out)


def constrain(x, *axes):
    """``with_sharding_constraint`` keyed on logical axis names.

    Identity when no mesh rules are active; otherwise pins ``x`` to the
    resolved PartitionSpec (see ``spec`` for the drop rules). ``axes`` may
    be shorter than ``x.ndim``; missing trailing entries mean unsharded.
    """
    if _ACTIVE is None:
        return x
    if len(axes) > x.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{x.ndim} value")
    s = spec(x.shape, *axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_ACTIVE.mesh, s))
