"""Canonical pytree path -> string conversion shared by policy/ckpt/sharding."""

from __future__ import annotations

from jax.tree_util import DictKey, FlattenedIndexKey, GetAttrKey, SequenceKey


def key_str(entry) -> str:
    if isinstance(entry, DictKey):
        return str(entry.key)
    if isinstance(entry, GetAttrKey):
        return str(entry.name)
    if isinstance(entry, SequenceKey):
        return str(entry.idx)
    if isinstance(entry, FlattenedIndexKey):
        return str(entry.key)
    return str(entry)


def path_str(path) -> str:
    return "/".join(key_str(p) for p in path)
