"""Perf-variant feature flags (hillclimbing switches).

Every optimization beyond the paper-faithful baseline sits behind a flag so
§Perf can lower/compile both variants of the same cell:

  deferred_decode_cache  decode steps return only the new K/V rows from the
                         layer scan; one donated dynamic-update-slice commits
                         them after the scan (kills the per-layer full-cache
                         copy the scan-ys baseline dataflow implies)
  blockwise_attention    chunked online-softmax attention (flash-style) for
                         train/prefill: O(chunk) score buffers instead of the
                         full (b, heads, s, t) materialization. TPU deployment
                         uses the Pallas kernel (kernels/flash_attn.py); the
                         XLA fallback here is its math-identical reference.
"""

from __future__ import annotations

import contextlib

FLAGS: dict[str, bool | int] = {
    "deferred_decode_cache": False,
    "blockwise_attention": False,
    "attention_chunk": 1024,
    # KV cache stored (L,B,KV,T,hd) so decode attention contracts the last
    # axis of both operands — no per-layer transpose materialization.
    # Implies deferred_decode_cache for the decode path.
    "kvt_cache_layout": False,
    # Paper's C1 applied to the KV cache: symmetric int8 per (position, head)
    # with fp32 scales (group = head_dim). Scales factor out of the score and
    # context sums exactly like GQMV's group scales. Implies kvt layout.
    "int8_kv_cache": False,
    # Prefill is compute-bound (tens of thousands of tokens per weight read),
    # so W8A8 GQMV buys nothing there and its int32 group-sum buffers cost
    # real traffic in the XLA path. This flag dequantizes each int8 weight
    # once per layer and runs the bf16 MXU matmul instead; decode still runs
    # GQMV. Weights stay int8 in HBM either way (the paper's storage win).
    "prefill_dequant": False,
    # Mamba2's chunked SSD (matmul duality): process the time axis in chunks
    # of ssd_chunk, intra-chunk via MXU matmuls, carry the state once per
    # chunk instead of once per step (state HBM traffic / ssd_chunk).
    "chunked_ssd": False,
    "ssd_chunk": 128,
}


def get(name: str):
    return FLAGS[name]


@contextlib.contextmanager
def overrides(**kw):
    old = {k: FLAGS[k] for k in kw}
    FLAGS.update(kw)
    try:
        yield
    finally:
        FLAGS.update(old)
