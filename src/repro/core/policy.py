"""Post-training quantization policy (paper §III-A, Table I).

The paper quantizes: token embeddings, classifier, attention projections,
FFN matrices. It leaves RMSNorm weights in fp32 ("smaller size leading to
negligible overhead"). We generalize the same reasoning to the assigned
architectures: every large (out, in) matmul weight is quantized; small /
accuracy-critical leaves (norms, MoE routers, SSM decay params, conv
kernels, biases, RoPE tables) stay in float.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, quantize_groupwise

# Leaf-name patterns that are never quantized (generalizes the paper's
# RMSNorm exemption).
EXCLUDE_PATTERNS = (
    "norm",        # all RMSNorm / LayerNorm weights (paper Table I: "No")
    "router",      # MoE gates: tiny and routing-accuracy critical
    "a_log", "dt_bias", "d_skip",   # Mamba2 SSM scan parameters
    "conv",        # depthwise conv kernels (tiny)
    "decay", "bonus", "mix", "lora",  # RWKV6 data-dependent decay / token-shift
    "bias",
)

MIN_QUANT_DIM = 32  # don't quantize anything smaller than one group


from repro.core.treepath import path_str as _tree_path_str


def _path_str(path) -> str:
    return _tree_path_str(path).lower()


# Leaves whose CONTRACTION axis is sharded over the model axis when serving
# tensor-parallel (Megatron row-parallel). Quantization groups must then fit
# within one shard, so the per-leaf group size divides n/tp. MoE expert
# leaves are EP-sharded (expert axis), so their contraction stays whole.
ROW_PARALLEL_KEYS = ("wo", "w2", "wout", "wff2")


def _row_parallel(path: str) -> bool:
    if "experts" in path:
        return False
    leafname = path.rsplit("/", 1)[-1]
    return leafname in ROW_PARALLEL_KEYS


def should_quantize(path: str, leaf: Any, group_size: int) -> bool:
    if not isinstance(leaf, jnp.ndarray | jax.Array):
        return False
    if leaf.ndim < 2:
        return False
    if any(p in path for p in EXCLUDE_PATTERNS):
        return False
    n = leaf.shape[-1]
    return n % group_size == 0 and n >= MIN_QUANT_DIM


def leaf_group_size(path: str, leaf, preferred: int, tp: int = 1) -> int | None:
    """Per-leaf GS: the largest power of two <= ``preferred`` that divides the
    per-shard contraction dim (n/tp for row-parallel leaves, n otherwise).
    Returns None if no GS >= 16 fits (leaf then stays unquantized)."""
    n = leaf.shape[-1]
    if _row_parallel(path):
        if n % tp:
            return None
        n //= tp
    gs = preferred
    while gs >= 16:
        if n % gs == 0:
            return gs
        gs //= 2
    return None


def quantize_params(params, group_size: int, tp: int = 1):
    """PTQ driver: replace every quantizable weight leaf with a
    QuantizedTensor (groups along the trailing/contraction axis).

    ``tp`` is the tensor-parallel degree of the serving mesh; it constrains
    per-leaf group sizes so groups never straddle shard boundaries."""

    def convert(path, leaf):
        p = _path_str(path)
        if not should_quantize(p, leaf, 16):
            return leaf
        gs = leaf_group_size(p, leaf, group_size, tp)
        if gs is None:
            return leaf
        return quantize_groupwise(leaf, gs)

    return jax.tree_util.tree_map_with_path(convert, params)


def quantized_fraction(params) -> float:
    """Fraction of parameter bytes stored as int8 after PTQ (for reporting:
    paper compresses 4.4 GB -> 1.1 GB, i.e. ~97% of bytes quantized)."""
    q_bytes = tot_bytes = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    ):
        if isinstance(leaf, QuantizedTensor):
            b = leaf.nbytes()
            q_bytes += b
            tot_bytes += b
        else:
            tot_bytes += leaf.size * leaf.dtype.itemsize
    return q_bytes / max(tot_bytes, 1)
