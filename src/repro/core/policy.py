"""Post-training quantization policy (paper §III-A, Table I).

The paper quantizes: token embeddings, classifier, attention projections,
FFN matrices. It leaves RMSNorm weights in fp32 ("smaller size leading to
negligible overhead"). We generalize the same reasoning to the assigned
architectures: every large (out, in) matmul weight is quantized; small /
accuracy-critical leaves (norms, MoE routers, SSM decay params, conv
kernels, biases, RoPE tables) stay in float.

On top of WHETHER a leaf is quantized, this module decides IN WHICH FORMAT
(core/quant.py registry): leaves are bucketed into LAYER CLASSES (embed /
classifier / attn / ffn / other) and a format map assigns each class a
registry format name, enabling per-layer mixed precision — the "mixed"
preset keeps the accuracy-critical embeddings and classifier at int8 and
drops the bandwidth-dominant attention/FFN projections to packed int4
(sub-byte decode traffic, the axis Hummingbird/2502.10659 push past the
paper's W8A8).
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.core.quant import (
    QuantNumericsError,
    QuantizedTensor,
    get_format,
    largest_pow2_group,
)
from repro.core.treepath import path_str as _tree_path_str

# Leaf-name patterns that are never quantized (generalizes the paper's
# RMSNorm exemption).
EXCLUDE_PATTERNS = (
    "norm",        # all RMSNorm / LayerNorm weights (paper Table I: "No")
    "router",      # MoE gates: tiny and routing-accuracy critical
    "a_log", "dt_bias", "d_skip",   # Mamba2 SSM scan parameters
    "conv",        # depthwise conv kernels (tiny)
    "decay", "bonus", "mix", "lora",  # RWKV6 data-dependent decay / token-shift
    "bias",
)

MIN_QUANT_DIM = 32  # don't quantize anything smaller than one group


def _path_str(path) -> str:
    return _tree_path_str(path).lower()


# Leaves whose CONTRACTION axis is sharded over the model axis when serving
# tensor-parallel (Megatron row-parallel). Quantization groups must then fit
# within one shard, so the per-leaf group size divides n/tp. MoE expert
# leaves are EP-sharded (expert axis), so their contraction stays whole.
ROW_PARALLEL_KEYS = ("wo", "w2", "wout", "wff2")


def _row_parallel(path: str) -> bool:
    if "experts" in path:
        return False
    leafname = path.rsplit("/", 1)[-1]
    return leafname in ROW_PARALLEL_KEYS


# ---------------------------------------------------------------------------
# layer classes and format maps
# ---------------------------------------------------------------------------

LEAF_CLASSES = ("embed", "classifier", "attn", "ffn", "other")

# FFN projection leaf names that live outside an "mlp" container (RWKV6
# keeps its channel-mix matrices flat in the layer dict).
_FFN_LEAVES = ("w13", "w2", "wff1", "wff2", "wffr")

# Containers whose projections count as attention/mixer weights: attention
# blocks, enc-dec cross-attention, and Mamba in/out projections (the SSM
# SCAN parameters inside stay excluded via EXCLUDE_PATTERNS).
_ATTN_CONTAINERS = ("attn", "cross", "mamba")

# Uniform-format presets plus the per-layer-class mixed-precision map:
# embeddings/classifier keep int8 (table lookups are gather-bound, and both
# touch the vocab distribution directly); attention/FFN projections — the
# decode-bandwidth bulk — drop to packed int4.
MIXED_FORMAT_MAP: dict[str, str | None] = {
    "embed": "int8",
    "classifier": "int8",
    "attn": "int4",
    "ffn": "int4",
    "other": "int8",
}

# Sub-int4 frontier: same reasoning one notch further down. The accuracy-
# critical embeddings/classifier stay int8; the bandwidth-dominant
# attention/FFN streams drop to true 3-bit packing (0.375 B/weight, ~0.76x
# the mixed/int4 decode traffic on the bench shapes — benchmarks/quant_bench
# gates this). The quant-error gate (benchmarks/quant_error.py) picks this
# map over an fp8-attn alternative: int3's extra quant error concentrates in
# layers the gate shows tolerate it at GS<=256.
MIXED3_FORMAT_MAP: dict[str, str | None] = {
    "embed": "int8",
    "classifier": "int8",
    "attn": "int3",
    "ffn": "int3",
    "other": "int8",
}

FORMAT_POLICIES: dict[str, Mapping[str, str | None]] = {
    "mixed": MIXED_FORMAT_MAP,
    "mixed3": MIXED3_FORMAT_MAP,
}


def leaf_class(path: str) -> str:
    """Bucket a parameter tree path into one of LEAF_CLASSES.

    Works on the '/'-joined lowered path; a trailing qvalues/scales segment
    (already-quantized trees) is ignored so re-classification is stable.
    """
    parts = [p for p in path.lower().split("/") if p]
    if parts and parts[-1] in ("qvalues", "scales"):
        parts = parts[:-1]
    leaf = parts[-1] if parts else ""
    if "embed" in leaf:
        return "embed"
    if leaf == "classifier":
        return "classifier"
    if "mlp" in parts or "experts" in parts or leaf in _FFN_LEAVES:
        return "ffn"
    if any(c in parts for c in _ATTN_CONTAINERS) or leaf.startswith("w"):
        return "attn"
    return "other"


def resolve_format_map(formats) -> dict[str, str | None]:
    """Normalize a format selector into a complete {layer class: format} map.

    ``formats`` is a registry format name (uniform), a policy preset name
    from FORMAT_POLICIES ("mixed"), or a partial {class: name|None} mapping
    — unspecified classes default to "int8" (the paper baseline) and an
    explicit None excludes that class from quantization entirely.
    """
    if isinstance(formats, str):
        if formats in FORMAT_POLICIES:
            return dict(FORMAT_POLICIES[formats])
        get_format(formats)  # raises with the registered names on a typo
        return {c: formats for c in LEAF_CLASSES}
    if isinstance(formats, Mapping):
        bad = set(formats) - set(LEAF_CLASSES)
        if bad:
            raise ValueError(
                f"unknown layer classes {sorted(bad)}; valid: {LEAF_CLASSES}"
            )
        out: dict[str, str | None] = {c: "int8" for c in LEAF_CLASSES}
        for cls, name in formats.items():
            if name is not None:
                get_format(name)
            out[cls] = name
        return out
    raise TypeError(
        f"formats must be a format/policy name or a {{class: format}} map, "
        f"got {type(formats).__name__}"
    )


def should_quantize(path: str, leaf: Any, group_size: int) -> bool:
    if not isinstance(leaf, jnp.ndarray | jax.Array):
        return False
    if leaf.ndim < 2:
        return False
    if any(p in path for p in EXCLUDE_PATTERNS):
        return False
    n = leaf.shape[-1]
    return n % group_size == 0 and n >= MIN_QUANT_DIM


def leaf_group_size(path: str, leaf, preferred: int, tp: int = 1) -> int | None:
    """Per-leaf GS: the largest power of two <= ``preferred`` that divides the
    per-shard contraction dim (n/tp for row-parallel leaves, n otherwise).
    Returns None if no GS >= 16 fits (leaf then stays unquantized)."""
    n = leaf.shape[-1]
    if _row_parallel(path):
        if n % tp:
            return None
        n //= tp
    return largest_pow2_group(n, preferred, min_gs=16)


def quantize_params(params, group_size: int, tp: int = 1, formats="int8"):
    """PTQ driver: replace every quantizable weight leaf with a
    QuantizedTensor (groups along the trailing/contraction axis) in the
    format its layer class maps to.

    ``tp`` is the tensor-parallel degree of the serving mesh; it constrains
    per-leaf group sizes so groups never straddle shard boundaries.
    ``formats`` selects the format per leaf class (see resolve_format_map);
    the default reproduces the paper's uniform W8A8. A packed format whose
    pack factor does not divide the leaf's group size falls back to int8
    (unreachable for int4 today — group sizes are powers of two >= 16 —
    but a future pack-8 int1 entry would hit it), so a format choice can
    never silently drop a leaf back to fp32.
    """
    fmt_map = resolve_format_map(formats)

    def convert(path, leaf):
        p = _path_str(path)
        if not should_quantize(p, leaf, 16):
            return leaf
        fmt_name = fmt_map[leaf_class(p)]
        if fmt_name is None:
            return leaf
        gs = leaf_group_size(p, leaf, group_size, tp)
        if gs is None:
            return leaf
        fmt = get_format(fmt_name)
        if gs % fmt.pack:
            fmt = get_format("int8")  # packing impossible on this geometry
        try:
            return fmt.quantize(leaf, gs)
        except QuantNumericsError as e:
            # repro-san attribution: which weight, which layer class — the
            # report the debugger needs to find the corrupted checkpoint leaf
            raise QuantNumericsError(
                f"{e} [param {p!r}, layer-class {leaf_class(p)}]") from e

    return jax.tree_util.tree_map_with_path(convert, params)


def quantized_fraction(params) -> float:
    """Fraction of parameter bytes stored quantized after PTQ (for
    reporting: paper compresses 4.4 GB -> 1.1 GB, i.e. ~97% of bytes
    quantized). Accounting is format-aware via the registry's bits-per-
    weight, so packed int4 leaves count their true (halved) storage."""
    q_bits = tot_bits = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    ):
        if isinstance(leaf, QuantizedTensor):
            b = leaf.storage_bits()
            q_bits += b
            tot_bits += b
        else:
            tot_bits += leaf.size * leaf.dtype.itemsize * 8
    return q_bits / max(tot_bits, 1)


def format_breakdown(params) -> dict[str, int]:
    """Stored bytes per quantization format (plus 'float' for the rest) —
    the compression report the serve launcher and benchmarks print."""
    out: dict[str, int] = {}
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    ):
        if isinstance(leaf, QuantizedTensor):
            out[leaf.fmt] = out.get(leaf.fmt, 0) + leaf.nbytes()
        else:
            out["float"] = out.get("float", 0) + leaf.size * leaf.dtype.itemsize
    return out
