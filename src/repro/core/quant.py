"""Group-wise symmetric INT8 quantization (paper §II-B, §III-A).

Implements the paper's W8A8 scheme:

  Q(r)  = Int(r / S),            S = 2 * max(|r|) / 255        (Eq. 1)
  r_hat = Q(r) * S                                             (Eq. 2)

with *group-wise* scales: the contraction axis is split into groups of
``GS`` elements (GS=256 in the paper) and each group gets its own scale.

The quantized weight of a (m, n) matrix is stored exactly like the paper's
flattened ``wq``/``ws`` arrays, but kept 2-D for JAX/sharding friendliness:

  qvalues : int8   (m, n)        -- row-major, groups contiguous along n
  scales  : float32 (m, n // GS) -- one scale per (row, group)

Activations are quantized at run time with the same scheme along their
last axis (paper Alg. 2 lines 3/8/13/16).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_GROUP_SIZE = 256  # paper §III-A: GS=256 divides every TinyLlama dim

__all__ = [
    "DEFAULT_GROUP_SIZE",
    "QuantizedTensor",
    "quantize_groupwise",
    "dequantize",
    "quantize_activation",
    "choose_group_size",
    "quantization_error_stats",
]


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A group-wise symmetric-int8 quantized tensor.

    ``qvalues`` has the original shape; ``scales`` has the same shape with the
    last axis reduced by ``group_size``. Groups run along the LAST axis, which
    by convention is the contraction axis of the matmul that consumes this
    tensor (paper stores W row-major with groups along the column/input dim).
    """

    qvalues: jax.Array  # int8, shape (..., n)
    scales: jax.Array   # float32, shape (..., n // group_size)
    group_size: int

    # -- pytree protocol (keyed, so checkpoint/sharding paths stay readable)
    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("qvalues"), self.qvalues), (ga("scales"), self.scales)), (self.group_size,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        qvalues, scales = children
        return cls(qvalues=qvalues, scales=scales, group_size=aux[0])

    # -- conveniences -------------------------------------------------------
    @property
    def shape(self):
        return self.qvalues.shape

    @property
    def num_groups(self):
        return self.scales.shape[-1]

    def astuple(self):
        return self.qvalues, self.scales

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype=dtype)

    def nbytes(self) -> int:
        return int(np.prod(self.qvalues.shape)) + 4 * int(np.prod(self.scales.shape))


def _check_group_size(n: int, group_size: int) -> None:
    if n % group_size != 0:
        raise ValueError(
            f"last axis ({n}) must be divisible by group_size ({group_size}); "
            "pick GS per paper §III-A (GS must divide every quantized dim)"
        )


@partial(jax.jit, static_argnames=("group_size",))
def quantize_groupwise(r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Symmetric int8 group-wise quantization along the last axis (Eq. 1).

    S = 2*max|r|/255 per group, so r/S spans [-127.5, 127.5]; rounding to
    nearest then clipping to [-127, 127] uses the full signed-int8 range the
    way the paper's Int() does, without the -128 asymmetry.
    """
    n = r.shape[-1]
    _check_group_size(n, group_size)
    g = r.reshape(*r.shape[:-1], n // group_size, group_size).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scales = absmax * (2.0 / 255.0)
    # Avoid 0/0 for all-zero groups; scale value is irrelevant there (q==0).
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(g / safe[..., None]), -127, 127).astype(jnp.int8)
    return QuantizedTensor(
        qvalues=q.reshape(r.shape),
        scales=scales.astype(jnp.float32),
        group_size=group_size,
    )


@partial(jax.jit, static_argnames=("dtype",))
def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """r_hat = Q(r) * S (Eq. 2)."""
    n = qt.qvalues.shape[-1]
    g = qt.qvalues.reshape(*qt.qvalues.shape[:-1], qt.num_groups, qt.group_size)
    out = g.astype(jnp.float32) * qt.scales[..., None]
    return out.reshape(qt.qvalues.shape).astype(dtype)


def quantize_activation(x: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Run-time activation quantization (paper Alg. 2 lines 3/8/13/16).

    Same math as weights; a separate entry point so quantization policy can
    diverge later (e.g. per-tensor activations) without touching weight code.
    """
    return quantize_groupwise(x, group_size=group_size)


def choose_group_size(dims: list[int], preferred: int = DEFAULT_GROUP_SIZE) -> int:
    """Pick the largest GS <= preferred that divides every quantized dim.

    Paper picks 256 because every TinyLlama dim divides by it; assigned archs
    have dims like 5632/14336/10752 where this still holds, but e.g. a 1408
    FFN (deepseek-v2-lite) needs GS=128. Powers of two only, >= 32.
    """
    gs = preferred
    while gs >= 32:
        if all(d % gs == 0 for d in dims):
            return gs
        gs //= 2
    raise ValueError(f"no group size in [32, {preferred}] divides all of {dims}")


def quantization_error_stats(r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> dict[str, float]:
    """Per-element |r_hat - r| statistics (paper Table IV, Eq. 3)."""
    qt = quantize_groupwise(r, group_size)
    err = jnp.abs(qt.dequantize() - r.astype(jnp.float32))
    denom = jnp.where(jnp.abs(r) > 0, jnp.abs(r), 1.0)
    rel = err / denom
    return {
        "max": float(jnp.max(err)),
        "min": float(jnp.min(err)),
        "mean": float(jnp.mean(err)),
        "std": float(jnp.std(err)),
        "rel_mean_pct": float(100.0 * jnp.mean(rel)),
        "rel_std_pct": float(100.0 * jnp.std(rel)),
    }
