"""Pluggable group-wise quantization formats (paper §II-B, §III-A).

The paper's scheme is symmetric group-wise PTQ with per-group fp32 scales:

  Q(r)  = Int(r / S),            S = 2 * max(|r|) / (2^b - 1)     (Eq. 1)
  r_hat = Q(r) * S                                                (Eq. 2)

with the contraction axis split into groups of ``GS`` elements (GS=256 in
the paper) and one scale per group. The paper instantiates b=8; follow-up
work (Hummingbird, arXiv 2507.03308; arXiv 2502.10659) shows decode is
weight-bandwidth-bound well below 8 bits, so this module exposes the scheme
as a :class:`QuantFormat` REGISTRY instead of hardwiring int8:

  int8   storage int8, 1 value/byte, range [-127, 127]  (paper behavior,
         bit-identical to the original ``quantize_groupwise``)
  int4   storage int8, 2 nibbles/byte packed along the last axis,
         range [-7, 7] — halves weight HBM traffic per decode step
  int3   storage uint8, 8 values per 3 bytes (true 3-bit packing, no pow2
         padding), range [-3, 3] — 0.375 B/weight, below the int4 floor
  fp8    storage float8_e4m3fn, 1 value/byte, per-group scale S=absmax/448
         (the e4m3 max-finite) — int8's byte cost with a float value grid

A format is a small spec object: name, storage dtype, pack geometry
(``pack`` logical elements per ``pack_storage`` storage elements),
``quantize(r, gs) -> QuantizedTensor``, ``dequantize``, pack/unpack,
bits-per-weight, and a kernel hook name consumed by ``kernels/ops.py``.
Adding a new format (int2, mx4, ...) is one ``register_format`` call plus a
kernel-hook entry — no edits to qlinear/policy/sharding/checkpoint.

The quantized weight of a (m, n) matrix is stored like the paper's
flattened ``wq``/``ws`` arrays, kept 2-D for JAX/sharding friendliness:

  qvalues : storage dtype (m, n // pack)  -- row-major, groups along n,
                                             packed formats pair adjacent
                                             elements within a group
  scales  : float32 (m, n // GS)          -- one scale per (row, group)

Activations are always quantized at run time to int8 along their last axis
(paper Alg. 2 lines 3/8/13/16) — sub-byte weight formats are W4A8-style.
"""

from __future__ import annotations

import dataclasses
import math
from contextlib import contextmanager
from functools import partial, reduce
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_GROUP_SIZE = 256  # paper §III-A: GS=256 divides every TinyLlama dim

__all__ = [
    "DEFAULT_GROUP_SIZE",
    "QuantFormat",
    "QuantNumericsError",
    "QuantizedTensor",
    "numerics_checks",
    "numerics_checks_enabled",
    "set_numerics_checks",
    "register_format",
    "get_format",
    "available_formats",
    "quantize",
    "quantize_groupwise",
    "quantize_int4",
    "quantize_int3",
    "quantize_fp8",
    "pack_int4",
    "unpack_int4",
    "pack_int3",
    "unpack_int3",
    "FP8_MAX",
    "dequantize",
    "quantize_activation",
    "choose_group_size",
    "largest_pow2_group",
    "quantization_error_stats",
]


# ---------------------------------------------------------------------------
# repro-san numerics tripwires (opt-in; analysis/sanitizer.py enables them)
# ---------------------------------------------------------------------------
# A corrupted scale (NaN/Inf, or absmax overflow from an already-broken
# weight) quantizes to garbage that then dequantizes to *finite-looking*
# noise — the second silent-corruption class next to stale KV blocks. With
# checks on, the format-dispatched quantize/dequantize entry points guard
# inputs, scales, and outputs on the HOST side only (tracers and non-float
# dtypes pass through untouched), so jitted compute paths pay nothing and
# the flag is free when off. quant stays import-free of repro.analysis —
# the sanitizer imports us, not the reverse.

_OVERFLOW_LIMIT = 1e30          # |x| beyond this at a boundary is an error
_NUMERICS = {"on": False}       # process-global, like the format registry


class QuantNumericsError(ArithmeticError):
    """NaN/Inf/overflow crossing a quantize/dequantize boundary."""


def set_numerics_checks(on: bool) -> None:
    _NUMERICS["on"] = bool(on)


def numerics_checks_enabled() -> bool:
    return _NUMERICS["on"]


@contextmanager
def numerics_checks(on: bool = True):
    """Scoped enable/disable for tests and one-off audits."""
    prev = _NUMERICS["on"]
    _NUMERICS["on"] = bool(on)
    try:
        yield
    finally:
        _NUMERICS["on"] = prev


def _numerics_guard(tag: str, x) -> None:
    if isinstance(x, jax.core.Tracer):
        return                  # jitted call sites: checks are host-only
    a = np.asarray(x)
    if not np.issubdtype(a.dtype, np.inexact):
        return
    bad = ~np.isfinite(a) | (np.abs(a) > _OVERFLOW_LIMIT)
    n = int(bad.sum())
    if n:
        idx = tuple(int(i) for i in np.argwhere(bad)[0])
        raise QuantNumericsError(
            f"repro-san[numerics]: {tag}: {n} non-finite/overflow value(s) "
            f"of {a.size}, first at index {idx} = {a[idx]!r}")


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A group-wise symmetric quantized tensor in some registered format.

    ``qvalues`` holds the storage array: the original shape for unpacked
    formats, last axis divided by ``format.pack`` for packed ones. ``scales``
    has the original shape with the last axis reduced by ``group_size``.
    Groups run along the LAST (logical) axis, which by convention is the
    contraction axis of the matmul that consumes this tensor (paper stores W
    row-major with groups along the column/input dim). ``fmt`` is the
    registry name carried as pytree aux data, so checkpoint/sharding paths
    (``.../qvalues``, ``.../scales``) are stable across formats.
    """

    qvalues: jax.Array  # storage dtype, shape (..., n // pack)
    scales: jax.Array   # float32, shape (..., n // group_size)
    group_size: int
    fmt: str = "int8"

    # -- pytree protocol (keyed, so checkpoint/sharding paths stay readable)
    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (
            ((ga("qvalues"), self.qvalues), (ga("scales"), self.scales)),
            (self.group_size, self.fmt),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        qvalues, scales = children
        return cls(qvalues=qvalues, scales=scales, group_size=aux[0], fmt=aux[1])

    # -- conveniences -------------------------------------------------------
    @property
    def format(self) -> "QuantFormat":
        return get_format(self.fmt)

    @property
    def shape(self):
        """LOGICAL shape — what dequantize() returns. Packing is a storage
        detail: model code reading dims off a weight leaf (e.g. the fused
        SwiGLU split) must see the represented tensor, not the byte layout."""
        return self.logical_shape

    @property
    def storage_shape(self):
        return self.qvalues.shape

    @property
    def logical_shape(self):
        s = self.qvalues.shape
        f = self.format
        return (*s[:-1], s[-1] * f.pack // f.pack_storage)

    @property
    def num_groups(self):
        return self.scales.shape[-1]

    def astuple(self):
        return self.qvalues, self.scales

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype=dtype)

    def storage_bits(self) -> int:
        """Total stored bits (qvalues + scales), format-aware."""
        return 8 * self.nbytes()

    def nbytes(self) -> int:
        def _nb(a):
            return int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize

        return _nb(self.qvalues) + _nb(self.scales)

    def bits_per_weight(self) -> float:
        """Stored bits per LOGICAL weight element, scales included
        (e.g. int8/GS=256: 8.125; packed int4/GS=256: 4.125)."""
        return self.storage_bits() / int(np.prod(self.logical_shape))


# ---------------------------------------------------------------------------
# format registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """Spec for one quantization format.

    ``kernel`` names the GQMV/GQMM kernel family in ``kernels/ops.py``
    (``KERNEL_HOOKS``); quant.py stays import-free of the kernels package.
    ``pack``/``unpack_values`` convert between storage and logical values
    (identity for unpacked formats). Pack geometry is a ratio: ``pack``
    logical elements occupy ``pack_storage`` storage elements (int4: 2/1,
    int3: 8/3 — eight 3-bit fields in three bytes). Sharding relies on
    groups being whole multiples of ``pack`` so a pack unit never straddles
    groups. ``kind`` is "int" for symmetric integer grids (the ``qmax`` law
    applies) or "float" for fp8-style value grids (``qmax`` records the
    max-finite magnitude instead).
    """

    name: str
    bits: int                      # stored bits per logical weight element
    storage_dtype: Any             # dtype of QuantizedTensor.qvalues
    pack: int                      # logical elements per pack unit
    qmax: int                      # symmetric range [-qmax, qmax]
    kernel: str                    # hook name consumed by kernels/ops.py
    quantize_fn: Callable = dataclasses.field(repr=False, default=None)
    dequantize_fn: Callable = dataclasses.field(repr=False, default=None)
    pack_fn: Callable = dataclasses.field(repr=False, default=None)
    unpack_fn: Callable = dataclasses.field(repr=False, default=None)
    pack_storage: int = 1          # storage elements per pack unit
    kind: str = "int"              # "int" | "float" value grid

    def quantize(self, r: jax.Array, group_size: int) -> "QuantizedTensor":
        if _NUMERICS["on"]:
            _numerics_guard(f"quantize[{self.name}].input", r)
        qt = self.quantize_fn(r, group_size=group_size)
        if _NUMERICS["on"]:
            _numerics_guard(f"quantize[{self.name}].scales", qt.scales)
        return qt

    def dequantize(self, qt: "QuantizedTensor", dtype=jnp.float32) -> jax.Array:
        if _NUMERICS["on"]:
            _numerics_guard(f"dequantize[{self.name}].scales", qt.scales)
        out = self.dequantize_fn(qt, dtype=dtype)
        if _NUMERICS["on"]:
            _numerics_guard(f"dequantize[{self.name}].output", out)
        return out

    def unpack_values(self, qvalues: jax.Array) -> jax.Array:
        """Storage array -> logical values (int8 for integer formats, the
        storage dtype itself for float formats; identity when pack == 1)."""
        return qvalues if self.unpack_fn is None else self.unpack_fn(qvalues)

    def pack_values(self, values: jax.Array) -> jax.Array:
        return values if self.pack_fn is None else self.pack_fn(values)


_FORMATS: dict[str, QuantFormat] = {}


def register_format(fmt: QuantFormat) -> QuantFormat:
    if fmt.name in _FORMATS:
        raise ValueError(f"quant format {fmt.name!r} already registered")
    _FORMATS[fmt.name] = fmt
    return fmt


def get_format(name: str) -> QuantFormat:
    try:
        return _FORMATS[name]
    except KeyError:
        raise ValueError(
            f"unknown quant format {name!r}; registered: {available_formats()}"
        ) from None


def available_formats() -> tuple[str, ...]:
    return tuple(sorted(_FORMATS))


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _check_group_size(n: int, group_size: int) -> None:
    if n % group_size != 0:
        raise ValueError(
            f"last axis ({n}) must be divisible by group_size ({group_size}); "
            "pick GS per paper §III-A (GS must divide every quantized dim)"
        )


def _group_quantize(r: jax.Array, group_size: int, qmax: int):
    """Shared Eq. 1 core: per-group scale S = 2*max|r|/(2*qmax+1) and
    round-clip to [-qmax, qmax]. Returns (q int8 logical values, scales)."""
    n = r.shape[-1]
    _check_group_size(n, group_size)
    g = r.reshape(*r.shape[:-1], n // group_size, group_size).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scales = absmax * (2.0 / (2 * qmax + 1))
    # Avoid 0/0 for all-zero groups; scale value is irrelevant there (q==0).
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(g / safe[..., None]), -qmax, qmax).astype(jnp.int8)
    return q.reshape(r.shape), scales.astype(jnp.float32)


def largest_pow2_group(n: int, preferred: int, min_gs: int) -> int | None:
    """Largest power-of-two group size <= ``preferred`` and >= ``min_gs``
    that divides ``n``; None if no such size exists.

    The single power-of-two descent shared by :func:`choose_group_size`
    (config-level, floor 32) and ``policy.leaf_group_size`` (per-leaf,
    floor 16) — the two floors differ, the search must not.
    """
    gs = preferred
    while gs >= min_gs:
        if n % gs == 0:
            return gs
        gs //= 2
    return None


def choose_group_size(
    dims: list[int], preferred: int = DEFAULT_GROUP_SIZE, min_gs: int = 32
) -> int:
    """Pick the largest GS <= preferred that divides every quantized dim.

    Paper picks 256 because every TinyLlama dim divides by it; assigned archs
    have dims like 5632/14336/10752 where this still holds, but e.g. a 1408
    FFN (deepseek-v2-lite) needs GS=128. Powers of two only, >= ``min_gs``.
    """
    gs = largest_pow2_group(reduce(math.gcd, dims), preferred, min_gs)
    if gs is None:
        raise ValueError(f"no group size in [{min_gs}, {preferred}] divides all of {dims}")
    return gs


# ---------------------------------------------------------------------------
# int8 (paper W8A8; bit-identical to the pre-registry implementation)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("group_size",))
def quantize_groupwise(r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Symmetric int8 group-wise quantization along the last axis (Eq. 1).

    S = 2*max|r|/255 per group, so r/S spans [-127.5, 127.5]; rounding to
    nearest then clipping to [-127, 127] uses the full signed-int8 range the
    way the paper's Int() does, without the -128 asymmetry.
    """
    q, scales = _group_quantize(r, group_size, qmax=127)
    return QuantizedTensor(qvalues=q, scales=scales, group_size=group_size, fmt="int8")


@partial(jax.jit, static_argnames=("dtype",))
def _dequantize_int8(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """r_hat = Q(r) * S (Eq. 2)."""
    g = qt.qvalues.reshape(*qt.qvalues.shape[:-1], qt.num_groups, qt.group_size)
    out = g.astype(jnp.float32) * qt.scales[..., None]
    return out.reshape(qt.qvalues.shape).astype(dtype)


# ---------------------------------------------------------------------------
# int4, packed two nibbles per int8 byte (W4A8)
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """int8 logical values in [-7, 7], (..., n) -> packed int8 (..., n // 2).

    Byte i holds element 2i in its low nibble and element 2i+1 in its high
    nibble; adjacent elements pair up, so any even group size keeps every
    byte inside one quantization group (the sharding invariant).
    """
    if q.shape[-1] % 2:
        raise ValueError(f"int4 packing needs an even last axis, got {q.shape}")
    lo = jnp.bitwise_and(q[..., 0::2], 0x0F)
    hi = jnp.left_shift(q[..., 1::2], 4)            # int8 shift wraps mod 256
    return jnp.bitwise_or(lo, hi)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Packed int8 (..., n // 2) -> sign-extended int8 logical values (..., n)."""
    lo = jnp.right_shift(jnp.left_shift(p, 4), 4)   # arithmetic >> sign-extends
    hi = jnp.right_shift(p, 4)
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)


@partial(jax.jit, static_argnames=("group_size",))
def quantize_int4(r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Symmetric packed-int4 group-wise quantization (Eq. 1 with b=4).

    S = 2*max|r|/15 per group, round-clip to [-7, 7], then pack nibble pairs;
    weight bytes drop ~2x vs int8 — the off-chip-bandwidth axis the paper
    optimizes (§II-B) pushed below one byte per weight.
    """
    if group_size % 2:
        raise ValueError(f"int4 needs an even group_size, got {group_size}")
    q, scales = _group_quantize(r, group_size, qmax=7)
    return QuantizedTensor(
        qvalues=pack_int4(q), scales=scales, group_size=group_size, fmt="int4"
    )


@partial(jax.jit, static_argnames=("dtype",))
def _dequantize_int4(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    vals = unpack_int4(qt.qvalues)
    g = vals.reshape(*vals.shape[:-1], qt.num_groups, qt.group_size)
    out = g.astype(jnp.float32) * qt.scales[..., None]
    return out.reshape(vals.shape).astype(dtype)


# ---------------------------------------------------------------------------
# int3, true 3-bit packing: 8 values per 3 bytes (W3A8)
# ---------------------------------------------------------------------------
# Pow2-padding 3-bit fields to nibbles would store int3 at int4's byte cost
# and erase the whole point; instead eight 3-bit two's-complement fields are
# packed little-endian into one 24-bit word (3 uint8 storage bytes). pack=8
# divides every power-of-two group size >= 8, so the whole-groups sharding
# invariant holds with no new geometry at the policy layer.

def pack_int3(q: jax.Array) -> jax.Array:
    """int8 logical values in [-3, 3], (..., n) -> packed uint8 (..., n//8*3).

    Each run of 8 elements becomes one 24-bit little-endian word: element i
    occupies bits [3i, 3i+3) as a 3-bit two's-complement field; the word is
    stored as 3 bytes (b0 = bits 0-7, b1 = 8-15, b2 = 16-23)."""
    if q.shape[-1] % 8:
        raise ValueError(f"int3 packing needs a last axis divisible by 8, got {q.shape}")
    u = jnp.bitwise_and(q.astype(jnp.int32), 0x7)
    u = u.reshape(*q.shape[:-1], q.shape[-1] // 8, 8)
    w = jnp.sum(jnp.left_shift(u, jnp.arange(8, dtype=jnp.int32) * 3), axis=-1)
    b = jnp.stack([w & 0xFF, (w >> 8) & 0xFF, (w >> 16) & 0xFF], axis=-1)
    return b.astype(jnp.uint8).reshape(*q.shape[:-1], q.shape[-1] // 8 * 3)


def unpack_int3(p: jax.Array) -> jax.Array:
    """Packed uint8 (..., 3k) -> sign-extended int8 logical values (..., 8k).

    Pure shift/mask/interleave: each 3-bit field of the little-endian 24-bit
    group comes straight off its byte plane(s), and sign extension is the
    ``(v << 5) >>a 5`` trick on a bitcast int8 view — no select/subtract.
    This is not a style choice: the xray bytes audit (analysis/hlo.py
    ``is_unpack_fusion``) only normalizes unpack fusions whose body is free
    of arithmetic, the contract that the TPU dot reads the PACKED buffer.
    An unpack with compares/subtracts is charged at full s32 width and
    int3 decode would audit at ~8x its declared traffic.
    """
    if p.shape[-1] % 3:
        raise ValueError(f"int3 storage last axis must divide by 3, got {p.shape}")
    b = p.reshape(*p.shape[:-1], p.shape[-1] // 3, 3)
    b0, b1, b2 = b[..., 0], b[..., 1], b[..., 2]
    fields = [
        b0 & 7,                                  # bits 0-2
        (b0 >> 3) & 7,                           # bits 3-5
        ((b0 >> 6) & 3) | ((b1 << 2) & 4),       # bits 6-8 straddle b0/b1
        (b1 >> 1) & 7,                           # bits 9-11
        (b1 >> 4) & 7,                           # bits 12-14
        ((b1 >> 7) & 1) | ((b2 << 1) & 6),       # bits 15-17 straddle b1/b2
        (b2 >> 2) & 7,                           # bits 18-20
        (b2 >> 5) & 7,                           # bits 21-23
    ]
    u = jnp.stack(fields, axis=-1)               # (..., k, 8) uint8 in 0..7
    v = jax.lax.bitcast_convert_type(u << 5, jnp.int8) >> 5
    return v.reshape(*p.shape[:-1], p.shape[-1] // 3 * 8)


@partial(jax.jit, static_argnames=("group_size",))
def quantize_int3(r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Symmetric packed-int3 group-wise quantization (Eq. 1 with b=3).

    S = 2*max|r|/7 per group, round-clip to [-3, 3], pack 8-per-3-bytes:
    0.375 B/weight, ~2.67x less weight HBM per decode step than int8 and
    ~1.33x less than packed int4."""
    if group_size % 8:
        raise ValueError(f"int3 needs a group_size divisible by 8, got {group_size}")
    q, scales = _group_quantize(r, group_size, qmax=3)
    return QuantizedTensor(
        qvalues=pack_int3(q), scales=scales, group_size=group_size, fmt="int3"
    )


@partial(jax.jit, static_argnames=("dtype",))
def _dequantize_int3(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    vals = unpack_int3(qt.qvalues)
    g = vals.reshape(*vals.shape[:-1], qt.num_groups, qt.group_size)
    out = g.astype(jnp.float32) * qt.scales[..., None]
    return out.reshape(vals.shape).astype(dtype)


# ---------------------------------------------------------------------------
# fp8 (e4m3, per-group scale): a float value grid at int8's byte cost
# ---------------------------------------------------------------------------

FP8_MAX = 448.0      # float8_e4m3fn max finite (no inf encoding in e4m3fn)


@partial(jax.jit, static_argnames=("group_size",))
def quantize_fp8(r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Group-wise fp8 (e4m3): S = max|r|/448 maps each group onto the full
    e4m3 exponent range; the storage cast rounds-to-nearest onto the float
    grid. Same byte cost as int8 but a relative-error profile that follows
    magnitude — the frontier choice for outlier-heavy layer classes."""
    n = r.shape[-1]
    _check_group_size(n, group_size)
    g = r.reshape(*r.shape[:-1], n // group_size, group_size).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scales = absmax * (1.0 / FP8_MAX)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = (g / safe[..., None]).astype(jnp.float8_e4m3fn)
    return QuantizedTensor(
        qvalues=q.reshape(r.shape), scales=scales.astype(jnp.float32),
        group_size=group_size, fmt="fp8",
    )


@partial(jax.jit, static_argnames=("dtype",))
def _dequantize_fp8(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    g = qt.qvalues.reshape(*qt.qvalues.shape[:-1], qt.num_groups, qt.group_size)
    out = g.astype(jnp.float32) * qt.scales[..., None]
    return out.reshape(qt.qvalues.shape).astype(dtype)


register_format(QuantFormat(
    name="int8", bits=8, storage_dtype=jnp.int8, pack=1, qmax=127,
    kernel="gqmv_int8",
    quantize_fn=quantize_groupwise, dequantize_fn=_dequantize_int8,
))

register_format(QuantFormat(
    name="int4", bits=4, storage_dtype=jnp.int8, pack=2, qmax=7,
    kernel="gqmv_int4",
    quantize_fn=quantize_int4, dequantize_fn=_dequantize_int4,
    pack_fn=pack_int4, unpack_fn=unpack_int4,
))

register_format(QuantFormat(
    name="int3", bits=3, storage_dtype=jnp.uint8, pack=8, pack_storage=3,
    qmax=3, kernel="gqmv_int3",
    quantize_fn=quantize_int3, dequantize_fn=_dequantize_int3,
    pack_fn=pack_int3, unpack_fn=unpack_int3,
))

register_format(QuantFormat(
    name="fp8", bits=8, storage_dtype=jnp.float8_e4m3fn, pack=1,
    qmax=int(FP8_MAX), kernel="gqmv_fp8", kind="float",
    quantize_fn=quantize_fp8, dequantize_fn=_dequantize_fp8,
))


# ---------------------------------------------------------------------------
# generic entry points (format-dispatched)
# ---------------------------------------------------------------------------

def quantize(
    r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE, fmt: str = "int8"
) -> QuantizedTensor:
    """Quantize ``r`` group-wise in registry format ``fmt``."""
    return get_format(fmt).quantize(r, group_size)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """r_hat = Q(r) * S (Eq. 2), dispatched on ``qt.fmt``."""
    return qt.format.dequantize(qt, dtype=dtype)


def quantize_activation(x: jax.Array, group_size: int = DEFAULT_GROUP_SIZE) -> QuantizedTensor:
    """Run-time activation quantization (paper Alg. 2 lines 3/8/13/16).

    Always int8, regardless of the weight format: sub-byte WEIGHTS are what
    cut decode HBM traffic (weights dominate, §II-B); activations are tiny
    and re-quantized per step, so W4A8 keeps the accumulation exact in the
    same int8*int8->int32 datapath.
    """
    return quantize_groupwise(x, group_size=group_size)


def quantization_error_stats(
    r: jax.Array, group_size: int = DEFAULT_GROUP_SIZE, fmt: str = "int8"
) -> dict[str, float]:
    """Per-element |r_hat - r| statistics (paper Table IV, Eq. 3)."""
    qt = quantize(r, group_size, fmt)
    err = jnp.abs(qt.dequantize() - r.astype(jnp.float32))
    denom = jnp.where(jnp.abs(r) > 0, jnp.abs(r), 1.0)
    rel = err / denom
    return {
        "max": float(jnp.max(err)),
        "min": float(jnp.min(err)),
        "mean": float(jnp.mean(err)),
        "std": float(jnp.std(err)),
        "rel_mean_pct": float(100.0 * jnp.mean(rel)),
        "rel_std_pct": float(100.0 * jnp.std(rel)),
    }
