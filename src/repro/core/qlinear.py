"""Quantization-aware linear / embedding primitives.

Every weight-bearing matmul in the model zoo goes through ``linear``: when
the weight leaf is a plain array it is an ordinary (bf16/f32) matmul; when
it is a :class:`QuantizedTensor` the call becomes the paper's GQMV/GQMM
(run-time int8 activation quantization + the group-wise kernel of the
weight's registered format — W8A8 for int8 storage, W4A8 for packed int4;
see core/quant.py and DESIGN.md §8).

Weights follow the paper's (out, in) row-major layout with quantization
groups along the *in* (contraction) axis.

Kernel-launch fusion (paper C4: concatenated Wq+Wk+Wv, W1+W3) is expressed
by storing the concatenated matrix as one leaf and splitting the output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import flags
from repro.core.quant import QuantizedTensor
from repro.kernels import ops

__all__ = ["linear", "embedding_lookup", "split_fused"]


def linear(w, x: jax.Array, *, impl: str = "auto") -> jax.Array:
    """y = x @ W^T for W (out, in); quantized-kernel path when W is a
    QuantizedTensor (any registered format)."""
    if isinstance(w, QuantizedTensor):
        if flags.get("prefill_dequant"):
            # compute-bound many-token passes: one dequant + bf16 MXU matmul
            # beats GQMV's int32 group-sum buffers (flags.py rationale)
            return jnp.einsum("...i,oi->...o", x, w.dequantize(x.dtype))
        return ops.quantized_matmul(x, w, impl=impl).astype(x.dtype)
    return jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))


def embedding_lookup(w, ids: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Row gather from a (vocab, d) table; dequantizes gathered rows when
    the table is quantized (paper quantizes W_embeddings, Table I).

    Only the gathered rows leave HBM: packed formats gather their (smaller)
    storage rows and unpack to nibble values on-chip before scaling.
    """
    if isinstance(w, QuantizedTensor):
        q = jnp.take(w.qvalues, ids, axis=0)        # (..., d/pack) storage
        s = jnp.take(w.scales, ids, axis=0)         # (..., d/GS)
        v = w.format.unpack_values(q)               # (..., d) int8 values
        g = v.reshape(*v.shape[:-1], w.num_groups, w.group_size).astype(dtype)
        return (g * s[..., None].astype(dtype)).reshape(v.shape)
    return jnp.take(w, ids, axis=0).astype(dtype)


def split_fused(y: jax.Array, sizes: tuple[int, ...]):
    """Split the output of a fused projection (paper Alg. 2 lines 4, 12)."""
    outs, off = [], 0
    for s in sizes:
        outs.append(y[..., off:off + s])
        off += s
    if off != y.shape[-1]:
        raise ValueError(
            f"split_fused sizes {tuple(sizes)} sum to {off} but the fused "
            f"output has trailing dim {y.shape[-1]} (shape {y.shape})"
        )
    return outs
