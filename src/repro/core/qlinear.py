"""Quantization-aware linear / embedding primitives.

Every weight-bearing matmul in the model zoo goes through ``linear``: when
the weight leaf is a plain array it is an ordinary (bf16/f32) matmul; when it
is a :class:`QuantizedTensor` the call becomes the paper's W8A8 GQMV/GQMM
(run-time activation quantization + group-wise int8 kernel).

Weights follow the paper's (out, in) row-major layout with quantization
groups along the *in* (contraction) axis.

Kernel-launch fusion (paper C4: concatenated Wq+Wk+Wv, W1+W3) is expressed
by storing the concatenated matrix as one leaf and splitting the output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import flags
from repro.core.quant import QuantizedTensor
from repro.kernels import ops

__all__ = ["linear", "embedding_lookup", "split_fused"]


def linear(w, x: jax.Array, *, impl: str = "auto") -> jax.Array:
    """y = x @ W^T for W (out, in); W8A8 path when W is quantized."""
    if isinstance(w, QuantizedTensor):
        if flags.get("prefill_dequant"):
            # compute-bound many-token passes: one dequant + bf16 MXU matmul
            # beats GQMV's int32 group-sum buffers (flags.py rationale)
            return jnp.einsum("...i,oi->...o", x, w.dequantize(x.dtype))
        return ops.quantized_matmul(x, w, impl=impl).astype(x.dtype)
    return jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))


def embedding_lookup(w, ids: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Row gather from a (vocab, d) table; dequantizes gathered rows when the
    table is int8-quantized (paper quantizes W_embeddings, Table I)."""
    if isinstance(w, QuantizedTensor):
        q = jnp.take(w.qvalues, ids, axis=0)                    # (..., d) int8
        s = jnp.take(w.scales, ids, axis=0)                     # (..., d/GS)
        g = q.reshape(*q.shape[:-1], w.num_groups, w.group_size).astype(dtype)
        return (g * s[..., None].astype(dtype)).reshape(q.shape)
    return jnp.take(w, ids, axis=0).astype(dtype)


def split_fused(y: jax.Array, sizes: tuple[int, ...]):
    """Split the output of a fused projection (paper Alg. 2 lines 4, 12)."""
    outs, off = [], 0
    for s in sizes:
        outs.append(y[..., off:off + s])
        off += s
    assert off == y.shape[-1], (off, y.shape)
    return outs
