"""Token sampling strategies (paper §II-A: greedy + top-p)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import NEG_INF


def greedy(logits: jax.Array, key=None) -> jax.Array:
    """(b, V) -> (b,) int32. The paper's evaluation setting (§V-C)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def nucleus_mask(logits: jax.Array, p: float) -> jax.Array:
    """Boolean mask of the smallest set whose probability mass reaches ``p``.

    Sorted-space construction: keep sorted position i iff the mass BEFORE it
    (exclusive cumsum) is still < p, then scatter the mask back to original
    positions through the inverse sort permutation. Value-threshold filtering
    (``logits >= cutoff``) keeps every token tied with the cutoff logit and
    inflates the nucleus past p — worst case the whole vocab on tied logits.
    The top token is always kept (its exclusive mass is 0 < p).
    """
    idx = jnp.argsort(logits, axis=-1)[..., ::-1]              # descending
    sorted_logits = jnp.take_along_axis(logits, idx, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    mass_before = jnp.cumsum(probs, axis=-1) - probs           # exclusive
    keep_sorted = mass_before < p
    inv = jnp.argsort(idx, axis=-1)                            # inverse perm
    return jnp.take_along_axis(keep_sorted, inv, axis=-1)


def top_p(logits: jax.Array, key, p: float = 0.9, temperature: float = 1.0) -> jax.Array:
    """Nucleus sampling [Holtzman et al., 2020] (paper ref [15])."""
    logits = logits / temperature
    filtered = jnp.where(nucleus_mask(logits, p), logits, NEG_INF)
    return jax.random.categorical(key, filtered, axis=-1).astype(jnp.int32)


def sampler_sig(sampler_kw) -> tuple:
    """Canonical hashable form of a sampler-kwargs mapping, shared by every
    jit/scheduler cache key (engine.generate, serve_continuous, serve_paged)
    so the normalization cannot drift between call sites."""
    return tuple(sorted(dict(sampler_kw or {}).items()))


def make_sampler(name: str, **kw):
    """sampler(logits, key) -> tokens. ``kw`` (p / temperature for top_p) is
    reachable end to end: InferenceEngine.generate / serve_ragged /
    the schedulers accept ``sampler_kw`` and the serve CLI exposes
    --top-p / --temperature."""
    if name == "greedy":
        if kw:
            raise ValueError(f"greedy sampler takes no kwargs, got {sorted(kw)}")
        return greedy
    if name == "top_p":
        return lambda logits, key: top_p(logits, key, **kw)
    raise ValueError(f"unknown sampler {name}")
