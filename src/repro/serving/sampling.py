"""Token sampling strategies (paper §II-A: greedy + top-p)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array, key=None) -> jax.Array:
    """(b, V) -> (b,) int32. The paper's evaluation setting (§V-C)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def top_p(logits: jax.Array, key, p: float = 0.9, temperature: float = 1.0) -> jax.Array:
    """Nucleus sampling [Holtzman et al., 2020] (paper ref [15])."""
    logits = logits / temperature
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # smallest set whose cumulative prob >= p; always keep the top token
    cutoff_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    filtered = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return jax.random.categorical(key, filtered, axis=-1).astype(jnp.int32)


def make_sampler(name: str, **kw):
    if name == "greedy":
        return greedy
    if name == "top_p":
        return lambda logits, key: top_p(logits, key, **kw)
    raise ValueError(f"unknown sampler {name}")
