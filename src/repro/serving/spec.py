"""Speculative decoding: drafters + exact accept/reject + the jitted verify
step (DESIGN.md §10).

LlamaF's decode regime is weight-bandwidth-bound (§II-B): every generated
token streams the full quantized weight set once. Speculative decoding
amortizes that stream over a chunk — k candidate tokens run through ONE
forward pass (`models/transformer.py::lm_verify`), turning k sequential
GQMVs into a single k-row GQMM that reads each weight block once, and the
accepted prefix advances the sequence by 1..k tokens per stream.

Three pieces live here:

- **Drafters** — propose the candidates. `NgramDrafter` (default) is the
  zero-weight prompt-lookup drafter: it continues the longest context
  suffix that occurred earlier in the context, so repetitive traffic
  (code, templated text, self-repeating generations) drafts itself for
  free. `ModelDrafter` runs a small registry model greedily. Both are
  host-side and deterministic — a point-mass proposal distribution, which
  is what makes the acceptance rule below exact.
- **`spec_accept`** — distribution-preserving accept/reject on the verify
  logits. Greedy fast path: the accepted prefix is the run of drafts that
  match the target argmax, and the target argmax row doubles as the
  correction/bonus token, so `out = argmax(logits)` and
  `n_out = 1 + leading matches`. For top-p/temperature the draft token
  d is accepted with probability p_target(d); on rejection the output is
  sampled from the leftover distribution — p_target with d masked out and
  renormalized (`nucleus_mask` builds p_target) — which reproduces the
  target distribution exactly for a deterministic drafter.
- **`build_verify_step`** — the jitted step the engine and both
  schedulers share: verify -> accept -> commit the accepted prefix
  (clamped to each row's remaining budget and its live mask) -> advance
  positions. Rejected rows are never written (contiguous: scatter
  dropped; paged: routed to the sink block), so rollback is the position
  arithmetic itself.
"""

from __future__ import annotations

from functools import partial
from typing import Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import NEG_INF
from repro.serving.sampling import nucleus_mask


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

@runtime_checkable
class Drafter(Protocol):
    """Proposes k candidate continuations of a token context. Host-side and
    deterministic: the acceptance rule treats the proposal as a point mass."""

    name: str

    def draft(self, tokens: Sequence[int], k: int) -> list[int]:
        """tokens -> exactly k proposed continuation token ids."""
        ...


class NgramDrafter:
    """Prompt-lookup / n-gram drafter — no weights, no forward passes.

    Finds the most recent earlier occurrence of the context's trailing
    n-gram (longest n first, down to 1) and proposes the tokens that
    followed it. With no match it repeats the last token — still a valid
    proposal, just unlikely to be accepted. Acceptance is high exactly when
    the target's output revisits its own history (repetitive traces), which
    is where the weight-stream amortization pays off.

    The scan covers only the trailing ``window`` tokens so the per-step
    host cost stays O(window) on long generations instead of growing with
    the full history (the repeats worth drafting are overwhelmingly local;
    the verify step this feeds is the hot loop)."""

    name = "ngram"

    def __init__(self, max_n: int = 3, window: int = 512):
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        self.max_n = max_n
        self.window = window

    def draft(self, tokens: Sequence[int], k: int) -> list[int]:
        toks = list(tokens)[-self.window:]
        if not toks:
            return [0] * k
        for n in range(min(self.max_n, len(toks) - 1), 0, -1):
            suffix = toks[-n:]
            # most recent earlier occurrence wins (local context repeats
            # beat distant ones)
            for i in range(len(toks) - n - 1, -1, -1):
                if toks[i:i + n] == suffix:
                    cont = toks[i + n:i + n + k]
                    if cont:
                        return (cont + [toks[-1]] * (k - len(cont)))[:k]
        return [toks[-1]] * k


class ModelDrafter:
    """Greedy k-token drafts from a small registry model with its own
    weights. Reference implementation: each draft call re-prefills the
    (bucket-padded) context and decodes k-1 greedy steps — O(context) work
    per call, amortized by the draft model being a fraction of the target.
    Persistent per-request draft caches are a scheduler-state extension,
    not needed for correctness."""

    def __init__(self, model, params, *, max_len: int = 4096):
        if not model.supports_lengths:
            raise ValueError(
                f"{model.cfg.arch_id}: ModelDrafter needs length-aware "
                "prefill (decoder_lm families)"
            )
        self.name = f"model:{model.cfg.arch_id}"
        self.model = model
        self.params = params
        self.max_len = max_len
        self._jit: dict[tuple[int, int], callable] = {}

    def _fn(self, pad_len: int, k: int):
        if (pad_len, k) not in self._jit:
            model = self.model

            @jax.jit
            def run(params, toks, length):
                logits, cache = model.prefill(
                    params, {"tokens": toks, "lengths": length}, pad_len + k
                )
                t0 = jnp.argmax(logits, -1).astype(jnp.int32)

                def step(carry, _):
                    tok, cache, pos = carry
                    lg, cache = model.decode(params, tok, cache, pos)
                    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
                    return (nxt, cache, pos + 1), nxt

                (_, _, _), rest = jax.lax.scan(
                    step, (t0, cache, length), None, length=k - 1
                )
                return jnp.concatenate([t0[:, None], rest.T], axis=1)

            self._jit[(pad_len, k)] = run
        return self._jit[(pad_len, k)]

    def draft(self, tokens: Sequence[int], k: int) -> list[int]:
        from repro.serving.batching import bucket_length

        toks = list(tokens)[-self.max_len:]
        pad_len = bucket_length(len(toks))
        arr = np.zeros((1, pad_len), np.int32)
        arr[0, : len(toks)] = toks
        out = self._fn(pad_len, k)(
            self.params, jnp.asarray(arr),
            jnp.asarray([len(toks)], jnp.int32),
        )
        return [int(t) for t in np.asarray(out)[0]]


def resolve_drafter(name: str | None, *, reduced: bool = False,
                    seed: int = 0) -> Drafter:
    """CLI-string drafter factory: ``"ngram"`` (default) or
    ``"model:<arch-id>"`` (fresh weights from the registry — a stand-in for
    a trained draft checkpoint)."""
    from repro.models.registry import build_arch

    if name is None or name == "ngram":
        return NgramDrafter()
    if name.startswith("model:"):
        model = build_arch(name.split(":", 1)[1], reduced=reduced)
        params = model.init(jax.random.PRNGKey(seed))
        return ModelDrafter(model, params)
    raise ValueError(f"unknown drafter {name!r} (ngram or model:<arch-id>)")


# ---------------------------------------------------------------------------
# exact accept/reject
# ---------------------------------------------------------------------------

def spec_accept(logits, chunk, key, *, sampler: str = "greedy",
                sampler_kw=()):
    """Accept/reject a drafted chunk against its verify logits.

    logits (b, k, V): row j is the target's next-token distribution after
    chunk token j. chunk (b, k) = [t0, d1, .., d_{k-1}] — the current token
    followed by the drafted candidates, so draft d_{j+1} is tested against
    logits row j. Returns (out (b, k) int32, n_out (b,) int32): the tokens
    produced this step are ``out[i, :n_out[i]]`` — the accepted drafts
    followed by one correction (greedy argmax / leftover sample) or, when
    every draft survives, a bonus token from the final row. Every verify
    step therefore produces at least one token, and greedy output is
    token-identical to vanilla decode by construction."""
    b, k, v = logits.shape
    drafts = chunk[:, 1:]                                       # (b, k-1)
    if sampler == "greedy":
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (b, k)
        if k == 1:
            return tgt, jnp.ones((b,), jnp.int32)
        match = tgt[:, : k - 1] == drafts
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        # tgt[:, j] == d_{j+1} for accepted j, and row n_acc is the
        # correction/bonus — out IS the argmax matrix
        return tgt, n_acc + 1
    if sampler != "top_p":
        raise ValueError(f"unknown sampler {sampler!r} for speculative accept")
    kw = dict(sampler_kw)
    p, temp = kw.pop("p", 0.9), kw.pop("temperature", 1.0)
    if kw:
        raise ValueError(f"top_p accept takes p/temperature, got {sorted(kw)}")
    lg = logits / temp
    filt = jnp.where(nucleus_mask(lg, p), lg, NEG_INF)          # (b, k, V)
    probs = jax.nn.softmax(filt, axis=-1)
    ku, kr = jax.random.split(key)
    if k == 1:
        out = jax.random.categorical(kr, filt[:, 0], axis=-1).astype(jnp.int32)
        return out[:, None], jnp.ones((b,), jnp.int32)
    # accept d_{j+1} with prob p_target(d_{j+1}); deterministic (point-mass)
    # proposal => the residual is p_target with the draft token removed
    p_draft = jnp.take_along_axis(
        probs[:, : k - 1], drafts[..., None].astype(jnp.int32), axis=-1
    )[..., 0]                                                   # (b, k-1)
    accept = jax.random.uniform(ku, (b, k - 1)) < p_draft
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)
    rows = jnp.arange(b)
    sel = filt[rows, n_acc]                                     # (b, V)
    # rejection at row n_acc < k-1: mask the rejected draft out of the
    # nucleus (leftover distribution); full acceptance samples the bonus
    # from the final row unmasked
    rejected = n_acc < (k - 1)
    rej_tok = drafts[rows, jnp.minimum(n_acc, k - 2)]
    sel = jnp.where(
        rejected[:, None] & (jnp.arange(v)[None, :] == rej_tok[:, None]),
        NEG_INF, sel,
    )
    t_new = jax.random.categorical(kr, sel, axis=-1).astype(jnp.int32)
    out = jnp.concatenate([drafts, jnp.zeros((b, 1), jnp.int32)], axis=1)
    out = out.at[rows, n_acc].set(t_new)
    return out, n_acc + 1


# ---------------------------------------------------------------------------
# host-side round bookkeeping (shared by the engine and the scheduling core)
# ---------------------------------------------------------------------------

def draft_chunk(drafter: Drafter, tok, live, context_fn, k: int):
    """Assemble the (B, k) verify chunk: column 0 is each row's newest
    (uncommitted) token; live rows get k-1 drafts from their token history
    (``context_fn(i) -> list[int]``); dead rows keep the frozen token."""
    chunk = np.repeat(np.asarray(tok, np.int32)[:, None], k, axis=1)
    for i in np.flatnonzero(live):
        chunk[i, 1:] = drafter.draft(context_fn(i), k - 1)
    return chunk


def take_accepted(out_row, n_out, remaining, eos, stats, k: int) -> list[int]:
    """Post-verify bookkeeping for one row: clamp the produced tokens to the
    remaining budget, truncate at EOS, and account only the KEPT tokens —
    drafts accepted past an EOS or the budget clamp are discarded work, not
    amortization, so they must not inflate the acceptance/throughput stats
    the spec benchmark and CLI report. Returns the tokens to keep (ending
    with EOS when one fired)."""
    take = min(int(n_out), int(remaining))
    new = [int(t) for t in out_row[:take]]
    if eos is not None and eos in new:
        new = new[: new.index(eos) + 1]
    stats["drafted"] += k - 1
    stats["accepted"] += min(int(n_out) - 1, len(new))
    stats["generated"] += len(new)
    return new


# ---------------------------------------------------------------------------
# the jitted verify step
# ---------------------------------------------------------------------------

def build_verify_step(model, *, sampler: str = "greedy", sampler_kw=(),
                      paged: bool = False):
    """One speculative decode step as a single jitted program:
    verify k chunk tokens -> accept/reject -> commit the accepted prefix ->
    advance positions. Shared by `InferenceEngine._generate_spec` and the
    scheduling core's spec-capable cache adapters (`ContiguousAdapter`,
    `PagedAdapter` — see serving/core.py).

    The commit count is ``min(n_out, remaining)`` gated by ``live``: a row
    past its budget (or a frozen scheduler slot) commits nothing and its
    position stays put, so cache growth tracks exactly the tokens the host
    will keep. The cache argument is donated (same rationale as the
    schedulers' decode programs).

    Contiguous signature: step(params, chunk, cache, pos, live, remaining,
    key); paged inserts ``table`` after ``cache``. Returns (out (b, k),
    n_out (b,), cache, pos, last_logits (b, V)) where last_logits is each
    row's distribution that produced its final output token."""
    skw = tuple(sorted(dict(sampler_kw or {}).items()))

    def _finish(logits, chunk, key, live, remaining):
        out, n_out = spec_accept(logits, chunk, key, sampler=sampler,
                                 sampler_kw=skw)
        n_commit = jnp.where(live, jnp.minimum(n_out, jnp.maximum(remaining, 0)), 0)
        # the distribution that produced each row's final KEPT token: index
        # by the budget-clamped count, not the raw accept count (the raw
        # row prices a token the host will discard). EOS truncation is
        # host-side knowledge, so an EOS mid-chunk still reads one row
        # late — see the GenerationResult logits_last caveat.
        idx = jnp.maximum(jnp.minimum(n_out, jnp.maximum(remaining, 1)) - 1, 0)
        last = logits[jnp.arange(out.shape[0]), idx]
        return out, n_out, n_commit, last

    if paged:
        @partial(jax.jit, donate_argnums=(2,))
        def step(params, chunk, cache, table, pos, live, remaining, key):
            logits, rows = model.verify_paged(params, chunk, cache, table, pos)
            out, n_out, n_commit, last = _finish(logits, chunk, key, live, remaining)
            cache = model.commit_verify_paged(cache, rows, table, pos, n_commit)
            return out, n_out, cache, pos + n_commit, last
        return step

    @partial(jax.jit, donate_argnums=(2,))
    def step(params, chunk, cache, pos, live, remaining, key):
        logits, rows = model.verify(params, chunk, cache, pos)
        out, n_out, n_commit, last = _finish(logits, chunk, key, live, remaining)
        cache = model.commit_verify(cache, rows, pos, n_commit)
        return out, n_out, cache, pos + n_commit, last
    return step
