"""Inference engine: prefill + scanned decode with W8A8 or float weights.

Mirrors the paper's serving structure (Alg. 2): the "transformer controller"
is the jitted scan below, the quantized weights feed GQMV/GQMM via the
linear() dispatch, and batch-1 real-time decoding is the faithful setting
(batched decode is the TPU-native generalization).

Fault-tolerance hooks: ``snapshot()``/``restore()`` expose the generation
state (cache + position + tokens) so a preempted decode can resume on a
rebuilt mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.policy import quantize_params, quantized_fraction
from repro.models.registry import Model
from repro.serving.sampling import make_sampler


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array         # (b, max_new_tokens) sampled tokens
    logits_last: jax.Array    # (b, vocab) final-step logits
    steps: int


class InferenceEngine:
    """Uniform-length batched generation over any registry Model.

    quantize=True applies the paper's PTQ (W8A8 group-wise) to the weights;
    quantize=False is the "PS baseline" (same math, float weights).
    """

    def __init__(self, model: Model, params, *, cache_len: int,
                 quantize: bool = False, tp: int = 1, eos_id: int | None = None):
        self.model = model
        self.cfg = model.cfg
        self.cache_len = cache_len
        self.eos_id = eos_id
        if quantize:
            params = quantize_params(params, self.cfg.group_size, tp=tp)
        self.params = params
        self.quantized_fraction = quantized_fraction(params)
        self._generate_jit: dict[tuple, Callable] = {}

    # -- one-step APIs (used by benchmarks and the dry-run) -----------------
    def prefill(self, batch):
        return self.model.prefill(self.params, batch, self.cache_len)

    def decode_step(self, token, cache, pos):
        return self.model.decode(self.params, token, cache, pos)

    # -- full generation -----------------------------------------------------
    def _build_generate(self, max_new_tokens: int, sampler_name: str, prompt_len: int):
        sampler = make_sampler(sampler_name)
        model, cache_len = self.model, self.cache_len

        @jax.jit
        def run(params, batch, key):
            logits, cache = model.prefill(params, batch, cache_len)
            tok0 = sampler(logits, key)

            def step(carry, k):
                tok, cache, pos, done = carry
                logits, cache = model.decode(params, tok, cache, pos)
                nxt = sampler(logits, k)
                if self.eos_id is not None:
                    nxt = jnp.where(done, self.eos_id, nxt)
                    done = done | (nxt == self.eos_id)
                return (nxt, cache, pos + 1, done), (nxt, logits)

            done0 = jnp.zeros(tok0.shape, jnp.bool_)
            keys = jax.random.split(key, max_new_tokens)
            (_, cache, _, _), (toks, logit_seq) = jax.lax.scan(
                step, (tok0, cache, jnp.int32(prompt_len), done0), keys
            )
            tokens = jnp.concatenate([tok0[None], toks[:-1]], axis=0)
            return jnp.moveaxis(tokens, 0, 1), logit_seq[-1]

        return run

    def generate(self, batch, max_new_tokens: int, *, sampler: str = "greedy",
                 key=None) -> GenerationResult:
        prompt_len = batch["tokens"].shape[1]
        sig = (max_new_tokens, sampler, prompt_len)
        if sig not in self._generate_jit:
            self._generate_jit[sig] = self._build_generate(*sig)
        key = key if key is not None else jax.random.PRNGKey(0)
        toks, logits = self._generate_jit[sig](self.params, batch, key)
        return GenerationResult(tokens=toks, logits_last=logits, steps=max_new_tokens)

    # -- fault tolerance ------------------------------------------------------
    @staticmethod
    def snapshot(cache, pos, tokens) -> dict[str, Any]:
        return {"cache": jax.device_get(cache), "pos": int(pos),
                "tokens": jax.device_get(tokens)}

    def restore(self, snap):
        return jax.device_put(snap["cache"]), jnp.int32(snap["pos"]), jnp.asarray(snap["tokens"])
