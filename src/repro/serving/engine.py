"""Inference engine: prefill + scanned decode with quantized or float weights.

Mirrors the paper's serving structure (Alg. 2): the "transformer controller"
is the jitted scan below, the quantized weights feed GQMV/GQMM via the
linear() dispatch, and batch-1 real-time decoding is the faithful setting
(batched decode is the TPU-native generalization). The weight format —
uniform int8 (paper W8A8), packed int4, or a per-layer-class mix — is
selected through the ``quantize`` argument (core/policy.py format maps).

Fault-tolerance hooks: ``snapshot()``/``restore()`` expose the generation
state (cache + position + tokens) so a preempted decode can resume on a
rebuilt mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import quantize_params, quantized_fraction
from repro.models.registry import Model
from repro.serving.sampling import make_sampler, sampler_sig


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array         # (b, max_new_tokens) sampled tokens
    logits_last: jax.Array    # (b, vocab) final-step logits
    steps: int


class InferenceEngine:
    """Uniform-length batched generation over any registry Model.

    ``quantize`` selects the PTQ applied to the weights:

      False          no quantization — the fp32 "PS baseline"
      True           the config's ``quant_format`` (default "int8", the
                     paper's group-wise W8A8)
      "int8"/"int4"  one registry format uniformly (core/quant.py)
      "mixed"        the per-layer-class preset: embeddings/classifier int8,
                     attention/FFN projections packed int4
      {class: fmt}   an explicit layer-class -> format map
                     (core/policy.py ``resolve_format_map``)
    """

    def __init__(self, model: Model, params, *, cache_len: int,
                 quantize: bool | str | Mapping[str, str | None] = False,
                 tp: int = 1, eos_id: int | None = None):
        self.model = model
        self.cfg = model.cfg
        self.cache_len = cache_len
        self.eos_id = eos_id
        if quantize is not False and quantize is not None:
            formats = self.cfg.quant_format if quantize is True else quantize
            params = quantize_params(params, self.cfg.group_size, tp=tp,
                                     formats=formats)
        self.params = params
        self.quantized_fraction = quantized_fraction(params)
        self._generate_jit: dict[tuple, Callable] = {}

    # -- one-step APIs (used by benchmarks and the dry-run) -----------------
    def prefill(self, batch):
        return self.model.prefill(self.params, batch, self.cache_len)

    def decode_step(self, token, cache, pos):
        """pos: scalar int32 or (b,) per-request position vector."""
        return self.model.decode(self.params, token, cache, pos)

    # -- full generation -----------------------------------------------------
    def _build_generate(self, max_new_tokens: int, sampler_name: str,
                        prompt_len: int, ragged: bool, sampler_kw=(),
                        paged: bool = False, block_size: int = 8):
        sampler = make_sampler(sampler_name, **dict(sampler_kw))
        model, cache_len = self.model, self.cache_len
        if paged:
            from repro.models.transformer import contiguous_to_paged

            # pad the prefill target up to whole blocks so the contiguous
            # rows reshape exactly into the pool
            cache_len = -(-cache_len // block_size) * block_size

        @jax.jit
        def run(params, batch, key):
            # independent streams for the first sample and the decode steps —
            # reusing `key` for both correlated tok0 with step 1's sample
            key0, key_steps = jax.random.split(key)
            logits, cache = model.prefill(params, batch, cache_len)
            tok0 = sampler(logits, key0)
            # ragged rows continue at their own true lengths (per-row scatter
            # commits); uniform batches keep the scalar position counter and
            # its donated dynamic-update-slice commit fast path
            if ragged:
                pos0 = batch["lengths"].astype(jnp.int32)
            else:
                pos0 = jnp.int32(prompt_len)
            if paged:
                # identity block tables: row i owns blocks [i*MB, (i+1)*MB) —
                # the uniform-batch shape of the block-table decode contract;
                # mixed-traffic pooling lives in serving/paged.py
                cache, table = contiguous_to_paged(cache, block_size)
                if not ragged:
                    pos0 = jnp.full((tok0.shape[0],), pos0, jnp.int32)

            def step(carry, k):
                tok, cache, pos, done = carry
                if paged:
                    logits, cache = model.decode_paged(params, tok, cache,
                                                       table, pos)
                else:
                    logits, cache = model.decode(params, tok, cache, pos)
                nxt = sampler(logits, k)
                if self.eos_id is not None:
                    nxt = jnp.where(done, self.eos_id, nxt)
                    done = done | (nxt == self.eos_id)
                return (nxt, cache, pos + 1, done), (nxt, logits)

            if self.eos_id is not None:
                done0 = tok0 == self.eos_id   # prompt may emit EOS immediately
            else:
                done0 = jnp.zeros(tok0.shape, jnp.bool_)
            keys = jax.random.split(key_steps, max_new_tokens)
            (_, cache, _, _), (toks, logit_seq) = jax.lax.scan(
                step, (tok0, cache, pos0, done0), keys
            )
            tokens = jnp.concatenate([tok0[None], toks[:-1]], axis=0)
            return jnp.moveaxis(tokens, 0, 1), logit_seq[-1]

        return run

    def generate(self, batch, max_new_tokens: int, *, sampler: str = "greedy",
                 sampler_kw=None, key=None, lengths=None, paged: bool = False,
                 block_size: int = 8) -> GenerationResult:
        """``lengths`` (b,) enables ragged right-padded prompts: row i's pads
        are masked in prefill, its first token is sampled from the logits at
        lengths[i]-1, and decode runs on per-request position counters.
        ``sampler_kw`` reaches the sampler (top_p's p / temperature).
        ``paged`` decodes through the block-table path over an
        identity-mapped block pool — token-identical to the contiguous path
        (the mixed-traffic scheduler is serving/paged.py)."""
        if paged and not self.model.supports_paged:
            raise ValueError(
                f"{self.cfg.arch_id}: model family has no paged decode path "
                "(GQA decoder_lm families only)"
            )
        if lengths is not None:
            lengths = jnp.asarray(lengths, jnp.int32)
            batch = dict(batch, lengths=lengths)
        elif "lengths" in batch:
            lengths = jnp.asarray(batch["lengths"], jnp.int32)
        if lengths is not None and not self.model.supports_lengths:
            raise ValueError(
                f"{self.cfg.arch_id}: model family does not support ragged "
                "lengths; batch by exact length instead (see serving/batching.py)"
            )
        prompt_len = batch["tokens"].shape[1]
        # validate up front: dynamic_update_slice clamps at the cache boundary,
        # which would silently overwrite the last slot instead of failing
        start_max = prompt_len if lengths is None else int(np.max(np.asarray(lengths)))
        need = max(prompt_len, start_max + max_new_tokens)
        if need > self.cache_len:
            raise ValueError(
                f"KV cache overflow: prompt_len={prompt_len} (max start "
                f"{start_max}) + max_new_tokens={max_new_tokens} needs "
                f"{need} slots but cache_len={self.cache_len}"
            )
        sig = (max_new_tokens, sampler, prompt_len, lengths is not None,
               sampler_sig(sampler_kw), paged, block_size)
        if sig not in self._generate_jit:
            self._generate_jit[sig] = self._build_generate(*sig)
        key = key if key is not None else jax.random.PRNGKey(0)
        toks, logits = self._generate_jit[sig](self.params, batch, key)
        return GenerationResult(tokens=toks, logits_last=logits, steps=max_new_tokens)

    # -- fault tolerance ------------------------------------------------------
    @staticmethod
    def snapshot(cache, pos, tokens, block_table=None) -> dict[str, Any]:
        """Generation state for resume-on-rebuilt-mesh. For the paged path
        the cache is the block POOL, so the block tables are part of the
        state — without them the pool rows are unaddressable."""
        snap = {"cache": jax.device_get(cache), "pos": np.asarray(pos),
                "tokens": jax.device_get(tokens)}
        if block_table is not None:
            snap["block_table"] = np.asarray(block_table)
        return snap

    def restore(self, snap):
        out = (jax.device_put(snap["cache"]),
               jnp.asarray(snap["pos"], jnp.int32),
               jnp.asarray(snap["tokens"]))
        if "block_table" in snap:
            return out + (jnp.asarray(snap["block_table"], jnp.int32),)
        return out
