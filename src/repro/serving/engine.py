"""Inference engine: prefill + scanned decode with quantized or float weights.

Mirrors the paper's serving structure (Alg. 2): the "transformer controller"
is the jitted scan below, the quantized weights feed GQMV/GQMM via the
linear() dispatch, and batch-1 real-time decoding is the faithful setting
(batched decode is the TPU-native generalization). The weight format —
uniform int8 (paper W8A8), packed int4, or a per-layer-class mix — is
selected through the ``quantize`` argument (core/policy.py format maps).

Fault-tolerance hooks: ``snapshot()``/``restore()`` expose the generation
state (cache + position + tokens) so a preempted decode can resume on a
rebuilt mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import quantize_params, quantized_fraction
from repro.models.registry import Model
from repro.serving.sampling import make_sampler, sampler_sig


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array         # (b, max_new_tokens) sampled tokens
    # (b, vocab) final-step logits. CAVEAT — the two decode paths differ:
    # the vanilla scan returns the distribution AFTER the last returned
    # token (the discarded step-max_new+1 sample's logits); the speculative
    # path returns the accept-path distribution that PRODUCED each row's
    # final kept token — one position earlier, since the chunk never fed
    # that token back through the model (and one row later than that when
    # an EOS truncated the chunk: the device clamp knows budgets, not EOS).
    # Don't compare across paths or resume sampling from the spec-path value.
    logits_last: jax.Array
    steps: int                # decode forward passes (spec: verify steps)
    # speculative-decode accounting (None for the vanilla path):
    # {"verify_steps", "generated", "drafted", "accepted"} — verify forward
    # passes, useful tokens DELIVERED (including each row's prefill-sampled
    # token; post-EOS / over-budget chunk tails excluded — same semantics
    # as the schedulers' last_spec_stats), proposed and accepted drafts.
    spec_stats: dict[str, int] | None = None


class InferenceEngine:
    """Uniform-length batched generation over any registry Model.

    ``quantize`` selects the PTQ applied to the weights:

      False          no quantization — the fp32 "PS baseline"
      True           the config's ``quant_format`` (default "int8", the
                     paper's group-wise W8A8)
      "int8"/"int4"  one registry format uniformly (core/quant.py)
      "mixed"        the per-layer-class preset: embeddings/classifier int8,
                     attention/FFN projections packed int4
      "mixed3"       the sub-int4 preset: attention/FFN at true 3-bit packing
      {class: fmt}   an explicit layer-class -> format map
                     (core/policy.py ``resolve_format_map``)

    ``kv_quant`` quantizes the KV cache itself ("int8" or "fp8"): contiguous
    and paged caches store rows at storage width with per-row f32 scale
    leaves, dequantized inside attention (models/attention.py). GQA
    decoder_lm families only; incompatible with speculative decode.
    """

    def __init__(self, model: Model, params, *, cache_len: int,
                 quantize: bool | str | Mapping[str, str | None] = False,
                 tp: int = 1, eos_id: int | None = None,
                 sanitize: bool | None = None, kv_quant: str | None = None):
        if kv_quant:
            from repro.models.attention import KV_STORE_DTYPES
            from repro.models.registry import build

            if kv_quant not in KV_STORE_DTYPES:
                raise ValueError(
                    f"unknown kv_quant format {kv_quant!r}; supported: "
                    f"{sorted(KV_STORE_DTYPES)}")
            if not model.supports_paged:
                # supports_paged == "GQA decoder_lm cache layouts": the same
                # families whose contiguous/paged KV rows the quantized
                # layout covers (MLA latent / recurrent-state caches do not)
                raise ValueError(
                    f"{model.cfg.arch_id}: kv_quant covers the GQA decoder_lm "
                    "cache layouts only (no MLA/recurrent/encdec)")
            if model.cfg.kv_quant != kv_quant:
                # rebuild so every model closure (init_cache, prefill,
                # decode, decode_paged) sees the threaded config
                model = build(dataclasses.replace(model.cfg, kv_quant=kv_quant))
        self.model = model
        self.cfg = model.cfg
        self.cache_len = cache_len
        self.eos_id = eos_id
        # repro-san (analysis/sanitizer.py, DESIGN.md §13): None defers to
        # the REPRO_SAN environment opt-in; schedulers built on this engine
        # inherit the resolved setting. Numerics checks arm BEFORE
        # quantization so a corrupted checkpoint is caught at init, with
        # param-path + layer-class attribution (core/policy.py).
        if sanitize is None:
            from repro.analysis.sanitizer import sanitize_enabled

            sanitize = sanitize_enabled()
        self.sanitize = bool(sanitize)
        if self.sanitize:
            from repro.core.quant import set_numerics_checks

            set_numerics_checks(True)
        if quantize is not False and quantize is not None:
            formats = self.cfg.quant_format if quantize is True else quantize
            params = quantize_params(params, self.cfg.group_size, tp=tp,
                                     formats=formats)
        self.params = params
        self.quantized_fraction = quantized_fraction(params)
        self._generate_jit: dict[tuple, Callable] = {}
        self._unbounded_state: bool | None = None

    @property
    def unbounded_state(self) -> bool:
        """True for cache_kind="state" families whose decode state is O(1)
        in ``cache_len`` (rwkv6): no cache leaf's shape depends on the cache
        length, so there is no capacity to overflow and the generate/serve
        length validation is skipped. Probed abstractly (eval_shape — no
        allocation) and cached; zamba2's shared-attention KV rows DO scale
        with cache_len, so it stays bounded."""
        if self._unbounded_state is None:
            if self.model.cache_kind != "state":
                self._unbounded_state = False
            else:
                dt = self.cfg.cdtype()
                a = jax.eval_shape(lambda: self.model.init_cache(1, 8, dt))
                b = jax.eval_shape(lambda: self.model.init_cache(1, 16, dt))
                self._unbounded_state = all(
                    x.shape == y.shape
                    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        return self._unbounded_state

    # -- one-step APIs (used by benchmarks and the dry-run) -----------------
    def prefill(self, batch):
        return self.model.prefill(self.params, batch, self.cache_len)

    def decode_step(self, token, cache, pos):
        """pos: scalar int32 or (b,) per-request position vector."""
        return self.model.decode(self.params, token, cache, pos)

    # -- full generation -----------------------------------------------------
    def _build_generate(self, max_new_tokens: int, sampler_name: str,
                        prompt_len: int, ragged: bool, sampler_kw=(),
                        paged: bool = False, block_size: int = 8):
        sampler = make_sampler(sampler_name, **dict(sampler_kw))
        model, cache_len = self.model, self.cache_len
        if paged:
            from repro.models.transformer import contiguous_to_paged

            # pad the prefill target up to whole blocks so the contiguous
            # rows reshape exactly into the pool
            cache_len = -(-cache_len // block_size) * block_size

        @jax.jit
        def run(params, batch, key):
            # independent streams for the first sample and the decode steps —
            # reusing `key` for both correlated tok0 with step 1's sample
            key0, key_steps = jax.random.split(key)
            logits, cache = model.prefill(params, batch, cache_len)
            tok0 = sampler(logits, key0)
            # ragged rows continue at their own true lengths (per-row scatter
            # commits); uniform batches keep the scalar position counter and
            # its donated dynamic-update-slice commit fast path
            if ragged:
                pos0 = batch["lengths"].astype(jnp.int32)
            else:
                pos0 = jnp.int32(prompt_len)
            if paged:
                # identity block tables: row i owns blocks [i*MB, (i+1)*MB) —
                # the uniform-batch shape of the block-table decode contract;
                # mixed-traffic pooling lives in serving/paged.py
                cache, table = contiguous_to_paged(cache, block_size)
                if not ragged:
                    pos0 = jnp.full((tok0.shape[0],), pos0, jnp.int32)

            def step(carry, k):
                tok, cache, pos, done = carry
                if paged:
                    logits, cache = model.decode_paged(params, tok, cache,
                                                       table, pos)
                else:
                    logits, cache = model.decode(params, tok, cache, pos)
                nxt = sampler(logits, k)
                if self.eos_id is not None:
                    nxt = jnp.where(done, self.eos_id, nxt)
                    done = done | (nxt == self.eos_id)
                return (nxt, cache, pos + 1, done), (nxt, logits)

            if self.eos_id is not None:
                done0 = tok0 == self.eos_id   # prompt may emit EOS immediately
            else:
                done0 = jnp.zeros(tok0.shape, jnp.bool_)
            keys = jax.random.split(key_steps, max_new_tokens)
            (_, cache, _, _), (toks, logit_seq) = jax.lax.scan(
                step, (tok0, cache, pos0, done0), keys
            )
            tokens = jnp.concatenate([tok0[None], toks[:-1]], axis=0)
            return jnp.moveaxis(tokens, 0, 1), logit_seq[-1]

        return run

    def generate(self, batch, max_new_tokens: int, *, sampler: str = "greedy",
                 sampler_kw=None, key=None, lengths=None, paged: bool = False,
                 block_size: int = 8, spec_k: int | None = None,
                 drafter=None) -> GenerationResult:
        """``lengths`` (b,) enables ragged right-padded prompts: row i's pads
        are masked in prefill, its first token is sampled from the logits at
        lengths[i]-1, and decode runs on per-request position counters.
        ``sampler_kw`` reaches the sampler (top_p's p / temperature).
        ``paged`` decodes through the block-table path over an
        identity-mapped block pool — token-identical to the contiguous path
        (the mixed-traffic scheduler is serving/paged.py).

        ``spec_k`` >= 2 switches decode to speculative chunks: each step
        verifies the current token plus ``spec_k - 1`` drafted candidates in
        ONE forward pass (serving/spec.py), producing 1..spec_k tokens per
        weight stream. ``drafter`` defaults to the zero-weight n-gram
        prompt-lookup drafter. Greedy speculative output is token-identical
        to vanilla decode (CI-gated, benchmarks/run.py spec)."""
        if paged and not self.model.supports_paged:
            raise ValueError(
                f"{self.cfg.arch_id}: model family has no paged decode path "
                "(GQA decoder_lm families only)"
            )
        if lengths is not None:
            lengths = jnp.asarray(lengths, jnp.int32)
            batch = dict(batch, lengths=lengths)
        elif "lengths" in batch:
            lengths = jnp.asarray(batch["lengths"], jnp.int32)
        if lengths is not None and not self.model.supports_lengths:
            raise ValueError(
                f"{self.cfg.arch_id}: model family does not support ragged "
                "lengths; batch by exact length instead (see serving/batching.py)"
            )
        prompt_len = batch["tokens"].shape[1]
        # validate up front: dynamic_update_slice clamps at the cache boundary,
        # which would silently overwrite the last slot instead of failing
        start_max = prompt_len if lengths is None else int(np.max(np.asarray(lengths)))
        # a verify chunk reads/writes score columns up to pos + spec_k - 1,
        # so the speculative path needs spec_k slots of slack past the
        # vanilla requirement
        need = max(prompt_len, start_max + max_new_tokens + (spec_k or 0))
        # unbounded-state families (rwkv6: O(1) recurrent state, no cache
        # axis) have nothing to overflow — any budget is servable
        if need > self.cache_len and not self.unbounded_state:
            raise ValueError(
                f"KV cache overflow: prompt_len={prompt_len} (max start "
                f"{start_max}) + max_new_tokens={max_new_tokens}"
                + (f" + spec_k={spec_k}" if spec_k else "")
                + f" needs {need} slots but cache_len={self.cache_len}"
            )
        if spec_k is not None:
            if spec_k < 2:
                raise ValueError(f"spec_k must be >= 2 (got {spec_k}): a "
                                 "chunk is the current token plus >=1 draft")
            if self.cfg.kv_quant:
                raise ValueError(
                    f"{self.cfg.arch_id}: speculative decode requires the "
                    "float KV layout (kv_quant off) — the verify chunk "
                    "scatters float rows the quantized cache cannot hold")
            if not self.model.supports_spec:
                raise ValueError(
                    f"{self.cfg.arch_id}: model family has no speculative "
                    "verify path (GQA decoder_lm families only)"
                )
            key = key if key is not None else jax.random.PRNGKey(0)
            return self._generate_spec(
                batch, max_new_tokens, spec_k, drafter, sampler=sampler,
                sampler_kw=sampler_kw, key=key, lengths=lengths, paged=paged,
                block_size=block_size,
            )
        sig = (max_new_tokens, sampler, prompt_len, lengths is not None,
               sampler_sig(sampler_kw), paged, block_size)
        if sig not in self._generate_jit:
            self._generate_jit[sig] = self._build_generate(*sig)
        key = key if key is not None else jax.random.PRNGKey(0)
        toks, logits = self._generate_jit[sig](self.params, batch, key)
        if self.sanitize:
            from repro.analysis.sanitizer import check_array

            check_array("generate.logits_last", logits)
        return GenerationResult(tokens=toks, logits_last=logits, steps=max_new_tokens)

    # -- speculative decode (serving/spec.py, DESIGN.md §10) -----------------
    def _spec_prefill_fn(self, prompt_len: int, sampler_name: str,
                         ragged: bool, sampler_kw, paged: bool,
                         block_size: int):
        sig = ("spec_prefill", prompt_len, sampler_name, ragged,
               sampler_sig(sampler_kw), paged, block_size)
        if sig not in self._generate_jit:
            sampler = make_sampler(sampler_name, **dict(sampler_kw or {}))
            model, cache_len = self.model, self.cache_len
            if paged:
                cache_len = -(-cache_len // block_size) * block_size

            @jax.jit
            def run(params, batch, key):
                logits, cache = model.prefill(params, batch, cache_len)
                tok0 = sampler(logits, key)
                if ragged:
                    pos0 = batch["lengths"].astype(jnp.int32)
                else:
                    pos0 = jnp.full((tok0.shape[0],), prompt_len, jnp.int32)
                if paged:
                    from repro.models.transformer import contiguous_to_paged

                    cache, table = contiguous_to_paged(cache, block_size)
                    return tok0, logits, cache, table, pos0
                return tok0, logits, cache, pos0

            self._generate_jit[sig] = run
        return self._generate_jit[sig]

    def _spec_step_fn(self, spec_k: int, sampler_name: str, sampler_kw,
                      paged: bool):
        from repro.serving.spec import build_verify_step

        sig = ("spec_step", spec_k, sampler_name, sampler_sig(sampler_kw), paged)
        if sig not in self._generate_jit:
            self._generate_jit[sig] = build_verify_step(
                self.model, sampler=sampler_name, sampler_kw=sampler_kw,
                paged=paged)
        return self._generate_jit[sig]

    def _generate_spec(self, batch, max_new: int, spec_k: int, drafter, *,
                       sampler: str, sampler_kw, key, lengths, paged: bool,
                       block_size: int) -> GenerationResult:
        """Host-driven speculative generation: draft on the host (the n-gram
        drafter needs the token history), verify+accept+commit in one jitted
        step. Each verify step advances every live row by 1..spec_k tokens
        for a single weight stream; rows progress unevenly, so positions are
        per-row vectors throughout (the ragged-decode machinery)."""
        from repro.serving.spec import NgramDrafter, draft_chunk, take_accepted

        drafter = drafter if drafter is not None else NgramDrafter()
        eos = self.eos_id
        toks_np = np.asarray(batch["tokens"])
        b, prompt_len = toks_np.shape
        lens = (np.asarray(lengths, np.int64) if lengths is not None
                else np.full((b,), prompt_len, np.int64))
        ragged = lengths is not None
        prefill = self._spec_prefill_fn(prompt_len, sampler, ragged,
                                        sampler_kw, paged, block_size)
        step = self._spec_step_fn(spec_k, sampler, sampler_kw, paged)

        key0, key_steps = jax.random.split(key)
        if paged:
            tok0_d, logits0, cache, table, pos = prefill(self.params, batch, key0)
        else:
            tok0_d, logits0, cache, pos = prefill(self.params, batch, key0)
        tok0 = np.asarray(tok0_d)
        ctx = [[int(t) for t in toks_np[i, : lens[i]]] for i in range(b)]
        outs: list[list[int]] = [[] for _ in range(b)]
        done = np.zeros((b,), bool)
        for i in range(b):
            ctx[i].append(int(tok0[i]))
            outs[i].append(int(tok0[i]))
            if eos is not None and int(tok0[i]) == eos:
                done[i] = True
        last_tok = tok0.astype(np.int32).copy()
        stats = {"verify_steps": 0, "generated": b, "drafted": 0, "accepted": 0}
        # seed with the prefill logits: a row that finishes before its first
        # verify step (max_new == 1, or EOS on the prefill-sampled token)
        # still reports the distribution that produced its final token
        logits_last = np.asarray(logits0, np.float32).copy()

        while True:
            live = np.asarray([not done[i] and len(outs[i]) < max_new
                               for i in range(b)])
            if not live.any():
                break
            chunk = draft_chunk(drafter, last_tok, live,
                                lambda i: ctx[i], spec_k)
            remaining = np.asarray(
                [max_new - len(outs[i]) for i in range(b)], np.int32)
            key_steps, ks = jax.random.split(key_steps)
            args = (self.params, jnp.asarray(chunk), cache)
            args += ((table,) if paged else ())
            args += (pos, jnp.asarray(live), jnp.asarray(remaining), ks)
            out_d, n_out_d, cache, pos, last_d = step(*args)
            # one transfer for everything the host needs this step
            out, n_out, last_np = jax.device_get((out_d, n_out_d, last_d))
            stats["verify_steps"] += 1
            for i in np.flatnonzero(live):
                new = take_accepted(out[i], n_out[i], remaining[i], eos,
                                    stats, spec_k)
                outs[i].extend(new)
                ctx[i].extend(new)
                if new:
                    last_tok[i] = new[-1]
                    # the accept-path logits of this row's newest token
                    # (see the GenerationResult logits_last caveat)
                    logits_last[i] = last_np[i]
                if eos is not None and new and new[-1] == eos:
                    done[i] = True
                if len(outs[i]) >= max_new:
                    done[i] = True

        pad = eos if eos is not None else 0
        tokens = np.full((b, max_new), pad, np.int32)
        for i in range(b):
            tokens[i, : len(outs[i])] = outs[i][:max_new]
        if self.sanitize:
            from repro.analysis.sanitizer import check_array

            check_array("generate_spec.logits_last", logits_last)
        return GenerationResult(
            tokens=jnp.asarray(tokens), logits_last=jnp.asarray(logits_last),
            steps=stats["verify_steps"], spec_stats=stats,
        )

    # -- fault tolerance ------------------------------------------------------
    @staticmethod
    def snapshot(cache, pos, tokens, block_table=None) -> dict[str, Any]:
        """Generation state for resume-on-rebuilt-mesh. For the paged path
        the cache is the block POOL, so the block tables are part of the
        state — without them the pool rows are unaddressable."""
        snap = {"cache": jax.device_get(cache), "pos": np.asarray(pos),
                "tokens": jax.device_get(tokens)}
        if block_table is not None:
            snap["block_table"] = np.asarray(block_table)
        return snap

    def restore(self, snap):
        out = (jax.device_put(snap["cache"]),
               jnp.asarray(snap["pos"], jnp.int32),
               jnp.asarray(snap["tokens"]))
        if "block_table" in snap:
            return out + (jnp.asarray(snap["block_table"], jnp.int32),)
        return out
