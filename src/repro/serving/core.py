"""The scheduling core: ONE serving loop, pluggable per-slot cache adapters.

Every continuous-batching mode is the same host loop — validate, admit
pending requests into fixed decode slots (one batched prefill per admission
group), decode in jitted rounds, finish slots at EOS/budget, finalize
Responses in arrival order. What differs between modes is only HOW a slot's
persistent decode state is laid out and addressed:

- ``ContiguousAdapter`` — one ``cache_len``-wide KV row per slot (the
  original ``SlotScheduler`` cache), batch on axis 1 of every leaf.
- ``PagedAdapter`` (serving/paged.py) — a ``BlockPool`` of fixed-size KV
  blocks behind per-slot block tables; admission is reservation-gated and
  blocks are allocated on demand / reclaimed the step a slot finishes.
- ``RecurrentAdapter`` — O(1) per-slot recurrent state (rwkv6, zamba2's SSM
  backbone): continuous batching is a state gather/scatter, no paging and —
  for fully O(1) families — no cache capacity to validate at all.

``SchedulerCore`` owns the queue, the slots, the budgets, the speculative
draft/accept bookkeeping and the Response finalization; adapters own the
jitted device programs (prefill/insert/decode/verify). Adapters return
DEVICE arrays; the core performs the single host sync per admission wave and
per round, so the host-sync round-trip budget (DESIGN.md §7,
analysis/host_sync.py) is enforced lexically on one loop instead of one copy
per scheduler (DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import make_sampler

__all__ = [
    "CacheAdapter",
    "ContiguousAdapter",
    "RecurrentAdapter",
    "Request",
    "Response",
    "SchedulerCore",
    "bucket_length",
    "finalize_tokens",
    "make_response",
    "pad_bucket",
]


@dataclasses.dataclass
class Request:
    id: int
    tokens: list[int]
    # per-request decode budget; None falls back to the serve call's
    # max_new_tokens. Mixed budgets are where continuous batching pays off:
    # bucketed decode drags every row to its bucket's longest budget, the
    # slot schedulers free and refill each slot at its own.
    max_new: int | None = None


@dataclasses.dataclass
class Response:
    id: int
    tokens: np.ndarray
    # true generated length: tokens[:length] are real, the rest is padding
    # (EOS, or 0 when the engine has no eos_id — indistinguishable from a
    # real vocab-0 token, which is exactly why the length rides along).
    length: int | None = None


def finalize_tokens(toks: list[int], budget: int, eos: int | None):
    """Trim at EOS, pad to ``budget``; returns (tokens (budget,), true length).

    ``length`` counts the real generated tokens (including the EOS itself);
    callers must not infer it from the pad value — with ``eos None`` the pad
    token 0 is a legal vocab id."""
    t = toks[:budget]
    if eos is not None and eos in t:
        t = t[: t.index(eos) + 1]
    length = len(t)
    t = t + [eos if eos is not None else 0] * (budget - length)
    return np.asarray(t, np.int32), length


def make_response(req: Request, toks: list[int], budget: int,
                  eos: int | None) -> Response:
    """The one Response construction path for every serving mode (bucketed,
    continuous, recurrent, paged): trim at EOS, pad to the request's budget,
    carry the true generated length. Keeping EOS/length semantics in a
    single call site is what makes the cross-mode parity tests meaningful."""
    tokens, length = finalize_tokens(toks, budget, eos)
    return Response(id=req.id, tokens=tokens, length=length)


def bucket_length(n: int, *, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


def pad_bucket(reqs: Sequence[Request], length: int, pad_id: int = 0):
    """Right-pad to ``length``; returns (tokens (b, length), true lengths)."""
    toks = np.full((len(reqs), length), pad_id, np.int32)
    lens = np.zeros((len(reqs),), np.int32)
    for i, r in enumerate(reqs):
        toks[i, : len(r.tokens)] = r.tokens
        lens[i] = len(r.tokens)
    return toks, lens


# ---------------------------------------------------------------------------
# cache adapters
# ---------------------------------------------------------------------------

class CacheAdapter:
    """Per-slot cache policy behind ``SchedulerCore``: alloc / insert /
    commit / free / snapshot. The protocol verbs map onto the loop as:

      alloc    ``can_admit`` / ``on_admit``  (paged: reservation-gated block
               allocation; contiguous/recurrent: a free slot IS the alloc)
      insert   ``prefill`` + ``insert``      (batched prefill rows scattered
               into the admitted slots)
      commit   ``decode_round`` / ``verify_round``  (jitted programs that
               advance the cache in place — buffers donated)
      free     ``on_finish``                 (paged: blocks back to the pool,
               table row sunk; others: freeing the slot index is enough)
      snapshot ``snapshot``                  (host copy of per-slot state,
               for preemption/debug)

    Adapters must return DEVICE values from prefill/decode/verify — the core
    owns the one host sync per admission wave and per round."""

    kind: str = "abstract"
    spec_capable: bool = False

    def bind(self, core, *, sampler: str, sampler_kw) -> None:
        """Attach to a core and build the jitted device programs."""
        raise NotImplementedError

    def validate(self, requests, budget, slack: int) -> None:
        """Reject requests that could never be served (capacity/layout)."""

    def begin_serve(self):
        """Fresh per-serve device cache (plus any host-side pool state)."""
        raise NotImplementedError

    def can_admit(self, r: Request, budget: int) -> bool:
        return True

    def on_admit(self, s: int, r: Request, budget: int) -> None:
        """Per-slot allocation at admission (paged: prompt blocks + table)."""

    def group_len(self, n: int) -> int:
        """Padded prefill length for an ``n``-token prompt; admission groups
        share one batched prefill per distinct value."""
        raise NotImplementedError

    def prefill(self, length: int):
        """Jitted (params, toks, lens, key) -> (first tokens, cache rows)."""
        raise NotImplementedError

    def insert(self, cache, rows, group, length: int):
        """Scatter prefill ``rows`` into ``group``'s slots; returns cache."""
        raise NotImplementedError

    def before_round(self, pos, live) -> None:
        """Pre-round host bookkeeping (paged: on-demand block growth)."""

    def check_positions(self, pos, live) -> None:
        """Assert live positions are addressable (cache edge, table edge)."""

    def decode_round(self, params, tok, cache, pos, live, remaining, keys):
        """One jitted decode round -> device (toks (steps, b), steps, cache,
        pos). ``steps`` may be a device scalar (paged early exit) or a plain
        int; the core resolves it inside its single round sync."""
        raise NotImplementedError

    def verify_round(self, params, chunk, cache, pos, live, remaining, key):
        """One jitted speculative verify round -> device (out (b, k), n_out
        (b,), cache, pos). Only ``spec_capable`` adapters implement this."""
        raise NotImplementedError(f"{self.kind}: no speculative verify path")

    def on_finish(self, s: int) -> None:
        """Free slot ``s``'s allocation (the core froze its tok/pos)."""

    def end_serve(self) -> None:
        """Post-serve bookkeeping (paged: pool high-water accounting)."""

    def snapshot(self, cache, slots):
        """Host copy of the per-slot cache state for ``slots``."""
        raise NotImplementedError

    def san_state(self) -> dict:
        """repro-san registration (analysis/sanitizer.py): the adapter's
        host allocator state as ``{"pool": BlockPool | None, "table":
        block-table ndarray | None}``. Every concrete adapter must define
        this (the ``adapter-lifecycle`` checker enforces it) so the shadow
        tracker can mirror whatever the adapter allocates."""
        raise NotImplementedError(f"{self.kind}: adapter registers no "
                                  "sanitizer state (san_state)")


class ContiguousAdapter(CacheAdapter):
    """The original ``SlotScheduler`` cache: one ``cache_len``-wide cache row
    per slot, batch on axis 1 of every leaf (``Model.insert_slots`` /
    ``Model.gather_slots``), live positions bounded by ``cache_len``."""

    kind = "contiguous"
    spec_capable = True

    def __init__(self, engine):
        if not engine.model.supports_lengths:
            raise ValueError(
                f"{engine.cfg.arch_id}: continuous batching needs length-aware "
                "prefill and per-request decode positions (decoder_lm families)"
            )
        self.engine = engine

    def bind(self, core, *, sampler, sampler_kw):
        engine = self.engine
        self.core = core
        self._prefill_jit: dict[int, callable] = {}
        if core.spec_k is not None:
            from repro.serving.spec import build_verify_step

            # verify -> accept -> commit-accepted-prefix in one jitted
            # program; per-slot budgets and the live mask clamp the commit
            self._verify_step = build_verify_step(
                engine.model, sampler=sampler, sampler_kw=sampler_kw,
                paged=False)

        model, sample = engine.model, core._sampler

        # the cache is donated: the core always rebinds it to the result,
        # and without donation XLA keeps both buffers live across every
        # chunk — a full extra cache of device memory
        @partial(jax.jit, donate_argnums=(2,))
        def decode_chunk(params, tok, cache, pos, live, keys):
            # ``live`` (b,) freezes finished/empty slots: their token and
            # position stop advancing, so a slot idling to the chunk
            # boundary keeps committing the SAME in-bounds cache slot of its
            # own (dead) row instead of drifting past cache_len, where the
            # commit would clamp/drop against the cache edge.
            def step(carry, k):
                tok, cache, pos = carry
                logits, cache = model.decode(params, tok, cache, pos)
                nxt = sample(logits, k)
                nxt = jnp.where(live, nxt, tok)
                pos = jnp.where(live, pos + 1, pos)
                return (nxt, cache, pos), nxt

            (tok, cache, pos), toks = jax.lax.scan(step, (tok, cache, pos), keys)
            return toks, cache, pos

        @partial(jax.jit, donate_argnums=(0,))
        def insert_slots(cache, rows, slots):
            return model.insert_slots(cache, rows, slots)

        self._decode_chunk = decode_chunk
        self._insert = insert_slots

    def validate(self, requests, budget, slack):
        cache_len = self.engine.cache_len
        for r in requests:
            need = max(bucket_length(len(r.tokens)),
                       len(r.tokens) + budget(r) + slack)
            if need > cache_len:
                raise ValueError(
                    f"request {r.id}: len={len(r.tokens)} + "
                    f"max_new={budget(r)}"
                    + (f" + spec_k={slack}" if slack else "")
                    + f" needs {need} cache slots "
                    f"but cache_len={cache_len}"
                )

    def begin_serve(self):
        engine = self.engine
        return engine.model.init_cache(
            self.core.slots, engine.cache_len, engine.cfg.cdtype())

    def group_len(self, n):
        return bucket_length(n)

    def prefill(self, length):
        """Jitted batched prefill+sample, cached per padded group length
        (retraces per admission-group size via jit's shape cache)."""
        if length not in self._prefill_jit:
            model, cache_len = self.engine.model, self.engine.cache_len
            sample = self.core._sampler

            @jax.jit
            def prefill_group(params, toks, lens, key):
                logits, cache = model.prefill(
                    params, {"tokens": toks, "lengths": lens}, cache_len
                )
                return sample(logits, key), cache

            self._prefill_jit[length] = prefill_group
        return self._prefill_jit[length]

    def insert(self, cache, rows, group, length):
        del length
        slots_g = jnp.asarray([s for s, _ in group], jnp.int32)
        return self._insert(cache, rows, slots_g)

    def check_positions(self, pos, live):
        cache_len = self.engine.cache_len
        assert not live.any() or int(pos[live].max()) < cache_len, (
            f"live slot position escaped the cache: {pos[live]} "
            f">= cache_len={cache_len}")

    def decode_round(self, params, tok, cache, pos, live, remaining, keys):
        del remaining   # chunk rounds run full length; budgets live on host
        toks, cache, pos = self._decode_chunk(params, tok, cache, pos, live,
                                              keys)
        return toks, keys.shape[0], cache, pos

    def verify_round(self, params, chunk, cache, pos, live, remaining, key):
        out, n_out, cache, pos, _ = self._verify_step(
            params, chunk, cache, pos, live, remaining, key)
        return out, n_out, cache, pos

    def snapshot(self, cache, slots):
        san = getattr(self.core, "sanitizer", None)
        if san is not None:
            san.on_snapshot(slots)
        rows = self.engine.model.gather_slots(
            cache, jnp.asarray(slots, jnp.int32))
        return jax.device_get(rows)

    def san_state(self):
        # slot rows are the allocation: no pool, no table to shadow
        return {"pool": None, "table": None}


class RecurrentAdapter(ContiguousAdapter):
    """Slot-state continuous batching for recurrent families (rwkv6, zamba2's
    SSM backbone): the per-slot "cache" is O(1) recurrent state, so admission
    is a state gather/scatter — no paging, no per-slot KV rows to size. Two
    deltas from the contiguous adapter:

    - a recurrent prefill cannot mask pads out of the recurrence, so
      admission groups by EXACT prompt length and the batched prefill sees
      no pad tokens;
    - position bounds only exist where the state still carries a bounded
      cache axis (zamba2's shared-attention KV rows); a fully O(1) family
      (rwkv6) has nothing to overflow and serves any budget
      (``engine.unbounded_state``)."""

    kind = "recurrent"
    spec_capable = False

    def __init__(self, engine):
        if engine.model.cache_kind != "state":
            raise ValueError(
                f"{engine.cfg.arch_id}: the recurrent adapter serves "
                "cache_kind='state' families only"
            )
        # deliberately no supports_lengths gate: exact-length groups make
        # per-row lengths unnecessary
        self.engine = engine

    def validate(self, requests, budget, slack):
        engine = self.engine
        if engine.unbounded_state:
            return
        for r in requests:
            need = len(r.tokens) + budget(r) + slack
            if need > engine.cache_len:
                raise ValueError(
                    f"request {r.id}: len={len(r.tokens)} + "
                    f"max_new={budget(r)} needs {need} cache slots "
                    f"but cache_len={engine.cache_len}"
                )

    def group_len(self, n):
        # exact length: no pad token may enter the recurrence
        return n

    def prefill(self, length):
        """Jitted batched prefill+sample, cached per EXACT prompt length
        (retraces per admission-group size via jit's shape cache)."""
        if length not in self._prefill_jit:
            model, cache_len = self.engine.model, self.engine.cache_len
            sample = self.core._sampler

            @jax.jit
            def prefill_group(params, toks, lens, key):
                del lens   # exact-length groups: every row IS its length
                logits, state = model.prefill(
                    params, {"tokens": toks}, cache_len)
                return sample(logits, key), state

            self._prefill_jit[length] = prefill_group
        return self._prefill_jit[length]

    def check_positions(self, pos, live):
        if self.engine.unbounded_state:
            return
        ContiguousAdapter.check_positions(self, pos, live)

    def san_state(self):
        # explicit (not just inherited): the shadow-coverage contract is
        # that every concrete adapter declares its sanitizer state in its
        # own body, so the adapter-lifecycle checker can verify it
        return {"pool": None, "table": None}


# ---------------------------------------------------------------------------
# the scheduling core
# ---------------------------------------------------------------------------

class SchedulerCore:
    """The one serving loop: admission -> grouped prefill -> jitted
    decode/verify rounds -> finish -> finalize, over any ``CacheAdapter``.

    Responses always contain exactly the request's budget of tokens;
    sequences that hit EOS early are padded with EOS (``make_response`` —
    parity across every mode). The adapter's jitted programs live for the
    core's lifetime, so a long-lived core serves successive traces with no
    recompilation.

    Host-sync budget (pinned lexically by analysis/host_sync.py): ONE
    ``jax.device_get`` per admission wave and ONE per decode/verify round.
    """

    def __init__(self, engine, adapter: CacheAdapter, *, slots: int = 4,
                 chunk: int = 4, sampler: str = "greedy", sampler_kw=None,
                 spec_k: int | None = None, drafter=None,
                 sanitize: bool | None = None):
        if spec_k is not None:
            if spec_k < 2:
                raise ValueError(f"spec_k must be >= 2, got {spec_k}")
            if not adapter.spec_capable or not engine.model.supports_spec:
                raise ValueError(
                    f"{engine.cfg.arch_id}: model family has no speculative "
                    "verify path (GQA decoder_lm families only)"
                )
        self.engine = engine
        self.adapter = adapter
        self.slots = slots
        self.chunk = chunk
        self.spec_k = spec_k
        self._sampler = make_sampler(sampler, **dict(sampler_kw or {}))
        self.last_positions = None     # final per-slot positions (debug)
        self.last_spec_stats = None    # per-serve speculative accounting
        if spec_k is not None:
            from repro.serving.spec import NgramDrafter

            self._drafter = drafter if drafter is not None else NgramDrafter()
        # repro-san (DESIGN.md §13): None inherits the engine's setting, so
        # every scheduler built over a sanitized engine is sanitized too
        san_on = (getattr(engine, "sanitize", False) if sanitize is None
                  else bool(sanitize))
        self.sanitizer = None
        if san_on:
            from repro.analysis.sanitizer import Sanitizer

            self.sanitizer = Sanitizer(self)
        adapter.bind(self, sampler=sampler, sampler_kw=sampler_kw)

    def serve(self, requests: Sequence[Request], max_new_tokens: int,
              *, key=None) -> list[Response]:
        engine, adapter, B = self.engine, self.adapter, self.slots
        eos = engine.eos_id

        def budget(r: Request) -> int:
            return r.max_new if r.max_new is not None else max_new_tokens

        # a verify chunk touches score columns up to pos + spec_k - 1, so
        # speculative serving needs spec_k slots of slack past the vanilla
        # requirement (frozen slots included: their chunks still index)
        slack = self.spec_k or 0
        adapter.validate(requests, budget, slack)

        cache = adapter.begin_serve()
        san = self.sanitizer
        if san is not None:
            cache = san.begin_serve(adapter, cache)
        pending = deque(requests)
        slot_req: list[Request | None] = [None] * B
        slot_toks: list[list[int]] = [[] for _ in range(B)]
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        live = np.zeros((B,), bool)
        remaining = np.zeros((B,), np.int32)
        out: dict[int, Response] = {}
        key = key if key is not None else jax.random.PRNGKey(0)
        self.last_spec_stats = (
            {"verify_steps": 0, "generated": 0, "drafted": 0, "accepted": 0}
            if self.spec_k is not None else None)

        def finish(s: int):
            nonlocal cache
            r = slot_req[s]
            out[r.id] = make_response(r, slot_toks[s], budget(r), eos)
            slot_req[s], slot_toks[s] = None, []
            remaining[s] = 0
            live[s] = False                # token and position stay frozen
            adapter.on_finish(s)
            if san is not None:
                # freeze the slot shadow, audit the request's blocks, and
                # poison its frees NOW — before any re-allocation can write
                cache = san.on_request_finish(cache, s, r.id, pos[s])

        while pending or live.any():
            # admission: pop pending in arrival order while a slot (and, for
            # gated adapters, worst-case capacity) is available; one batched
            # prefill per distinct group length, one scatter-insert per group
            free_slots = [s for s in range(B) if slot_req[s] is None]
            admitted: dict[int, list[tuple[int, Request]]] = defaultdict(list)
            while free_slots and pending:
                r = pending[0]
                if not adapter.can_admit(r, budget(r)):
                    break                  # backpressure: decode frees space
                pending.popleft()
                s = free_slots.pop(0)
                slot_req[s], slot_toks[s] = r, []
                live[s] = True
                if san is not None:
                    san.on_admit(s, r)
                adapter.on_admit(s, r, budget(r))
                admitted[adapter.group_len(len(r.tokens))].append((s, r))
            staged: list[tuple[list[tuple[int, Request]], jax.Array]] = []
            for length, group in admitted.items():
                if san is not None:
                    san.on_prefill_group(group, length)
                toks_np, lens_np = pad_bucket([r for _, r in group], length)
                key, kp = jax.random.split(key)
                t0_d, rows = adapter.prefill(length)(
                    engine.params, jnp.asarray(toks_np), jnp.asarray(lens_np),
                    kp)
                cache = adapter.insert(cache, rows, group, length)
                staged.append((group, t0_d))
            if staged:
                # ONE host round-trip for the whole admission wave, not one
                # per group (host-sync round-trip budget: admission + round)
                first_toks = jax.device_get([t for _, t in staged])
                for (group, _), t0 in zip(staged, first_toks):
                    for (s, r), t in zip(group, t0):
                        slot_toks[s] = [int(t)]
                        tok[s], pos[s] = int(t), len(r.tokens)
                        remaining[s] = budget(r) - 1
                        if self.last_spec_stats is not None:
                            # the prefill-sampled token is delivered work too
                            # — keeps 'generated' comparable with engine
                            # spec_stats
                            self.last_spec_stats["generated"] += 1
                        if budget(r) <= 1 or (eos is not None and int(t) == eos):
                            finish(s)

            if not live.any():
                if pending:
                    continue
                break

            adapter.before_round(pos, live)
            adapter.check_positions(pos, live)
            if san is not None:
                cache = san.pre_round(cache)
            key, kc = jax.random.split(key)
            if self.spec_k is not None:
                # speculative round: draft on the host (per-slot token
                # history), verify the chunk in one forward pass, keep the
                # accepted prefix — 1..spec_k tokens per weight stream
                from repro.serving.spec import draft_chunk, take_accepted

                K = self.spec_k
                chunk_np = draft_chunk(
                    self._drafter, tok, live,
                    lambda s: slot_req[s].tokens + slot_toks[s], K)
                out_d, n_out_d, cache, pos_d = adapter.verify_round(
                    engine.params, jnp.asarray(chunk_np), cache,
                    jnp.asarray(pos), jnp.asarray(live),
                    jnp.asarray(remaining), kc)
                out_np, n_out, pos = jax.device_get((out_d, n_out_d, pos_d))
                pos = pos.copy()
                st = self.last_spec_stats
                st["verify_steps"] += 1
                for s in np.flatnonzero(live):
                    slot_toks[s].extend(take_accepted(
                        out_np[s], n_out[s], remaining[s], eos, st, K))
                    tok[s] = slot_toks[s][-1]
                    n = budget(slot_req[s])
                    remaining[s] = n - len(slot_toks[s])
                    if len(slot_toks[s]) >= n or (
                            eos is not None and eos in slot_toks[s][:n]):
                        finish(s)
                if san is not None:
                    san.check_round(cache, pos, live)
                continue
            toks_d, steps_d, cache, pos_d = adapter.decode_round(
                engine.params, jnp.asarray(tok), cache, jnp.asarray(pos),
                jnp.asarray(live), jnp.asarray(remaining),
                jax.random.split(kc, self.chunk))
            # ONE host sync per round: separate transfers for the step
            # count, the chunk tokens and the positions would each force
            # their own device round-trip on the hot loop
            steps, toks_all, pos = jax.device_get((steps_d, toks_d, pos_d))
            toks_np = toks_all[: int(steps)]              # (steps, B)
            pos = pos.copy()
            for s in range(B):
                if not live[s]:
                    continue
                n = budget(slot_req[s])
                slot_toks[s].extend(int(t) for t in toks_np[:, s])
                tok[s] = slot_toks[s][-1]
                remaining[s] = n - len(slot_toks[s])
                done = len(slot_toks[s]) >= n
                if eos is not None and eos in slot_toks[s][:n]:
                    done = True
                if done:
                    finish(s)
            if san is not None:
                san.check_round(cache, pos, live)

        self.last_positions = pos.copy()
        if san is not None:
            san.finalize()
        adapter.end_serve()
        return [out[r.id] for r in requests]
