"""Variable-length request batching for the inference engine.

Real traffic is ragged. Two serving modes, both length-aware:

- **bucketed** — requests are right-padded to power-of-two buckets and each
  bucket runs one prefill+decode. True lengths ride along in the batch
  (``batch["lengths"]``): prefill masks pad keys, the first token is sampled
  from each row's logits at ``lengths[i]-1``, and decode runs per-request
  position counters, so a padded row decodes exactly like its unpadded self.
- **continuous** (``SlotScheduler``) — a fixed-width decode batch of slots.
  Finished slots (EOS or budget exhausted) are refilled from the queue by a
  single-request prefill written into the slot's cache row, so the decode
  pipeline stays full across mixed-length traffic instead of draining one
  bucket at a time. Decode runs in jitted chunks of ``chunk`` steps between
  admission points (continuous-batching-lite: a slot that finishes mid-chunk
  idles until the chunk boundary).

Families whose prefill carries sequential state through every token (rwkv6,
zamba2's SSM backbone, enc-dec) cannot mask pads out of a recurrence; for
them the bucketed mode groups by exact length (no pads, always correct) and
the continuous mode is unavailable.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampling import make_sampler


@dataclasses.dataclass
class Request:
    id: int
    tokens: list[int]
    # per-request decode budget; None falls back to the serve call's
    # max_new_tokens. Mixed budgets are where continuous batching pays off:
    # bucketed decode drags every row to its bucket's longest budget, the
    # slot scheduler frees and refills each slot at its own.
    max_new: int | None = None


@dataclasses.dataclass
class Response:
    id: int
    tokens: np.ndarray


def bucket_length(n: int, *, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


def pad_bucket(reqs: Sequence[Request], length: int, pad_id: int = 0):
    """Right-pad to ``length``; returns (tokens (b, length), true lengths)."""
    toks = np.full((len(reqs), length), pad_id, np.int32)
    lens = np.zeros((len(reqs),), np.int32)
    for i, r in enumerate(reqs):
        toks[i, : len(r.tokens)] = r.tokens
        lens[i] = len(r.tokens)
    return toks, lens


# ---------------------------------------------------------------------------
# bucketed mode
# ---------------------------------------------------------------------------

def serve_bucketed(engine, requests: Sequence[Request], max_new_tokens: int,
                   *, sampler: str = "greedy", key=None) -> list[Response]:
    """Bucket requests, generate per bucket, reassemble in arrival order.

    Length-aware families bucket by padded power-of-two length and pass the
    true lengths through to the engine; recurrent families group by exact
    length so no pad token ever enters the recurrence."""
    ragged = engine.model.supports_lengths
    buckets: dict[int, list[Request]] = defaultdict(list)
    for r in requests:
        n = len(r.tokens)
        buckets[bucket_length(n) if ragged else n].append(r)

    base_key = key if key is not None else jax.random.PRNGKey(0)
    out: dict[int, Response] = {}
    for length in sorted(buckets):
        reqs = buckets[length]
        toks, lens = pad_bucket(reqs, length)
        budgets = [r.max_new if r.max_new is not None else max_new_tokens
                   for r in reqs]
        # one generate per bucket runs to the bucket's longest budget; rows
        # with smaller budgets are decoded past their end and trimmed — the
        # serialization+overrun cost the slot scheduler removes
        res = engine.generate(
            {"tokens": jnp.asarray(toks)}, max(budgets), sampler=sampler,
            # independent PRNG stream per bucket — one shared key would make
            # every bucket sample the same per-step randomness
            key=jax.random.fold_in(base_key, length),
            lengths=lens if ragged else None,
        )
        gen = np.asarray(res.tokens)
        for i, r in enumerate(reqs):
            out[r.id] = Response(id=r.id, tokens=gen[i, : budgets[i]])
    return [out[r.id] for r in requests]


# ---------------------------------------------------------------------------
# continuous mode
# ---------------------------------------------------------------------------

class SlotScheduler:
    """Slot-based continuous batching over one engine.

    Holds the jitted decode-chunk and per-bucket prefill programs, so a
    long-lived scheduler serves successive traces with no recompilation.
    Responses always contain exactly ``max_new_tokens`` tokens; sequences
    that hit EOS early are padded with EOS (parity with the bucketed mode).
    """

    def __init__(self, engine, *, slots: int = 4, chunk: int = 4,
                 sampler: str = "greedy"):
        if not engine.model.supports_lengths:
            raise ValueError(
                f"{engine.cfg.arch_id}: continuous batching needs length-aware "
                "prefill and per-request decode positions (decoder_lm families)"
            )
        self.engine = engine
        self.slots = slots
        self.chunk = chunk
        self._sampler = make_sampler(sampler)
        self._prefill_jit: dict[int, callable] = {}

        model, sample = engine.model, self._sampler

        @jax.jit
        def decode_chunk(params, tok, cache, pos, keys):
            def step(carry, k):
                tok, cache, pos = carry
                logits, cache = model.decode(params, tok, cache, pos)
                nxt = sample(logits, k)
                return (nxt, cache, pos + 1), nxt

            (tok, cache, pos), toks = jax.lax.scan(step, (tok, cache, pos), keys)
            return toks, cache, pos

        @jax.jit
        def insert(cache, rows, slots):
            # every decoder_lm cache layout keeps batch on axis 1 of each
            # (layers, b, ...) leaf; the prefill rows replace whole slots
            return jax.tree.map(
                lambda big, small: big.at[:, slots].set(small), cache, rows
            )

        self._decode_chunk = decode_chunk
        self._insert = insert

    def _prefill_fn(self, length: int):
        """Jitted batched prefill+sample, cached per padded bucket length
        (retraces per admission-group size via jit's shape cache)."""
        if length not in self._prefill_jit:
            model, cache_len, sample = self.engine.model, self.engine.cache_len, self._sampler

            @jax.jit
            def prefill_group(params, toks, lens, key):
                logits, cache = model.prefill(
                    params, {"tokens": toks, "lengths": lens}, cache_len
                )
                return sample(logits, key), cache

            self._prefill_jit[length] = prefill_group
        return self._prefill_jit[length]

    def serve(self, requests: Sequence[Request], max_new_tokens: int,
              *, key=None) -> list[Response]:
        engine, B, chunk = self.engine, self.slots, self.chunk
        eos = engine.eos_id

        def budget(r: Request) -> int:
            return r.max_new if r.max_new is not None else max_new_tokens

        for r in requests:
            need = max(bucket_length(len(r.tokens)), len(r.tokens) + budget(r))
            if need > engine.cache_len:
                raise ValueError(
                    f"request {r.id}: len={len(r.tokens)} + "
                    f"max_new={budget(r)} needs {need} cache slots "
                    f"but cache_len={engine.cache_len}"
                )

        cache = engine.model.init_cache(B, engine.cache_len, engine.cfg.cdtype())
        pending = deque(requests)
        slot_req: list[Request | None] = [None] * B
        slot_toks: list[list[int]] = [[] for _ in range(B)]
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        out: dict[int, Response] = {}
        key = key if key is not None else jax.random.PRNGKey(0)

        def finish(s: int):
            r = slot_req[s]
            n = budget(r)
            t = slot_toks[s][:n]
            if eos is not None and eos in t:
                t = t[: t.index(eos) + 1]
            t = t + [eos if eos is not None else 0] * (n - len(t))
            out[r.id] = Response(id=r.id, tokens=np.asarray(t, np.int32))
            slot_req[s] = None
            slot_toks[s] = []

        while pending or any(r is not None for r in slot_req):
            # refill free slots: one batched prefill per bucket length, one
            # scatter-insert per group (keeps host round-trips off the
            # per-request path)
            free = [s for s in range(B) if slot_req[s] is None]
            admitted: dict[int, list[Request]] = defaultdict(list)
            take = [pending.popleft() for _ in range(min(len(free), len(pending)))]
            for r in take:
                admitted[bucket_length(len(r.tokens))].append(r)
            for length, group in admitted.items():
                slots_g, free = free[: len(group)], free[len(group):]
                toks_np, lens_np = pad_bucket(group, length)
                key, kp = jax.random.split(key)
                t0, rows = self._prefill_fn(length)(
                    engine.params, jnp.asarray(toks_np), jnp.asarray(lens_np), kp
                )
                cache = self._insert(cache, rows, jnp.asarray(slots_g, jnp.int32))
                t0 = np.asarray(t0)
                for s, r, t in zip(slots_g, group, t0):
                    slot_req[s], slot_toks[s] = r, [int(t)]
                    tok[s], pos[s] = int(t), len(r.tokens)
                    if budget(r) <= 1 or (eos is not None and int(t) == eos):
                        finish(s)

            if not any(r is not None for r in slot_req):
                if pending:
                    continue
                break

            key, kc = jax.random.split(key)
            toks_d, cache, pos_d = self._decode_chunk(
                engine.params, jnp.asarray(tok), cache, jnp.asarray(pos),
                jax.random.split(kc, chunk),
            )
            toks_np = np.asarray(toks_d)                # (chunk, B)
            tok = np.asarray(toks_np[-1]).copy()
            pos = np.asarray(pos_d).copy()
            for s in range(B):
                if slot_req[s] is None:
                    continue
                n = budget(slot_req[s])
                slot_toks[s].extend(int(t) for t in toks_np[:, s])
                done = len(slot_toks[s]) >= n
                if eos is not None and eos in slot_toks[s][:n]:
                    done = True
                if done:
                    finish(s)

        return [out[r.id] for r in requests]


def serve_continuous(engine, requests: Sequence[Request], max_new_tokens: int,
                     *, sampler: str = "greedy", key=None, slots: int = 4,
                     chunk: int = 4) -> list[Response]:
    """Continuous batching through a per-engine cached ``SlotScheduler``."""
    cache = getattr(engine, "_slot_schedulers", None)
    if cache is None:
        cache = engine._slot_schedulers = {}
    sig = (slots, chunk, sampler)
    if sig not in cache:
        cache[sig] = SlotScheduler(engine, slots=slots, chunk=chunk, sampler=sampler)
    return cache[sig].serve(requests, max_new_tokens, key=key)


def serve_ragged(engine, requests: Sequence[Request], max_new_tokens: int,
                 *, sampler: str = "greedy", key=None, mode: str = "auto",
                 slots: int = 4, chunk: int = 4) -> list[Response]:
    """Serve a ragged request set; responses come back in arrival order.

    mode="continuous" runs the slot scheduler (length-aware families),
    mode="bucketed" the per-bucket generate loop, mode="auto" picks
    continuous when the family supports it."""
    if not requests:
        return []
    if mode == "auto":
        mode = "continuous" if engine.model.supports_lengths else "bucketed"
    if mode == "continuous":
        return serve_continuous(engine, requests, max_new_tokens,
                                sampler=sampler, key=key, slots=slots, chunk=chunk)
    if mode == "bucketed":
        return serve_bucketed(engine, requests, max_new_tokens,
                              sampler=sampler, key=key)
    raise ValueError(f"unknown serving mode {mode!r}")
