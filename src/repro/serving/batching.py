"""Variable-length request batching for the inference engine.

Real traffic is ragged. Three serving modes, all length-aware:

- **bucketed** — requests are right-padded to power-of-two buckets and each
  bucket runs one prefill+decode. True lengths ride along in the batch
  (``batch["lengths"]``): prefill masks pad keys, the first token is sampled
  from each row's logits at ``lengths[i]-1``, and decode runs per-request
  position counters, so a padded row decodes exactly like its unpadded self.
- **continuous** (``SlotScheduler``) — a fixed-width decode batch of slots
  over per-slot ``cache_len`` cache rows. Finished slots (EOS or budget
  exhausted) are refilled from the queue by a single-request prefill written
  into the slot's cache row, so the decode pipeline stays full across
  mixed-length traffic instead of draining one bucket at a time. Decode runs
  in jitted chunks of ``chunk`` steps between admission points
  (continuous-batching-lite: a slot that finishes mid-chunk idles — token
  and position FROZEN — until the chunk boundary).
- **paged** (``PagedScheduler``, serving/paged.py) — the block-pool KV cache:
  per-request block tables, on-demand allocation, block reclaim and queue
  re-admission at ANY decode step. Token-identical greedy outputs to
  continuous; resident KV scales with live tokens. ``serve_ragged`` prefers
  it where the family supports it.

Families whose prefill carries sequential state through every token (rwkv6,
zamba2's SSM backbone, enc-dec) cannot mask pads out of a recurrence; for
them the bucketed mode groups by exact length (no pads, always correct) and
the continuous/paged modes are unavailable.

Both schedulers also run **speculatively** (``spec_k``, serving/spec.py):
each decode round drafts ``spec_k - 1`` candidates per slot from its token
history and verifies the chunk in one forward pass — 1..spec_k tokens per
weight stream, token-identical greedy outputs (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flags
from repro.serving.sampling import make_sampler, sampler_sig


@dataclasses.dataclass
class Request:
    id: int
    tokens: list[int]
    # per-request decode budget; None falls back to the serve call's
    # max_new_tokens. Mixed budgets are where continuous batching pays off:
    # bucketed decode drags every row to its bucket's longest budget, the
    # slot scheduler frees and refills each slot at its own.
    max_new: int | None = None


@dataclasses.dataclass
class Response:
    id: int
    tokens: np.ndarray
    # true generated length: tokens[:length] are real, the rest is padding
    # (EOS, or 0 when the engine has no eos_id — indistinguishable from a
    # real vocab-0 token, which is exactly why the length rides along).
    length: int | None = None


def finalize_tokens(toks: list[int], budget: int, eos: int | None):
    """Trim at EOS, pad to ``budget``; returns (tokens (budget,), true length).

    ``length`` counts the real generated tokens (including the EOS itself);
    callers must not infer it from the pad value — with ``eos None`` the pad
    token 0 is a legal vocab id."""
    t = toks[:budget]
    if eos is not None and eos in t:
        t = t[: t.index(eos) + 1]
    length = len(t)
    t = t + [eos if eos is not None else 0] * (budget - length)
    return np.asarray(t, np.int32), length


def bucket_length(n: int, *, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


def pad_bucket(reqs: Sequence[Request], length: int, pad_id: int = 0):
    """Right-pad to ``length``; returns (tokens (b, length), true lengths)."""
    toks = np.full((len(reqs), length), pad_id, np.int32)
    lens = np.zeros((len(reqs),), np.int32)
    for i, r in enumerate(reqs):
        toks[i, : len(r.tokens)] = r.tokens
        lens[i] = len(r.tokens)
    return toks, lens


# ---------------------------------------------------------------------------
# bucketed mode
# ---------------------------------------------------------------------------

def serve_bucketed(engine, requests: Sequence[Request], max_new_tokens: int,
                   *, sampler: str = "greedy", sampler_kw=None,
                   key=None) -> list[Response]:
    """Bucket requests, generate per bucket, reassemble in arrival order.

    Length-aware families bucket by padded power-of-two length and pass the
    true lengths through to the engine; recurrent families group by exact
    length so no pad token ever enters the recurrence."""
    ragged = engine.model.supports_lengths
    eos = engine.eos_id
    buckets: dict[int, list[Request]] = defaultdict(list)
    for r in requests:
        n = len(r.tokens)
        buckets[bucket_length(n) if ragged else n].append(r)

    base_key = key if key is not None else jax.random.PRNGKey(0)
    out: dict[int, Response] = {}
    for length in sorted(buckets):
        reqs = buckets[length]
        toks, lens = pad_bucket(reqs, length)
        budgets = [r.max_new if r.max_new is not None else max_new_tokens
                   for r in reqs]
        # one generate per bucket runs to the bucket's longest budget; rows
        # with smaller budgets are decoded past their end and trimmed — the
        # serialization+overrun cost the slot scheduler removes
        res = engine.generate(
            {"tokens": jnp.asarray(toks)}, max(budgets), sampler=sampler,
            sampler_kw=sampler_kw,
            # independent PRNG stream per bucket — one shared key would make
            # every bucket sample the same per-step randomness
            key=jax.random.fold_in(base_key, length),
            lengths=lens if ragged else None,
        )
        gen = np.asarray(res.tokens)
        for i, r in enumerate(reqs):
            toks_r, n_true = finalize_tokens(
                [int(t) for t in gen[i, : budgets[i]]], budgets[i], eos)
            out[r.id] = Response(id=r.id, tokens=toks_r, length=n_true)
    return [out[r.id] for r in requests]


# ---------------------------------------------------------------------------
# continuous mode
# ---------------------------------------------------------------------------

class SlotScheduler:
    """Slot-based continuous batching over one engine.

    Holds the jitted decode-chunk and per-bucket prefill programs, so a
    long-lived scheduler serves successive traces with no recompilation.
    Responses always contain exactly ``max_new_tokens`` tokens; sequences
    that hit EOS early are padded with EOS (parity with the bucketed mode).
    """

    def __init__(self, engine, *, slots: int = 4, chunk: int = 4,
                 sampler: str = "greedy", sampler_kw=None,
                 spec_k: int | None = None, drafter=None):
        if not engine.model.supports_lengths:
            raise ValueError(
                f"{engine.cfg.arch_id}: continuous batching needs length-aware "
                "prefill and per-request decode positions (decoder_lm families)"
            )
        if spec_k is not None:
            if spec_k < 2:
                raise ValueError(f"spec_k must be >= 2, got {spec_k}")
            if not engine.model.supports_spec:
                raise ValueError(
                    f"{engine.cfg.arch_id}: model family has no speculative "
                    "verify path (GQA decoder_lm families only)"
                )
        self.engine = engine
        self.slots = slots
        self.chunk = chunk
        self.spec_k = spec_k
        self._sampler = make_sampler(sampler, **dict(sampler_kw or {}))
        self._prefill_jit: dict[int, callable] = {}
        self.last_positions = None     # final per-slot positions (debug)
        self.last_spec_stats = None    # per-serve speculative accounting
        if spec_k is not None:
            from repro.serving.spec import NgramDrafter, build_verify_step

            self._drafter = drafter if drafter is not None else NgramDrafter()
            # verify -> accept -> commit-accepted-prefix in one jitted
            # program; per-slot budgets and the live mask clamp the commit
            self._verify_step = build_verify_step(
                engine.model, sampler=sampler, sampler_kw=sampler_kw,
                paged=False)

        model, sample = engine.model, self._sampler

        # the cache is donated: the scheduler always rebinds it to the
        # result, and without donation XLA keeps both buffers live across
        # every chunk — a full extra cache of device memory
        @partial(jax.jit, donate_argnums=(2,))
        def decode_chunk(params, tok, cache, pos, live, keys):
            # ``live`` (b,) freezes finished/empty slots: their token and
            # position stop advancing, so a slot idling to the chunk
            # boundary keeps committing the SAME in-bounds cache slot of its
            # own (dead) row instead of drifting past cache_len, where the
            # commit would clamp/drop against the cache edge.
            def step(carry, k):
                tok, cache, pos = carry
                logits, cache = model.decode(params, tok, cache, pos)
                nxt = sample(logits, k)
                nxt = jnp.where(live, nxt, tok)
                pos = jnp.where(live, pos + 1, pos)
                return (nxt, cache, pos), nxt

            (tok, cache, pos), toks = jax.lax.scan(step, (tok, cache, pos), keys)
            return toks, cache, pos

        @partial(jax.jit, donate_argnums=(0,))
        def insert(cache, rows, slots):
            # every decoder_lm cache layout keeps batch on axis 1 of each
            # (layers, b, ...) leaf; the prefill rows replace whole slots
            return jax.tree.map(
                lambda big, small: big.at[:, slots].set(small), cache, rows
            )

        self._decode_chunk = decode_chunk
        self._insert = insert

    def _prefill_fn(self, length: int):
        """Jitted batched prefill+sample, cached per padded bucket length
        (retraces per admission-group size via jit's shape cache)."""
        if length not in self._prefill_jit:
            model, cache_len, sample = self.engine.model, self.engine.cache_len, self._sampler

            @jax.jit
            def prefill_group(params, toks, lens, key):
                logits, cache = model.prefill(
                    params, {"tokens": toks, "lengths": lens}, cache_len
                )
                return sample(logits, key), cache

            self._prefill_jit[length] = prefill_group
        return self._prefill_jit[length]

    def serve(self, requests: Sequence[Request], max_new_tokens: int,
              *, key=None) -> list[Response]:
        engine, B, chunk = self.engine, self.slots, self.chunk
        eos = engine.eos_id

        def budget(r: Request) -> int:
            return r.max_new if r.max_new is not None else max_new_tokens

        # a verify chunk touches score columns up to pos + spec_k - 1, so
        # speculative serving needs spec_k slots of slack past the vanilla
        # requirement (frozen slots included: their chunks still index)
        slack = self.spec_k or 0
        for r in requests:
            need = max(bucket_length(len(r.tokens)),
                       len(r.tokens) + budget(r) + slack)
            if need > engine.cache_len:
                raise ValueError(
                    f"request {r.id}: len={len(r.tokens)} + "
                    f"max_new={budget(r)}"
                    + (f" + spec_k={slack}" if slack else "")
                    + f" needs {need} cache slots "
                    f"but cache_len={engine.cache_len}"
                )

        cache = engine.model.init_cache(B, engine.cache_len, engine.cfg.cdtype())
        pending = deque(requests)
        slot_req: list[Request | None] = [None] * B
        slot_toks: list[list[int]] = [[] for _ in range(B)]
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        out: dict[int, Response] = {}
        key = key if key is not None else jax.random.PRNGKey(0)
        self.last_spec_stats = (
            {"verify_steps": 0, "generated": 0, "drafted": 0, "accepted": 0}
            if self.spec_k is not None else None)

        def finish(s: int):
            r = slot_req[s]
            toks_r, length = finalize_tokens(slot_toks[s], budget(r), eos)
            out[r.id] = Response(id=r.id, tokens=toks_r, length=length)
            slot_req[s] = None
            slot_toks[s] = []

        while pending or any(r is not None for r in slot_req):
            # refill free slots: one batched prefill per bucket length, one
            # scatter-insert per group (keeps host round-trips off the
            # per-request path)
            free = [s for s in range(B) if slot_req[s] is None]
            admitted: dict[int, list[Request]] = defaultdict(list)
            take = [pending.popleft() for _ in range(min(len(free), len(pending)))]
            for r in take:
                admitted[bucket_length(len(r.tokens))].append(r)
            staged: list[tuple[list[int], list[Request], jax.Array]] = []
            for length, group in admitted.items():
                slots_g, free = free[: len(group)], free[len(group):]
                toks_np, lens_np = pad_bucket(group, length)
                key, kp = jax.random.split(key)
                t0_d, rows = self._prefill_fn(length)(
                    engine.params, jnp.asarray(toks_np), jnp.asarray(lens_np), kp
                )
                cache = self._insert(cache, rows, jnp.asarray(slots_g, jnp.int32))
                staged.append((slots_g, group, t0_d))
            if staged:
                # ONE host round-trip for the whole admission wave, not one
                # per bucket (host-sync chunk budget: admission + chunk)
                first_toks = jax.device_get([t for _, _, t in staged])
                for (slots_g, group, _), t0 in zip(staged, first_toks):
                    for s, r, t in zip(slots_g, group, t0):
                        slot_req[s], slot_toks[s] = r, [int(t)]
                        tok[s], pos[s] = int(t), len(r.tokens)
                        if self.last_spec_stats is not None:
                            # the prefill-sampled token is delivered work too
                            # — keeps 'generated' comparable with engine
                            # spec_stats
                            self.last_spec_stats["generated"] += 1
                        if budget(r) <= 1 or (eos is not None and int(t) == eos):
                            finish(s)

            if not any(r is not None for r in slot_req):
                if pending:
                    continue
                break

            live = np.asarray([slot_req[s] is not None for s in range(B)])
            assert not live.any() or int(pos[live].max()) < engine.cache_len, (
                f"live slot position escaped the cache: {pos[live]} "
                f">= cache_len={engine.cache_len}")
            key, kc = jax.random.split(key)
            if self.spec_k is not None:
                # speculative step: draft on the host (per-slot token
                # history), verify the chunk in one forward pass, keep the
                # accepted prefix — 1..spec_k tokens per weight stream
                from repro.serving.spec import draft_chunk, take_accepted

                K = self.spec_k
                remaining = np.asarray(
                    [budget(slot_req[s]) - len(slot_toks[s])
                     if slot_req[s] is not None else 0 for s in range(B)],
                    np.int32)
                chunk_np = draft_chunk(
                    self._drafter, tok, live,
                    lambda s: slot_req[s].tokens + slot_toks[s], K)
                out_d, n_out_d, cache, pos_d, _ = self._verify_step(
                    engine.params, jnp.asarray(chunk_np), cache,
                    jnp.asarray(pos), jnp.asarray(live),
                    jnp.asarray(remaining), kc,
                )
                out_np, n_out, pos = jax.device_get((out_d, n_out_d, pos_d))
                pos = pos.copy()
                st = self.last_spec_stats
                st["verify_steps"] += 1
                for s in np.flatnonzero(live):
                    slot_toks[s].extend(take_accepted(
                        out_np[s], n_out[s], remaining[s], eos, st, K))
                    tok[s] = slot_toks[s][-1]
                    n = budget(slot_req[s])
                    if len(slot_toks[s]) >= n or (
                            eos is not None and eos in slot_toks[s][:n]):
                        finish(s)
                continue
            toks_d, cache, pos_d = self._decode_chunk(
                engine.params, jnp.asarray(tok), cache, jnp.asarray(pos),
                jnp.asarray(live), jax.random.split(kc, chunk),
            )
            # ONE host sync per chunk: separate np.asarray() calls on the
            # chunk outputs each forced their own device round-trip
            toks_np, pos = jax.device_get((toks_d, pos_d))   # (chunk, B), (B,)
            tok = toks_np[-1].copy()
            pos = pos.copy()
            for s in range(B):
                if slot_req[s] is None:
                    continue
                n = budget(slot_req[s])
                slot_toks[s].extend(int(t) for t in toks_np[:, s])
                done = len(slot_toks[s]) >= n
                if eos is not None and eos in slot_toks[s][:n]:
                    done = True
                if done:
                    finish(s)

        self.last_positions = pos.copy()
        return [out[r.id] for r in requests]


def serve_continuous(engine, requests: Sequence[Request], max_new_tokens: int,
                     *, sampler: str = "greedy", sampler_kw=None, key=None,
                     slots: int = 4, chunk: int = 4, spec_k: int | None = None,
                     drafter=None) -> list[Response]:
    """Continuous batching through a per-engine cached ``SlotScheduler``."""
    cache = getattr(engine, "_slot_schedulers", None)
    if cache is None:
        cache = engine._slot_schedulers = {}
    sig = (slots, chunk, sampler, sampler_sig(sampler_kw), spec_k,
           id(drafter) if drafter is not None else None)
    if sig not in cache:
        cache[sig] = SlotScheduler(engine, slots=slots, chunk=chunk,
                                   sampler=sampler, sampler_kw=sampler_kw,
                                   spec_k=spec_k, drafter=drafter)
    return cache[sig].serve(requests, max_new_tokens, key=key)


def resolve_mode(engine, mode: str) -> str:
    """Capability dispatch for ``mode="auto"``: paged where the family has a
    block-pool cache, else continuous where lengths are supported, else
    bucketed. The single source of truth for every front-end (serve_ragged,
    the serve CLI)."""
    if mode != "auto":
        return mode
    # the paged pool keeps the base float KV layout; under the kvt/int8
    # cache flags auto must keep resolving to the contiguous scheduler,
    # whose decode paths support those layouts
    if (engine.model.supports_paged
            and not flags.get("kvt_cache_layout")
            and not flags.get("int8_kv_cache")):
        return "paged"
    return "continuous" if engine.model.supports_lengths else "bucketed"


def serve_ragged(engine, requests: Sequence[Request], max_new_tokens: int,
                 *, sampler: str = "greedy", sampler_kw=None, key=None,
                 mode: str = "auto", slots: int = 4, chunk: int = 4,
                 block_size: int = 8, num_blocks: int | None = None,
                 spec_k: int | None = None, drafter=None) -> list[Response]:
    """Serve a ragged request set; responses come back in arrival order.

    mode="paged" runs the block-pool scheduler (serving/paged.py: admission
    and block reclaim at any decode step), mode="continuous" the contiguous
    slot scheduler, mode="bucketed" the per-bucket generate loop;
    mode="auto" prefers paged, then continuous, by family capability.

    ``spec_k`` >= 2 turns the paged/continuous schedulers speculative: each
    step verifies spec_k candidate tokens per slot in one forward pass
    (serving/spec.py; ``drafter`` defaults to the n-gram prompt-lookup
    drafter). The bucketed fallback has no speculative path — its families
    lack the verify contract."""
    if not requests:
        return []
    mode = resolve_mode(engine, mode)
    if spec_k is not None and mode == "bucketed":
        raise ValueError(
            "speculative decoding needs the continuous or paged scheduler "
            f"(resolved mode is 'bucketed' for {engine.cfg.arch_id})"
        )
    if mode == "paged":
        from repro.serving.paged import serve_paged   # avoid import cycle

        return serve_paged(engine, requests, max_new_tokens, sampler=sampler,
                           sampler_kw=sampler_kw, key=key, slots=slots,
                           chunk=chunk, block_size=block_size,
                           num_blocks=num_blocks, spec_k=spec_k,
                           drafter=drafter)
    if mode == "continuous":
        return serve_continuous(engine, requests, max_new_tokens,
                                sampler=sampler, sampler_kw=sampler_kw,
                                key=key, slots=slots, chunk=chunk,
                                spec_k=spec_k, drafter=drafter)
    if mode == "bucketed":
        return serve_bucketed(engine, requests, max_new_tokens,
                              sampler=sampler, sampler_kw=sampler_kw, key=key)
    raise ValueError(f"unknown serving mode {mode!r}")
