"""Variable-length request batching for the inference engine.

The step functions take uniform-length batches (one shared position counter
— the shape the assigned decode cells use). Real traffic is ragged, so the
engine front-end buckets requests by padded prompt length (powers of two),
runs one prefill+decode per bucket, and reassembles responses in arrival
order — continuous-batching-lite. Per-token request joining (true continuous
batching) needs per-request position counters in the cache update and is
listed as serving future work in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    id: int
    tokens: list[int]


@dataclasses.dataclass
class Response:
    id: int
    tokens: np.ndarray


def bucket_length(n: int, *, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


def pad_bucket(reqs: Sequence[Request], length: int, pad_id: int = 0):
    """Right-pad to ``length``; returns (tokens (b, length), true lengths)."""
    toks = np.full((len(reqs), length), pad_id, np.int32)
    lens = np.zeros((len(reqs),), np.int32)
    for i, r in enumerate(reqs):
        toks[i, : len(r.tokens)] = r.tokens
        lens[i] = len(r.tokens)
    return toks, lens


def serve_ragged(engine, requests: Sequence[Request], max_new_tokens: int,
                 *, sampler: str = "greedy", key=None) -> list[Response]:
    """Bucket by padded length, generate per bucket, reassemble by id."""
    buckets: dict[int, list[Request]] = defaultdict(list)
    for r in requests:
        buckets[bucket_length(len(r.tokens))].append(r)

    out: dict[int, Response] = {}
    for length in sorted(buckets):
        reqs = buckets[length]
        toks, _ = pad_bucket(reqs, length)
        res = engine.generate({"tokens": jnp.asarray(toks)}, max_new_tokens,
                              sampler=sampler, key=key)
        gen = np.asarray(res.tokens)
        for i, r in enumerate(reqs):
            out[r.id] = Response(id=r.id, tokens=gen[i])
    return [out[r.id] for r in requests]
