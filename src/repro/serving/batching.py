"""Variable-length request batching: front-ends over the scheduling core.

Real traffic is ragged. The serving modes, all length-aware:

- **bucketed** — requests are right-padded to power-of-two buckets and each
  bucket runs one prefill+decode. True lengths ride along in the batch
  (``batch["lengths"]``): prefill masks pad keys, the first token is sampled
  from each row's logits at ``lengths[i]-1``, and decode runs per-request
  position counters, so a padded row decodes exactly like its unpadded self.
  Recurrent families group by exact length (no pads, always correct).
- **continuous** (``SlotScheduler``) — a fixed-width decode batch of slots
  fed by the scheduling core (serving/core.py). decoder_lm families slot
  into per-slot ``cache_len`` cache rows (``ContiguousAdapter``); recurrent
  families (rwkv6, zamba2) slot their O(1) recurrent state in and out with
  a gather/scatter (``RecurrentAdapter``) — continuous batching is no
  longer a decoder_lm-only fast path. Decode runs in jitted chunks of
  ``chunk`` steps between admission points (a slot that finishes mid-chunk
  idles — token and position FROZEN — until the chunk boundary).
- **paged** (``PagedScheduler``, serving/paged.py) — the block-pool KV cache
  behind the same core loop: per-request block tables, on-demand allocation,
  block reclaim and queue re-admission at ANY decode step. Token-identical
  greedy outputs to continuous; resident KV scales with live tokens.
  ``serve_ragged`` prefers it where the family supports it.

The admission/refill/finish/finalize loop itself lives in serving/core.py
(``SchedulerCore``), parameterized by a ``CacheAdapter``; the schedulers
here are thin fronts that pick the adapter and expose the historical API.

Both slot schedulers also run **speculatively** (``spec_k``,
serving/spec.py): each decode round drafts ``spec_k - 1`` candidates per
slot from its token history and verifies the chunk in one forward pass —
1..spec_k tokens per weight stream, token-identical greedy outputs
(DESIGN.md §10).
"""

from __future__ import annotations

from collections import defaultdict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flags
from repro.serving.core import (
    ContiguousAdapter,
    RecurrentAdapter,
    Request,
    Response,
    SchedulerCore,
    bucket_length,
    finalize_tokens,
    make_response,
    pad_bucket,
)
from repro.serving.sampling import sampler_sig

__all__ = [
    "Request",
    "Response",
    "SlotScheduler",
    "bucket_length",
    "finalize_tokens",
    "make_response",
    "pad_bucket",
    "resolve_mode",
    "serve_bucketed",
    "serve_continuous",
    "serve_ragged",
    "valid_modes",
]


# ---------------------------------------------------------------------------
# bucketed mode
# ---------------------------------------------------------------------------

def serve_bucketed(engine, requests: Sequence[Request], max_new_tokens: int,
                   *, sampler: str = "greedy", sampler_kw=None,
                   key=None) -> list[Response]:
    """Bucket requests, generate per bucket, reassemble in arrival order.

    Length-aware families bucket by padded power-of-two length and pass the
    true lengths through to the engine; recurrent families group by exact
    length so no pad token ever enters the recurrence."""
    ragged = engine.model.supports_lengths
    eos = engine.eos_id
    buckets: dict[int, list[Request]] = defaultdict(list)
    for r in requests:
        n = len(r.tokens)
        buckets[bucket_length(n) if ragged else n].append(r)

    base_key = key if key is not None else jax.random.PRNGKey(0)
    out: dict[int, Response] = {}
    for length in sorted(buckets):
        reqs = buckets[length]
        toks, lens = pad_bucket(reqs, length)
        budgets = [r.max_new if r.max_new is not None else max_new_tokens
                   for r in reqs]
        # one generate per bucket runs to the bucket's longest budget; rows
        # with smaller budgets are decoded past their end and trimmed — the
        # serialization+overrun cost the slot scheduler removes
        res = engine.generate(
            {"tokens": jnp.asarray(toks)}, max(budgets), sampler=sampler,
            sampler_kw=sampler_kw,
            # independent PRNG stream per bucket — one shared key would make
            # every bucket sample the same per-step randomness
            key=jax.random.fold_in(base_key, length),
            lengths=lens if ragged else None,
        )
        gen = np.asarray(res.tokens)
        for i, r in enumerate(reqs):
            out[r.id] = make_response(
                r, [int(t) for t in gen[i, : budgets[i]]], budgets[i], eos)
    return [out[r.id] for r in requests]


# ---------------------------------------------------------------------------
# continuous mode
# ---------------------------------------------------------------------------

class SlotScheduler:
    """Slot-based continuous batching over one engine: the scheduling-core
    loop behind a ``ContiguousAdapter`` (decoder_lm families: per-slot
    ``cache_len`` cache rows) or a ``RecurrentAdapter`` (cache_kind="state"
    families: O(1) per-slot recurrent state, exact-length admission groups).

    Holds the jitted decode-chunk and per-group prefill programs, so a
    long-lived scheduler serves successive traces with no recompilation.
    Responses always contain exactly ``max_new_tokens`` tokens; sequences
    that hit EOS early are padded with EOS (parity with the bucketed mode).
    """

    def __init__(self, engine, *, slots: int = 4, chunk: int = 4,
                 sampler: str = "greedy", sampler_kw=None,
                 spec_k: int | None = None, drafter=None):
        if engine.model.cache_kind == "state":
            adapter = RecurrentAdapter(engine)
        elif engine.model.supports_lengths:
            adapter = ContiguousAdapter(engine)
        else:
            raise ValueError(
                f"{engine.cfg.arch_id}: continuous batching needs "
                "length-aware prefill (decoder_lm families) or O(1) per-slot "
                "recurrent state (cache_kind='state' families)"
            )
        self.engine = engine
        self.adapter = adapter
        self._core = SchedulerCore(engine, adapter, slots=slots, chunk=chunk,
                                   sampler=sampler, sampler_kw=sampler_kw,
                                   spec_k=spec_k, drafter=drafter)
        self.slots = slots
        self.chunk = chunk
        self.spec_k = spec_k
        self.last_positions = None     # final per-slot positions (debug)
        self.last_spec_stats = None    # per-serve speculative accounting

    def serve(self, requests: Sequence[Request], max_new_tokens: int,
              *, key=None) -> list[Response]:
        out = self._core.serve(requests, max_new_tokens, key=key)
        self.last_positions = self._core.last_positions
        self.last_spec_stats = self._core.last_spec_stats
        return out


def serve_continuous(engine, requests: Sequence[Request], max_new_tokens: int,
                     *, sampler: str = "greedy", sampler_kw=None, key=None,
                     slots: int = 4, chunk: int = 4, spec_k: int | None = None,
                     drafter=None) -> list[Response]:
    """Continuous batching through a per-engine cached ``SlotScheduler``."""
    cache = getattr(engine, "_slot_schedulers", None)
    if cache is None:
        cache = engine._slot_schedulers = {}
    sig = (slots, chunk, sampler, sampler_sig(sampler_kw), spec_k,
           id(drafter) if drafter is not None else None)
    if sig not in cache:
        cache[sig] = SlotScheduler(engine, slots=slots, chunk=chunk,
                                   sampler=sampler, sampler_kw=sampler_kw,
                                   spec_k=spec_k, drafter=drafter)
    return cache[sig].serve(requests, max_new_tokens, key=key)


def valid_modes(model) -> list[str]:
    """Serving modes the family can run, preferred first. ``continuous``
    covers both the length-aware decoder_lm slot path and the recurrent
    slot-state path (cache_kind="state"); ``bucketed`` always works."""
    modes = []
    if model.supports_paged:
        modes.append("paged")
    if model.supports_lengths or model.cache_kind == "state":
        modes.append("continuous")
    modes.append("bucketed")
    return modes


def resolve_mode(engine, mode: str) -> str:
    """Capability dispatch, the single source of truth for every front-end
    (serve_ragged, the serve CLI).

    ``mode="auto"`` resolves to the family's preferred mode: paged where it
    has a block-pool cache (and the KV-layout flags allow it — the paged
    pool keeps the base float layout), else continuous — decoder_lm slots
    OR the recurrent slot-state path — else bucketed. Recurrent families
    (rwkv6, zamba2) therefore land on continuous, not bucket-serial.

    An explicit mode is validated against the family's surface; the error
    lists the modes valid for the arch (the serve CLI surfaces this as the
    ``--mode`` error message)."""
    ok = valid_modes(engine.model)
    if mode != "auto":
        if mode not in ("paged", "continuous", "bucketed"):
            raise ValueError(
                f"unknown serving mode {mode!r}; valid modes for "
                f"{engine.cfg.arch_id}: {', '.join(ok)} (or 'auto')")
        if mode not in ok:
            raise ValueError(
                f"{engine.cfg.arch_id} does not support mode={mode!r}; "
                f"valid modes: {', '.join(ok)} (or 'auto')")
        return mode
    # the paged pool keeps the base float KV layout; under the kvt/int8
    # cache flags auto must keep resolving to the contiguous scheduler,
    # whose decode paths support those layouts
    if ("paged" in ok
            and not flags.get("kvt_cache_layout")
            and not flags.get("int8_kv_cache")):
        return "paged"
    return "continuous" if "continuous" in ok else "bucketed"


def serve_ragged(engine, requests: Sequence[Request], max_new_tokens: int,
                 *, sampler: str = "greedy", sampler_kw=None, key=None,
                 mode: str = "auto", slots: int = 4, chunk: int = 4,
                 block_size: int = 8, num_blocks: int | None = None,
                 spec_k: int | None = None, drafter=None) -> list[Response]:
    """Serve a ragged request set; responses come back in arrival order.

    mode="paged" runs the block-pool scheduler (serving/paged.py: admission
    and block reclaim at any decode step), mode="continuous" the slot
    scheduler (contiguous cache rows for decoder_lm, slot-state for the
    recurrent families), mode="bucketed" the per-bucket generate loop;
    mode="auto" prefers paged, then continuous, by family capability.

    ``spec_k`` >= 2 turns the paged/continuous schedulers speculative: each
    step verifies spec_k candidate tokens per slot in one forward pass
    (serving/spec.py; ``drafter`` defaults to the n-gram prompt-lookup
    drafter). The bucketed fallback has no speculative path — its families
    lack the verify contract."""
    if not requests:
        return []
    mode = resolve_mode(engine, mode)
    if spec_k is not None and mode == "bucketed":
        raise ValueError(
            "speculative decoding needs the continuous or paged scheduler "
            f"(resolved mode is 'bucketed' for {engine.cfg.arch_id})"
        )
    if mode == "paged":
        from repro.serving.paged import serve_paged   # avoid import cycle

        return serve_paged(engine, requests, max_new_tokens, sampler=sampler,
                           sampler_kw=sampler_kw, key=key, slots=slots,
                           chunk=chunk, block_size=block_size,
                           num_blocks=num_blocks, spec_k=spec_k,
                           drafter=drafter)
    if mode == "continuous":
        return serve_continuous(engine, requests, max_new_tokens,
                                sampler=sampler, sampler_kw=sampler_kw,
                                key=key, slots=slots, chunk=chunk,
                                spec_k=spec_k, drafter=drafter)
    return serve_bucketed(engine, requests, max_new_tokens,
                          sampler=sampler, sampler_kw=sampler_kw, key=key)
