"""Paged KV-cache serving: block-pool allocator + the paged cache adapter.

The contiguous slot path (serving/core.py ``ContiguousAdapter``) reserves a
full ``slots x cache_len`` KV region up front and lets finished slots idle
until the next chunk boundary — the capacity/utilization gap LlamaF's weight
streaming attacks on the FPGA, replayed on the serving side. Here the cache
is a POOL of fixed-size KV blocks:

- ``BlockPool`` — host-side allocator over ``num_blocks`` blocks of
  ``block_size`` token slots. Block 0 is the reserved write-off SINK:
  unallocated block-table entries point at it, so stray writes (prompt pad
  tail, frozen slots) land somewhere harmless instead of clobbering live
  data. Blocks are recycled WITHOUT zeroing — the paged attention path
  overwrites the current column's score/value explicitly and masks
  everything beyond ``pos``, so stale block contents are unreachable.
- ``PagedAdapter`` — the block pool behind the scheduling core's one
  admission/refill/finish loop (serving/core.py). Requests admit into fixed
  decode slots (one batched prefill per bucket, scattered into their
  blocks), blocks are allocated ON DEMAND as positions advance (a round's
  worth ahead), and the jitted decode loop is a ``while_loop`` that EXITS
  the moment any live slot finishes — blocks are freed and the queue
  re-admitted at that exact step, not at the next chunk boundary. Resident
  KV memory therefore scales with live tokens (+ block slack), not with
  ``slots x cache_len`` (``benchmarks/run.py paged``).
- ``PagedScheduler`` — the historical front: picks the adapter, exposes
  pool sizing and the residency high-water mark.

Admission is reservation-gated (``can_admit``): a request is admitted only
when the pool can cover every live request's worst-case remaining need plus
its own, so allocation for live slots never fails and no preemption path is
needed (DESIGN.md §9 allocator invariants).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flags
from repro.serving.core import (
    CacheAdapter,
    Request,
    Response,
    SchedulerCore,
    bucket_length,
)
from repro.serving.sampling import sampler_sig

__all__ = ["BlockPool", "PagedAdapter", "PagedScheduler", "serve_paged"]


class BlockPool:
    """Fixed-size KV block allocator. Block ids are indices into the device
    pool's block axis; block 0 is the reserved sink and is never handed out.
    Tracks ``peak_live`` (high-water mark of allocated blocks) for the
    residency benchmark."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("BlockPool needs >= 2 blocks (block 0 is the sink)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))   # LIFO reuse
        self._free_set = set(self._free)
        self.peak_live = 0
        # repro-san hook (analysis/shadow.py ShadowBlockTracker): when set,
        # every alloc/free is mirrored — ownership, generations, poison queue
        self.shadow = None

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)} "
                f"of {self.num_blocks - 1}"
            )
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        self.peak_live = max(self.peak_live, self.live_blocks)
        if self.shadow is not None:
            self.shadow.on_alloc(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        if self.shadow is not None:
            # first: the shadow's unowned-free diagnosis (double-free with
            # generation attribution) beats the bare ValueError below
            self.shadow.on_free(blocks)
        for b in blocks:
            # a double-free would hand one physical block to two requests —
            # silent KV corruption — so this must not be a strippable assert
            if not 0 < b < self.num_blocks or b in self._free_set:
                raise ValueError(f"bad free of block {b}: out of range, "
                                 "double-freed, or the sink")
            self._free.append(b)
            self._free_set.add(b)


class PagedAdapter(CacheAdapter):
    """Block-pool cache behind the scheduling core: per-slot block tables
    over a ``BlockPool``, reservation-gated admission, on-demand block
    growth before each round, blocks reclaimed the step a slot finishes."""

    kind = "paged"
    spec_capable = True

    def __init__(self, engine, *, block_size: int = 8,
                 num_blocks: int | None = None, max_len: int | None = None):
        if not engine.model.supports_paged:
            raise ValueError(
                f"{engine.cfg.arch_id}: paged serving needs a block-pool cache "
                "(GQA decoder_lm families; MLA/recurrent keep the contiguous "
                "and slot-state paths)"
            )
        self.engine = engine
        self.block_size = block_size
        self.max_len = max_len if max_len is not None else engine.cache_len
        self.blocks_per_req = math.ceil(self.max_len / block_size)
        self._num_blocks_arg = num_blocks
        self.num_blocks: int | None = None   # resolved at bind (needs slots)
        self.pool: BlockPool | None = None   # per-serve allocator

    def bind(self, core, *, sampler, sampler_kw):
        engine = self.engine
        self.core = core
        # default pool matches the contiguous footprint (worst case for every
        # slot); benchmarks/tests hand in smaller pools to exercise
        # backpressure — correctness never depends on pool size
        self.num_blocks = (self._num_blocks_arg
                           if self._num_blocks_arg is not None
                           else core.slots * self.blocks_per_req + 1)
        # block lookahead per decode round: a verify chunk commits up to
        # spec_k rows per slot in one step
        self._ahead = (core.chunk if core.spec_k is None
                       else max(core.chunk, core.spec_k))
        self._prefill_jit = None
        if core.spec_k is not None:
            from repro.serving.spec import build_verify_step

            self._verify_step = build_verify_step(
                engine.model, sampler=sampler, sampler_kw=sampler_kw,
                paged=True)

        model, sample, eos = engine.model, core._sampler, engine.eos_id
        block_size = self.block_size

        # pool buffers are donated: the core always rebinds the cache to
        # each round's result, and an undonated pool would transiently
        # double the very footprint this subsystem exists to shrink
        @partial(jax.jit, donate_argnums=(2,))
        def decode_until(params, tok, cache, table, pos, live, remaining, keys):
            """Decode up to ``chunk`` steps, but stop at the step ANY live
            slot finishes (EOS or budget) — the host frees/refills there."""
            nsteps, b = keys.shape[0], tok.shape[0]

            def cond(c):
                i, _, _, _, _, stop, _ = c
                return (i < nsteps) & ~stop

            def body(c):
                i, tok, cache, pos, remaining, stop, toks = c
                logits, cache = model.decode_paged(params, tok, cache, table, pos)
                nxt = sample(logits, keys[i])
                nxt = jnp.where(live, nxt, tok)        # frozen slots keep tok
                toks = toks.at[i].set(nxt)
                pos = jnp.where(live, pos + 1, pos)    # ...and their position
                remaining = jnp.where(live, remaining - 1, remaining)
                fin = live & (remaining <= 0)
                if eos is not None:
                    fin = fin | (live & (nxt == eos))
                return (i + 1, nxt, cache, pos, remaining, jnp.any(fin), toks)

            toks0 = jnp.zeros((nsteps, b), jnp.int32)
            i, tok, cache, pos, remaining, _, toks = jax.lax.while_loop(
                cond, body,
                (jnp.int32(0), tok, cache, pos, remaining, jnp.bool_(False), toks0))
            return toks, i, cache, pos

        @partial(jax.jit, donate_argnums=(0,))
        def insert(cache, rows, tables):
            # rows: contiguous prefill cache (L, bg, S, KV, hd); tables
            # (bg, S // block_size) physical block per prompt block (0=sink)
            def put(pages, r):
                ell, bg = r.shape[:2]
                rr = r.reshape(ell, bg, tables.shape[1], block_size, *r.shape[3:])
                return pages.at[:, tables].set(rr)
            if "k_q" in rows:
                # quantized prefill rows arrive kvt-major (L, bg, KV, S[, hd]);
                # swing the time axis forward so the same block reshape applies
                # to storage rows and their per-row scale leaves alike
                tm = lambda leaf: jnp.moveaxis(leaf, 3, 2)
                return {"k_pages": put(cache["k_pages"], tm(rows["k_q"])),
                        "k_scales": put(cache["k_scales"], tm(rows["k_s"])),
                        "v_pages": put(cache["v_pages"], tm(rows["v_q"])),
                        "v_scales": put(cache["v_scales"], tm(rows["v_s"]))}
            return {"k_pages": put(cache["k_pages"], rows["k"]),
                    "v_pages": put(cache["v_pages"], rows["v"])}

        self._decode_until = decode_until
        self._insert = insert

    # -- sizing helpers -----------------------------------------------------

    def _prompt_pad(self, n: int) -> int:
        """Padded prefill length: the power-of-two bucket, rounded up to a
        whole number of blocks."""
        b = bucket_length(n)
        return math.ceil(b / self.block_size) * self.block_size

    def _blocks_needed(self, r: Request, budget: int) -> int:
        # decode commits positions len .. len+budget-2 (the first generated
        # token comes from prefill); prompt occupies 0 .. len-1
        last = len(r.tokens) + max(budget - 1, 0)
        return math.ceil(max(last, 1) / self.block_size)

    def _reserved_backlog(self) -> int:
        """Blocks the live slots may still demand beyond what they hold."""
        return sum(self._slot_need[s] - len(self._slot_blocks[s])
                   for s in range(len(self._slot_need)) if self._slot_live[s])

    def _ensure_blocks(self, s: int, p: int) -> None:
        """Grow slot ``s`` to cover the next round of decode commits
        (``chunk`` single-token steps, or one spec_k-row verify chunk) —
        reservation-gated admission guarantees this never fails."""
        bs = self.block_size
        target = min(math.ceil((p + self._ahead) / bs), self._slot_need[s])
        delta = target - len(self._slot_blocks[s])
        if delta > 0:
            if self.pool.shadow is not None:
                self.pool.shadow.set_context(s)   # attribute growth allocs
            new = self.pool.alloc(delta)
            start = len(self._slot_blocks[s])
            self._slot_blocks[s].extend(new)
            self.table[s, start:start + len(new)] = new

    # -- CacheAdapter surface ------------------------------------------------

    def validate(self, requests, budget, slack):
        if flags.get("kvt_cache_layout") or flags.get("int8_kv_cache"):
            raise ValueError("paged serving supports the base float KV layout "
                             "(kvt_cache_layout / int8_kv_cache flags off)")
        mb, bs = self.blocks_per_req, self.block_size
        for r in requests:
            need = max(self._prompt_pad(len(r.tokens)),
                       len(r.tokens) + budget(r) + slack)
            if need > mb * bs:
                raise ValueError(
                    f"request {r.id}: len={len(r.tokens)} + max_new={budget(r)}"
                    + (f" + spec_k={slack}" if slack else "")
                    + f" needs {need} cache slots but the paged table covers "
                    f"{mb} blocks x {bs} = {mb * bs}"
                )
            if self._blocks_needed(r, budget(r)) > self.num_blocks - 1:
                raise ValueError(
                    f"request {r.id}: needs {self._blocks_needed(r, budget(r))} "
                    f"blocks but the pool has {self.num_blocks - 1}"
                )

    def begin_serve(self):
        B, bs = self.core.slots, self.block_size
        self.pool = BlockPool(self.num_blocks, bs)
        self.table = np.zeros((B, self.blocks_per_req), np.int32)  # 0 = sink
        self._slot_blocks: list[list[int]] = [[] for _ in range(B)]
        self._slot_need = [0] * B              # worst-case total blocks
        self._slot_live = np.zeros((B,), bool)
        return self.engine.model.init_paged_cache(
            self.num_blocks, bs, self.engine.cfg.cdtype())

    def can_admit(self, r, budget):
        # reservation-gated: admit only when the pool covers every live
        # slot's worst-case remaining growth plus this request's whole need
        return (self._blocks_needed(r, budget)
                <= self.pool.free_blocks - self._reserved_backlog())

    def on_admit(self, s, r, budget):
        prompt_blocks = self.pool.alloc(
            math.ceil(len(r.tokens) / self.block_size))
        self._slot_blocks[s] = prompt_blocks
        self._slot_need[s] = self._blocks_needed(r, budget)
        self.table[s, :] = 0
        self.table[s, : len(prompt_blocks)] = prompt_blocks
        self._slot_live[s] = True

    def group_len(self, n):
        return self._prompt_pad(n)

    def prefill(self, length):
        del length   # pad target rides in via toks.shape: one cached program
        if self._prefill_jit is None:
            model, sample = self.engine.model, self.core._sampler

            @jax.jit
            def prefill_group(params, toks, lens, key):
                # pad target == the padded prompt length: the paged pool is
                # the only persistent cache, so no cache_len-wide row exists
                logits, cache = model.prefill(
                    params, {"tokens": toks, "lengths": lens}, toks.shape[1]
                )
                return sample(logits, key), cache

            self._prefill_jit = prefill_group
        return self._prefill_jit

    def insert(self, cache, rows, group, length):
        tables_g = jnp.asarray(
            np.stack([self.table[s, : length // self.block_size]
                      for s, _ in group]))
        return self._insert(cache, rows, tables_g)

    def before_round(self, pos, live):
        for s in range(len(live)):
            if live[s]:
                self._ensure_blocks(s, int(pos[s]))

    def check_positions(self, pos, live):
        mb, bs = self.blocks_per_req, self.block_size
        assert not live.any() or int(pos[live].max()) < mb * bs, (
            f"live slot position escaped the block table: {pos[live]}")

    def decode_round(self, params, tok, cache, pos, live, remaining, keys):
        toks, steps, cache, pos = self._decode_until(
            params, tok, cache, jnp.asarray(self.table), pos, live,
            remaining, keys)
        return toks, steps, cache, pos

    def verify_round(self, params, chunk, cache, pos, live, remaining, key):
        out, n_out, cache, pos, _ = self._verify_step(
            params, chunk, cache, jnp.asarray(self.table), pos, live,
            remaining, key)
        return out, n_out, cache, pos

    def on_finish(self, s):
        self.pool.free(self._slot_blocks[s])
        self._slot_blocks[s], self._slot_need[s] = [], 0
        self.table[s, :] = 0                   # stray writes go to the sink
        self._slot_live[s] = False

    def snapshot(self, cache, slots):
        """Pool-level snapshot: the pages plus each slot's block-table row —
        pool rows are unaddressable without the table (engine.snapshot
        carries the same pair for the uniform paged path)."""
        san = getattr(self.core, "sanitizer", None)
        if san is not None:
            san.on_snapshot(slots)
        return {"cache": jax.device_get(cache),
                "table": self.table[np.asarray(slots)].copy()}

    def san_state(self):
        return {"pool": self.pool, "table": self.table}


class PagedScheduler:
    """Paged continuous batching over one engine (see module docstring).

    Produces token-identical greedy outputs to the contiguous
    ``SlotScheduler`` / ``serve_ragged(mode="continuous")`` on any trace —
    the paged attention path is parity-tested bit-exact against the
    contiguous deferred decode (tests/test_paged.py).
    """

    def __init__(self, engine, *, slots: int = 4, chunk: int = 4,
                 block_size: int = 8, num_blocks: int | None = None,
                 max_len: int | None = None, sampler: str = "greedy",
                 sampler_kw=None, spec_k: int | None = None, drafter=None):
        self.adapter = PagedAdapter(engine, block_size=block_size,
                                    num_blocks=num_blocks, max_len=max_len)
        self._core = SchedulerCore(engine, self.adapter, slots=slots,
                                   chunk=chunk, sampler=sampler,
                                   sampler_kw=sampler_kw, spec_k=spec_k,
                                   drafter=drafter)
        self.engine = engine
        self.slots = slots
        self.chunk = chunk
        self.spec_k = spec_k
        self.block_size = block_size
        self.max_len = self.adapter.max_len
        self.blocks_per_req = self.adapter.blocks_per_req
        self.num_blocks = self.adapter.num_blocks
        self.last_peak_blocks = 0          # residency high-water of last serve
        self.last_positions: np.ndarray | None = None   # debug/introspection
        self.last_spec_stats = None        # per-serve speculative accounting

    def serve(self, requests: Sequence[Request], max_new_tokens: int,
              *, key=None) -> list[Response]:
        out = self._core.serve(requests, max_new_tokens, key=key)
        self.last_positions = self._core.last_positions
        self.last_spec_stats = self._core.last_spec_stats
        # the allocator's exact high-water mark (sampling pool.live_blocks at
        # loop points would miss peaks freed before the sample, e.g. prompt
        # blocks of budget<=1 requests finished at admission)
        self.last_peak_blocks = max(self.last_peak_blocks,
                                    self.adapter.pool.peak_live)
        return out


def serve_paged(engine, requests: Sequence[Request], max_new_tokens: int,
                *, sampler: str = "greedy", sampler_kw=None, key=None,
                slots: int = 4, chunk: int = 4, block_size: int = 8,
                num_blocks: int | None = None, spec_k: int | None = None,
                drafter=None) -> list[Response]:
    """Paged continuous batching through a per-engine cached scheduler."""
    cache = getattr(engine, "_paged_schedulers", None)
    if cache is None:
        cache = engine._paged_schedulers = {}
    sig = (slots, chunk, block_size, num_blocks, sampler,
           sampler_sig(sampler_kw), spec_k,
           id(drafter) if drafter is not None else None)
    if sig not in cache:
        cache[sig] = PagedScheduler(engine, slots=slots, chunk=chunk,
                                    block_size=block_size, num_blocks=num_blocks,
                                    sampler=sampler, sampler_kw=sampler_kw,
                                    spec_k=spec_k, drafter=drafter)
    sched = cache[sig]
    sched.last_peak_blocks = 0
    return sched.serve(requests, max_new_tokens, key=key)
