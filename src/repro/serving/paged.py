"""Paged KV-cache serving: block-pool allocator + paged continuous batching.

The contiguous ``SlotScheduler`` (serving/batching.py) reserves a full
``slots x cache_len`` KV region up front and lets finished slots idle until
the next chunk boundary — the capacity/utilization gap LlamaF's weight
streaming attacks on the FPGA, replayed on the serving side. Here the cache
is a POOL of fixed-size KV blocks:

- ``BlockPool`` — host-side allocator over ``num_blocks`` blocks of
  ``block_size`` token slots. Block 0 is the reserved write-off SINK:
  unallocated block-table entries point at it, so stray writes (prompt pad
  tail, frozen slots) land somewhere harmless instead of clobbering live
  data. Blocks are recycled WITHOUT zeroing — the paged attention path
  overwrites the current column's score/value explicitly and masks
  everything beyond ``pos``, so stale block contents are unreachable.
- ``PagedScheduler`` — continuous batching over the pool. Requests admit
  into fixed decode slots (one batched prefill per bucket, scattered into
  their blocks), blocks are allocated ON DEMAND as positions advance (a
  chunk's worth ahead), and the jitted decode loop is a ``while_loop`` that
  EXITS the moment any live slot finishes — blocks are freed and the queue
  re-admitted at that exact step, not at the next chunk boundary. Resident
  KV memory therefore scales with live tokens (+ block slack), not with
  ``slots x cache_len`` (``benchmarks/run.py paged``).

Admission is reservation-gated: a request is admitted only when the pool can
cover every live request's worst-case remaining need plus its own, so
allocation for live slots never fails and no preemption path is needed
(DESIGN.md §9 allocator invariants).
"""

from __future__ import annotations

import math
from collections import defaultdict, deque
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flags
from repro.serving.batching import (
    Request,
    Response,
    bucket_length,
    finalize_tokens,
    pad_bucket,
)
from repro.serving.sampling import make_sampler, sampler_sig


class BlockPool:
    """Fixed-size KV block allocator. Block ids are indices into the device
    pool's block axis; block 0 is the reserved sink and is never handed out.
    Tracks ``peak_live`` (high-water mark of allocated blocks) for the
    residency benchmark."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("BlockPool needs >= 2 blocks (block 0 is the sink)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))   # LIFO reuse
        self._free_set = set(self._free)
        self.peak_live = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)} "
                f"of {self.num_blocks - 1}"
            )
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        self.peak_live = max(self.peak_live, self.live_blocks)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            # a double-free would hand one physical block to two requests —
            # silent KV corruption — so this must not be a strippable assert
            if not 0 < b < self.num_blocks or b in self._free_set:
                raise ValueError(f"bad free of block {b}: out of range, "
                                 "double-freed, or the sink")
            self._free.append(b)
            self._free_set.add(b)


class PagedScheduler:
    """Paged continuous batching over one engine (see module docstring).

    Produces token-identical greedy outputs to the contiguous
    ``SlotScheduler`` / ``serve_ragged(mode="continuous")`` on any trace —
    the paged attention path is parity-tested bit-exact against the
    contiguous deferred decode (tests/test_paged.py).
    """

    def __init__(self, engine, *, slots: int = 4, chunk: int = 4,
                 block_size: int = 8, num_blocks: int | None = None,
                 max_len: int | None = None, sampler: str = "greedy",
                 sampler_kw=None, spec_k: int | None = None, drafter=None):
        if not engine.model.supports_paged:
            raise ValueError(
                f"{engine.cfg.arch_id}: paged serving needs a block-pool cache "
                "(GQA decoder_lm families; MLA/recurrent keep the contiguous path)"
            )
        if spec_k is not None and spec_k < 2:
            raise ValueError(f"spec_k must be >= 2, got {spec_k}")
        self.engine = engine
        self.slots = slots
        self.chunk = chunk
        self.spec_k = spec_k
        self.block_size = block_size
        self.max_len = max_len if max_len is not None else engine.cache_len
        self.blocks_per_req = math.ceil(self.max_len / block_size)
        # default pool matches the contiguous footprint (worst case for every
        # slot); benchmarks/tests hand in smaller pools to exercise
        # backpressure — correctness never depends on pool size
        self.num_blocks = (num_blocks if num_blocks is not None
                           else slots * self.blocks_per_req + 1)
        self._sampler = make_sampler(sampler, **dict(sampler_kw or {}))
        self._prefill_jit = None
        self.last_peak_blocks = 0          # residency high-water of last serve
        self.last_positions: np.ndarray | None = None   # debug/introspection
        self.last_spec_stats = None        # per-serve speculative accounting
        # block lookahead per decode round: a verify chunk commits up to
        # spec_k rows per slot in one step
        self._ahead = chunk if spec_k is None else max(chunk, spec_k)
        if spec_k is not None:
            from repro.serving.spec import NgramDrafter, build_verify_step

            self._drafter = drafter if drafter is not None else NgramDrafter()
            self._verify_step = build_verify_step(
                engine.model, sampler=sampler, sampler_kw=sampler_kw,
                paged=True)

        model, sample, eos = engine.model, self._sampler, engine.eos_id
        mb = self.blocks_per_req

        # pool buffers are donated: the serve loop always rebinds the cache
        # to each call's result, and an undonated pool would transiently
        # double the very footprint this subsystem exists to shrink
        @partial(jax.jit, donate_argnums=(2,))
        def decode_until(params, tok, cache, table, pos, live, remaining, keys):
            """Decode up to ``chunk`` steps, but stop at the step ANY live
            slot finishes (EOS or budget) — the host frees/refills there."""
            nsteps, b = keys.shape[0], tok.shape[0]

            def cond(c):
                i, _, _, _, _, stop, _ = c
                return (i < nsteps) & ~stop

            def body(c):
                i, tok, cache, pos, remaining, stop, toks = c
                logits, cache = model.decode_paged(params, tok, cache, table, pos)
                nxt = sample(logits, keys[i])
                nxt = jnp.where(live, nxt, tok)        # frozen slots keep tok
                toks = toks.at[i].set(nxt)
                pos = jnp.where(live, pos + 1, pos)    # ...and their position
                remaining = jnp.where(live, remaining - 1, remaining)
                fin = live & (remaining <= 0)
                if eos is not None:
                    fin = fin | (live & (nxt == eos))
                return (i + 1, nxt, cache, pos, remaining, jnp.any(fin), toks)

            toks0 = jnp.zeros((nsteps, b), jnp.int32)
            i, tok, cache, pos, remaining, _, toks = jax.lax.while_loop(
                cond, body,
                (jnp.int32(0), tok, cache, pos, remaining, jnp.bool_(False), toks0))
            return toks, i, cache, pos

        @partial(jax.jit, donate_argnums=(0,))
        def insert(cache, rows, tables):
            # rows: contiguous prefill cache (L, bg, S, KV, hd); tables
            # (bg, S // block_size) physical block per prompt block (0=sink)
            def put(pages, r):
                ell, bg = r.shape[:2]
                rr = r.reshape(ell, bg, tables.shape[1], block_size, *r.shape[3:])
                return pages.at[:, tables].set(rr)
            return {"k_pages": put(cache["k_pages"], rows["k"]),
                    "v_pages": put(cache["v_pages"], rows["v"])}

        self._decode_until = decode_until
        self._insert = insert
        self._mb = mb

    # -- helpers ------------------------------------------------------------

    def _prefill_fn(self):
        if self._prefill_jit is None:
            model, sample = self.engine.model, self._sampler

            @jax.jit
            def prefill_group(params, toks, lens, key):
                # pad target == the padded prompt length: the paged pool is
                # the only persistent cache, so no cache_len-wide row exists
                logits, cache = model.prefill(
                    params, {"tokens": toks, "lengths": lens}, toks.shape[1]
                )
                return sample(logits, key), cache

            self._prefill_jit = prefill_group
        return self._prefill_jit

    def _prompt_pad(self, n: int) -> int:
        """Padded prefill length: the power-of-two bucket, rounded up to a
        whole number of blocks."""
        b = bucket_length(n)
        return math.ceil(b / self.block_size) * self.block_size

    def _blocks_needed(self, r: Request, budget: int) -> int:
        # decode commits positions len .. len+budget-2 (the first generated
        # token comes from prefill); prompt occupies 0 .. len-1
        last = len(r.tokens) + max(budget - 1, 0)
        return math.ceil(max(last, 1) / self.block_size)

    # -- serving ------------------------------------------------------------

    def serve(self, requests: Sequence[Request], max_new_tokens: int,
              *, key=None) -> list[Response]:
        if flags.get("kvt_cache_layout") or flags.get("int8_kv_cache"):
            raise ValueError("paged serving supports the base float KV layout "
                             "(kvt_cache_layout / int8_kv_cache flags off)")
        engine, B, bs, mb = self.engine, self.slots, self.block_size, self._mb
        eos = engine.eos_id

        def budget(r: Request) -> int:
            return r.max_new if r.max_new is not None else max_new_tokens

        # verify chunks index score columns up to pos + spec_k - 1, so the
        # speculative mode needs spec_k columns of table slack
        slack = self.spec_k or 0
        for r in requests:
            need = max(self._prompt_pad(len(r.tokens)),
                       len(r.tokens) + budget(r) + slack)
            if need > mb * bs:
                raise ValueError(
                    f"request {r.id}: len={len(r.tokens)} + max_new={budget(r)}"
                    + (f" + spec_k={slack}" if slack else "")
                    + f" needs {need} cache slots but the paged table covers "
                    f"{mb} blocks x {bs} = {mb * bs}"
                )
            if self._blocks_needed(r, budget(r)) > self.num_blocks - 1:
                raise ValueError(
                    f"request {r.id}: needs {self._blocks_needed(r, budget(r))} "
                    f"blocks but the pool has {self.num_blocks - 1}"
                )

        pool = BlockPool(self.num_blocks, bs)
        cache = engine.model.init_paged_cache(self.num_blocks, bs,
                                              engine.cfg.cdtype())
        pending = deque(requests)
        slot_req: list[Request | None] = [None] * B
        slot_toks: list[list[int]] = [[] for _ in range(B)]
        slot_blocks: list[list[int]] = [[] for _ in range(B)]
        slot_need = [0] * B                    # worst-case total blocks
        table = np.zeros((B, mb), np.int32)    # 0 = sink
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        live = np.zeros((B,), bool)
        remaining = np.zeros((B,), np.int32)
        out: dict[int, Response] = {}
        key = key if key is not None else jax.random.PRNGKey(0)
        self.last_spec_stats = (
            {"verify_steps": 0, "generated": 0, "drafted": 0, "accepted": 0}
            if self.spec_k is not None else None)

        def reserved_backlog() -> int:
            """Blocks the live slots may still demand beyond what they hold."""
            return sum(slot_need[s] - len(slot_blocks[s])
                       for s in range(B) if live[s])

        def finish(s: int):
            r = slot_req[s]
            toks_r, length = finalize_tokens(slot_toks[s], budget(r), eos)
            out[r.id] = Response(id=r.id, tokens=toks_r, length=length)
            pool.free(slot_blocks[s])
            slot_req[s], slot_toks[s], slot_blocks[s] = None, [], []
            slot_need[s] = 0
            table[s, :] = 0                    # stray writes go to the sink
            live[s] = False                    # position stays frozen

        def ensure_blocks(s: int):
            """Grow slot ``s`` to cover the next round of decode commits
            (``chunk`` single-token steps, or one spec_k-row verify chunk) —
            reservation-gated admission guarantees this never fails."""
            target = min(math.ceil((int(pos[s]) + self._ahead) / bs), slot_need[s])
            delta = target - len(slot_blocks[s])
            if delta > 0:
                new = pool.alloc(delta)
                start = len(slot_blocks[s])
                slot_blocks[s].extend(new)
                table[s, start:start + len(new)] = new

        while pending or live.any():
            # admit in arrival order while a slot AND worst-case pool space
            # are both available; one batched prefill per padded length
            free_slots = [s for s in range(B) if slot_req[s] is None]
            admitted: dict[int, list[tuple[int, Request]]] = defaultdict(list)
            while free_slots and pending:
                r = pending[0]
                nb = self._blocks_needed(r, budget(r))
                if nb > pool.free_blocks - reserved_backlog():
                    break                       # backpressure: decode frees
                pending.popleft()
                s = free_slots.pop(0)
                prompt_blocks = pool.alloc(math.ceil(len(r.tokens) / bs))
                slot_req[s], slot_toks[s] = r, []
                slot_blocks[s] = prompt_blocks
                slot_need[s] = nb
                table[s, :] = 0
                table[s, : len(prompt_blocks)] = prompt_blocks
                live[s] = True
                admitted[self._prompt_pad(len(r.tokens))].append((s, r))
            staged: list[tuple[list[tuple[int, Request]], jax.Array]] = []
            for length, group in admitted.items():
                reqs_g = [r for _, r in group]
                toks_np, lens_np = pad_bucket(reqs_g, length)
                key, kp = jax.random.split(key)
                t0_d, rows = self._prefill_fn()(
                    engine.params, jnp.asarray(toks_np), jnp.asarray(lens_np), kp
                )
                tables_g = jnp.asarray(
                    np.stack([table[s, : length // bs] for s, _ in group]))
                cache = self._insert(cache, rows, tables_g)
                staged.append((group, t0_d))
            if staged:
                # ONE host round-trip for the whole admission wave, not one
                # per bucket (host-sync chunk budget: admission + chunk)
                first_toks = jax.device_get([t for _, t in staged])
                for (group, _), t0 in zip(staged, first_toks):
                    for (s, r), t in zip(group, t0):
                        slot_toks[s] = [int(t)]
                        tok[s], pos[s] = int(t), len(r.tokens)
                        remaining[s] = budget(r) - 1
                        if self.last_spec_stats is not None:
                            # the prefill-sampled token is delivered work too
                            # — keeps 'generated' comparable with engine
                            # spec_stats
                            self.last_spec_stats["generated"] += 1
                        if budget(r) <= 1 or (eos is not None and int(t) == eos):
                            finish(s)

            if not live.any():
                if pending:
                    continue
                break

            for s in range(B):
                if live[s]:
                    ensure_blocks(s)

            key, kc = jax.random.split(key)
            if self.spec_k is not None:
                # speculative round: one verify forward advances every live
                # slot by 1..spec_k tokens; rejected rows never reach the
                # pool (out-of-bounds drop), blocks were grown to cover the
                # worst-case accepted chunk by ensure_blocks above
                from repro.serving.spec import draft_chunk, take_accepted

                K = self.spec_k
                chunk_np = draft_chunk(
                    self._drafter, tok, live,
                    lambda s: slot_req[s].tokens + slot_toks[s], K)
                out_d, n_out_d, cache, pos_d, _ = self._verify_step(
                    engine.params, jnp.asarray(chunk_np), cache,
                    jnp.asarray(table), jnp.asarray(pos), jnp.asarray(live),
                    jnp.asarray(remaining), kc,
                )
                out_np, n_out, pos = jax.device_get((out_d, n_out_d, pos_d))
                pos = pos.copy()
                st = self.last_spec_stats
                st["verify_steps"] += 1
                assert not live.any() or int(pos[live].max()) < mb * bs, (
                    f"live verify position escaped the block table: {pos[live]}")
                for s in np.flatnonzero(live):
                    slot_toks[s].extend(take_accepted(
                        out_np[s], n_out[s], remaining[s], eos, st, K))
                    tok[s] = slot_toks[s][-1]
                    n = budget(slot_req[s])
                    remaining[s] = n - len(slot_toks[s])
                    if len(slot_toks[s]) >= n or (
                            eos is not None and eos in slot_toks[s][:n]):
                        finish(s)
                continue
            toks_d, steps, cache, pos_d = self._decode_until(
                engine.params, jnp.asarray(tok), cache, jnp.asarray(table),
                jnp.asarray(pos), jnp.asarray(live), jnp.asarray(remaining),
                jax.random.split(kc, self.chunk),
            )
            # ONE host sync per round: int(steps) + two np.asarray() calls
            # were three separate device round-trips on the hot loop
            steps, toks_all, pos = jax.device_get((steps, toks_d, pos_d))
            toks_np = toks_all[: int(steps)]              # (steps, B)
            pos = pos.copy()
            assert not live.any() or int(pos[live].max()) < mb * bs, (
                f"live decode position escaped the block table: {pos[live]}")
            for s in range(B):
                if not live[s]:
                    continue
                n = budget(slot_req[s])
                slot_toks[s].extend(int(t) for t in toks_np[:, s])
                tok[s] = slot_toks[s][-1]
                remaining[s] = n - len(slot_toks[s])
                done = len(slot_toks[s]) >= n
                if eos is not None and eos in slot_toks[s][:n]:
                    done = True
                if done:
                    finish(s)

        self.last_positions = pos.copy()
        # the allocator's exact high-water mark (sampling pool.live_blocks at
        # loop points would miss peaks freed before the sample, e.g. prompt
        # blocks of budget<=1 requests finished at admission)
        self.last_peak_blocks = max(self.last_peak_blocks, pool.peak_live)
        return [out[r.id] for r in requests]


def serve_paged(engine, requests: Sequence[Request], max_new_tokens: int,
                *, sampler: str = "greedy", sampler_kw=None, key=None,
                slots: int = 4, chunk: int = 4, block_size: int = 8,
                num_blocks: int | None = None, spec_k: int | None = None,
                drafter=None) -> list[Response]:
    """Paged continuous batching through a per-engine cached scheduler."""
    cache = getattr(engine, "_paged_schedulers", None)
    if cache is None:
        cache = engine._paged_schedulers = {}
    sig = (slots, chunk, block_size, num_blocks, sampler,
           sampler_sig(sampler_kw), spec_k,
           id(drafter) if drafter is not None else None)
    if sig not in cache:
        cache[sig] = PagedScheduler(engine, slots=slots, chunk=chunk,
                                    block_size=block_size, num_blocks=num_blocks,
                                    sampler=sampler, sampler_kw=sampler_kw,
                                    spec_k=spec_k, drafter=drafter)
    sched = cache[sig]
    sched.last_peak_blocks = 0
    return sched.serve(requests, max_new_tokens, key=key)
