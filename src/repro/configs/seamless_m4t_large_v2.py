"""seamless-m4t-large-v2 [audio]: enc-dec, multimodal [arXiv:2308.11596; hf].

24L encoder + 24L decoder, d_model=1024 16H (kv=16, head_dim=64) d_ff=8192
vocab=256206. Speech frontend is a STUB: input_specs() supplies precomputed
frame embeddings (b, s_enc, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    model_type="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="frames",
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
