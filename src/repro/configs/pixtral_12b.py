"""pixtral-12b [vlm]: Pixtral ViT frontend (STUB) + Mistral-NeMo-style
backbone [hf:mistralai/Pixtral-12B-2409; unverified].

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
input_specs() supplies precomputed patch embeddings (b, 256, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    model_type="decoder_lm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="patch_embed",
    num_frontend_tokens=256,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
