"""deepseek-coder-33b [dense]: llama-arch GQA [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=19200 vocab=32256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-coder-33b",
    family="dense",
    model_type="decoder_lm",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
