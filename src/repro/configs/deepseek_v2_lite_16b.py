"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 2 shared + 64 routed
top-6 experts [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff=1408 (per expert) vocab=102400. All layers MoE
per the assigned spec (released model keeps layer 0 dense -- DESIGN.md).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    model_type="decoder_lm",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
    group_size=128,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
