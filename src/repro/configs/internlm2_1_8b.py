"""internlm2-1.8b [dense]: GQA llama-arch [arXiv:2403.17297; hf].

24L d_model=2048 16H (GQA kv=8, head_dim=128) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-1.8b",
    family="dense",
    model_type="decoder_lm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
