"""TinyLlama 1.1B -- the paper's own evaluation model (arXiv:2401.02385).

22L, d=2048, 32H (GQA kv=4), d_ff=5632, vocab=32000; GS=256 divides every
dim (paper SIII-A). This is the model behind Tables II/IV/V/VI.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="tinyllama-1.1b",
    family="dense",
    model_type="decoder_lm",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10_000.0,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
