"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any architecture in the assigned pool; family-
specific sub-configs (MoE / MLA / SSM) are optional attachments. Every config
file in this package exports ``CONFIG`` (full size, exact assigned dims) —
the full configs are only ever *lowered* (dry-run); smoke tests use
``reduced()`` variants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0            # shared (always-on) experts, deepseek-v2 style


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int
    q_lora_rank: int               # 0 => direct q projection
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    model_type: str                # decoder_lm | rwkv6 | zamba2 | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # gemma2 specifics
    gemma_norms: bool = False      # (1+w) RMSNorm, embed * sqrt(d), post-norms
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None     # gemma2 query_pre_attn_scalar
    sliding_window: Optional[int] = None
    layer_pattern: Optional[str] = None     # "LG" = alternating local/global

    tie_embeddings: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # zamba2 hybrid: one SHARED attention block applied every k SSM layers
    shared_attn_every: int = 0

    # enc-dec (seamless)
    encoder_layers: int = 0

    # modality frontend stubs (vlm / audio): input_specs() supplies embeddings
    frontend: Optional[str] = None          # patch_embed | frames
    num_frontend_tokens: int = 0

    group_size: int = 256                   # paper §III-A GS
    # PTQ weight format applied when serving with quantize=True: a registry
    # format name ("int8" = paper W8A8, "int4" = packed sub-byte) or a
    # policy preset ("mixed": embed/classifier int8, attn/ffn int4). See
    # core/quant.py (registry) and core/policy.py (format maps).
    quant_format: str = "int8"
    # KV-cache quantization: None keeps the float cache; "int8"/"fp8" store
    # contiguous AND paged KV at storage width with per-row (head_dim-group)
    # f32 scales in sibling leaves, dequantized inside attention. Threaded
    # from InferenceEngine(kv_quant=...) / serve --kv-quant via the config so
    # every model closure (init_cache, prefill, decode, decode_paged) sees it
    # without signature churn. GQA layouts only (MLA keeps the latent cache).
    kv_quant: Optional[str] = None
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    sub_quadratic: bool = False             # eligible for long_500k

    # ---------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 32 so embedding/classifier rows
        shard evenly over the 16-way model axis (labels never hit the pad)."""
        return ((self.vocab_size + 31) // 32) * 32

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            group_size=32,
            num_frontend_tokens=min(self.num_frontend_tokens, 4),
            encoder_layers=min(self.encoder_layers, 2),
            sliding_window=64 if self.sliding_window else None,
            shared_attn_every=2 if self.shared_attn_every else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.layer_pattern:
            changes["layer_pattern"] = self.layer_pattern[: changes["num_layers"]]
        if self.moe:
            changes["moe"] = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=64,
                num_shared=min(self.moe.num_shared, 1),
            )
        if self.mla:
            changes["mla"] = MLAConfig(
                kv_lora_rank=32,
                q_lora_rank=32 if self.mla.q_lora_rank else 0,
                qk_nope_dim=16,
                qk_rope_dim=16,
                v_head_dim=16,
            )
        if self.ssm:
            changes["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2, conv_kernel=4)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def step_name(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step", "decode": "serve_step"}[self.kind]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}
