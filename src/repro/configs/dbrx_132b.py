"""dbrx-132b [moe]: 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8, head_dim=128) d_ff=10752 (per expert)
vocab=100352; MoE 16e top-4 on every layer.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    model_type="decoder_lm",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752),
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
