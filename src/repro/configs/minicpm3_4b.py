"""minicpm3-4b [dense]: MLA attention [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA kv_lora=256, q_lora=768,
qk_nope=64, qk_rope=32, v_head=64.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    model_type="decoder_lm",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
