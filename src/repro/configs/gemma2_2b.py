"""gemma2-2b [dense]: local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000;
sliding_window=4096 on alternating (L) layers; attn softcap 50, final
softcap 30; (1+w) RMSNorm with pre+post block norms; tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-2b",
    family="dense",
    model_type="decoder_lm",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    gemma_norms=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=256.0,
    sliding_window=4096,
    layer_pattern="LG",
    tie_embeddings=True,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
