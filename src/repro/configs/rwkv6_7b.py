"""rwkv6-7b [ssm]: RWKV-6 "Finch" -- attention-free, data-dependent decay
[arXiv:2404.05892; hf].

32L d_model=4096 (64 heads x 64) d_ff=14336 vocab=65536. O(1) decode state
=> runs the long_500k cell (sub_quadratic=True).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    model_type="rwkv6",
    num_layers=32,
    d_model=4096,
    num_heads=64,
    num_kv_heads=0,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    sub_quadratic=True,
)
