"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (kv=32, head_dim=112) d_ff=14336 vocab=32000
ssm_state=64; one SHARED GQA+MLP block applied every 6 Mamba2 layers.
O(1) SSM state => runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    model_type="zamba2",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4),
    shared_attn_every=6,
    group_size=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    sub_quadratic=True,
)
