"""Training loop substrate: loss, train_step factory, checkpointed driver.

The paper is inference-only; training here is framework substrate (bf16/f32
weights). The int8 group-quantized gradient all-reduce (optim/compress.py)
is the paper's quantization idea applied to training communication and is
switchable per run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.models.registry import Model
from repro.optim import adamw
from repro.optim.compress import compressed_psum


def lm_loss(logits: jax.Array, labels: jax.Array, ignore_id: int = -1) -> jax.Array:
    """Mean token cross-entropy; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits = model.forward(params, batch)
        loss = lm_loss(logits, batch["labels"])
        return loss, {"loss": loss}

    return loss_fn


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    *, compress_axis: str | None = None) -> Callable:
    """Returns train_step(params, opt_state, batch[, residuals]).

    With ``compress_axis`` set (e.g. "pod" inside shard_map), gradients are
    int8-group-compressed with error feedback before the cross-axis psum.
    """
    loss_fn = make_loss_fn(model)

    if compress_axis is None:
        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            params, opt_state, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
            return params, opt_state, {**aux, **metrics}

        return train_step

    def train_step(params, opt_state, batch, residuals):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads, residuals = compressed_psum(grads, compress_axis, residuals=residuals)
        params, opt_state, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
        return params, opt_state, residuals, {**aux, **metrics}

    return train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    # straggler mitigation: steps slower than stall_factor x the rolling
    # median get flagged (on real fleets this feeds the health controller)
    stall_factor: float = 3.0


def run_loop(model: Model, params, data_iter, opt_cfg: adamw.AdamWConfig,
             loop_cfg: LoopConfig, *, train_step=None, resume: bool = True,
             log: Callable[[str], None] = print):
    """Single-host driver with checkpoint/restart + straggler flagging.
    Returns (params, opt_state, history)."""
    opt_state = adamw.init(params)
    start_step = 0
    if resume and ckpt.latest_step(loop_cfg.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        state, step, extra = ckpt.restore(loop_cfg.ckpt_dir, state)
        params, opt_state = state["params"], state["opt"]
        start_step = step
        log(f"[resume] restored step {step} from {loop_cfg.ckpt_dir}")

    step_fn = train_step or jax.jit(make_train_step(model, opt_cfg))
    history: list[dict[str, Any]] = []
    durations: list[float] = []

    for step in range(start_step, loop_cfg.total_steps):
        batch = jax.tree.map(jnp.asarray, data_iter.batch_at(step))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        durations.append(dt)
        med = sorted(durations)[len(durations) // 2]
        straggler = len(durations) > 5 and dt > loop_cfg.stall_factor * med
        rec = {"step": step + 1, "loss": float(metrics["loss"]),
               "grad_norm": float(metrics["grad_norm"]), "sec": dt,
               "straggler": straggler}
        history.append(rec)
        if straggler:
            log(f"[straggler] step {rec['step']} took {dt:.2f}s (median {med:.2f}s)")
        if (step + 1) % loop_cfg.log_every == 0:
            log(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
                f"gnorm {rec['grad_norm']:.3f}  {dt*1e3:.0f} ms")
        if (step + 1) % loop_cfg.ckpt_every == 0 or step + 1 == loop_cfg.total_steps:
            ckpt.save(loop_cfg.ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state},
                      extra={"data_step": step + 1})
            ckpt.retain(loop_cfg.ckpt_dir, loop_cfg.ckpt_keep)

    return params, opt_state, history
