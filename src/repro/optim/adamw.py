"""AdamW + cosine schedule + global-norm clipping (pure-pytree, optax-free).

Optimizer state is a pytree shaped like the params (m, v), so ZeRO-1-style
sharding falls out of the same partition rules the params use.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
