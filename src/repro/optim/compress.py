"""Group-wise INT8 gradient compression for cross-pod all-reduce
(beyond-paper: the paper's C1 quantization applied to gradients-in-flight).

On a 2-pod mesh the inter-pod ICI link is the scarcest bandwidth; group-wise
symmetric int8 (same scheme as the weights, Eq. 1) cuts cross-pod gradient
bytes ~4x vs f32 (~2x vs bf16). Error feedback keeps the quantization error
from accumulating: the residual of each step is added back before the next
compression [Seide et al. 2014 1-bit SGD lineage].

Usage inside a shard_mapped train step:
    g_q, scales = compress(g)                # local, per group
    g_q  = lax.psum(g_q.astype(int32), 'pod')   # int payload on the wire
    g    = decompress(g_q, psum(scales)) / npods
The all-reduce-of-int8-partials formulation here is the simple "quantize,
sum dequantized" variant: each pod contributes a dequantized-int8 gradient,
so the wire format per pod is int8 + f32 group scales.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import DEFAULT_GROUP_SIZE


def _groupable(leaf, group_size: int) -> bool:
    return leaf.ndim >= 1 and leaf.shape[-1] % group_size == 0


@partial(jax.jit, static_argnames=("group_size",))
def compress_leaf(g: jax.Array, group_size: int = DEFAULT_GROUP_SIZE):
    """-> (int8 qvalues, f32 scales); groups along the last axis."""
    shape = g.shape
    gg = g.reshape(*shape[:-1], shape[-1] // group_size, group_size).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(gg), axis=-1)
    scales = absmax * (2.0 / 255.0)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(gg / safe[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scales


@partial(jax.jit, static_argnames=("group_size",))
def decompress_leaf(q: jax.Array, scales: jax.Array, group_size: int = DEFAULT_GROUP_SIZE):
    gg = q.reshape(*q.shape[:-1], q.shape[-1] // group_size, group_size)
    return (gg.astype(jnp.float32) * scales[..., None]).reshape(q.shape)


def compressed_psum(grads, axis_name: str, group_size: int = DEFAULT_GROUP_SIZE,
                    residuals=None):
    """Error-feedback int8-group-quantized psum over ``axis_name``.

    Returns (mean_grads, new_residuals). Leaves whose trailing dim is not
    group-divisible fall back to plain psum (they are tiny: norms, biases).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if not _groupable(g, group_size):
            return jax.lax.pmean(g32, axis_name), jnp.zeros_like(g32)
        if r is not None:
            g32 = g32 + r
        q, s = compress_leaf(g32, group_size)
        local = decompress_leaf(q, s, group_size)
        residual = g32 - local                      # error feedback
        summed = jax.lax.psum(local, axis_name)
        return summed / n, residual

    if residuals is None:
        residuals = jax.tree.map(lambda _: None, grads,
                                 is_leaf=lambda x: x is None)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])
