"""Atomic, restartable checkpoints (fault-tolerance substrate).

Format: one directory per step containing a flat .npz of all leaves plus a
JSON manifest (treedef paths, shapes, dtypes, step, data-iterator state).
Writes go to ``<dir>/tmp.<step>`` then os.replace() -> crash-safe: a partial
write can never be mistaken for a complete checkpoint.

Restore is resharding-friendly: leaves come back as host numpy arrays; the
caller device_puts them with whatever sharding the *current* mesh dictates
(elastic restart after losing a pod re-lays-out automatically).

Quantized leaves (QuantizedTensor) flatten to their ``.../qvalues`` and
``.../scales`` children, so the array format is format-agnostic; the
manifest additionally records each leaf's quantization format name and
group size (``quant`` key) and restore refuses a tree whose declared
formats disagree — a packed-int4 qvalues array silently reinterpreted as
int8 rows would be shape-valid but numerically garbage.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

from repro.core.quant import QuantizedTensor
from repro.core.treepath import path_str

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = path_str(path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _quant_meta(tree) -> dict:
    """{tree path: {"fmt", "group_size"}} for every QuantizedTensor leaf."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )
    return {
        path_str(p): {"fmt": leaf.fmt, "group_size": leaf.group_size}
        for p, leaf in flat
        if isinstance(leaf, QuantizedTensor)
    }


def save(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomically write checkpoint for ``step``. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f"tmp.{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, ARRAYS), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "extra": extra or {},
        "quant": _quant_meta(tree),
        "format": 1,
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
            os.path.join(directory, name, MANIFEST)
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, like, step: int | None = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, ARRAYS))

    saved_q = manifest.get("quant")
    if saved_q is not None:
        for key, meta in _quant_meta(like).items():
            got = saved_q.get(key)
            if got is not None and got != meta:
                raise ValueError(
                    f"quantization mismatch for {key}: checkpoint has "
                    f"{got}, restore target expects {meta} — requantize "
                    "instead of reinterpreting packed qvalues"
                )

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = path_str(p)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest["extra"]


def retain(directory: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory) if n.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
