"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun_results.json.

    PYTHONPATH=src python -m repro.launch.report [results.json]
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(results: dict) -> str:
    rows = ["| cell | mesh | step | status | compile | params | arg bytes/dev | temp bytes/dev | collectives (per-dev bytes) |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        parts = key.split("|")
        arch, shape = parts[0], parts[1]
        if len(parts) > 3:
            arch += f" [{parts[3]}]"
        if r["status"] == "ok":
            mem = r["memory"]
            coll = r["collectives"]["bytes_by_kind"]
            coll_s = ", ".join(f"{k}:{fmt_bytes(v)}" for k, v in sorted(coll.items())) or "none"
            rows.append(
                f"| {arch} x {shape} | {r['mesh']} | {r['step']} | ok | {r['compile_s']}s "
                f"| {r['num_params']/1e9:.2f}B | {fmt_bytes(mem['argument_bytes'])} "
                f"| {fmt_bytes(mem['temp_bytes'])} | {coll_s} |")
        elif r["status"] == "skipped":
            rows.append(f"| {arch} x {shape} | {r['mesh']} | {r['step']} | SKIP | - | - | - | - | {r['reason'][:60]} |")
        else:
            rows.append(f"| {arch} x {shape} | {r['mesh']} | {r['step']} | **ERROR** | - | - | - | - | {r['error'][:60]} |")
    return "\n".join(rows)


def roofline_table(results: dict, mesh_filter: str = "single") -> str:
    rows = ["| arch x shape | chips | compute | memory | collective | dominant | step | MODEL_FLOPs | useful ratio | MFU |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        if r["status"] != "ok":
            continue
        parts = key.split("|")
        if parts[2] != mesh_filter:
            continue
        arch, shape = parts[0], parts[1]
        if len(parts) > 3:
            arch += f" [{parts[3]}]"
        rl = r["roofline"]
        rows.append(
            f"| {arch} x {shape} | {rl['chips']} | {fmt_s(rl['compute_s'])} "
            f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
            f"| **{rl['dominant']}** | {fmt_s(rl['step_s'])} "
            f"| {rl['model_flops']:.2e} | {rl['useful_flops_ratio']:.3f} "
            f"| {rl['mfu']:.4f} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print("## Dry-run table (both meshes)\n")
    print(dryrun_table(results))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(results, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(results, "multi"))


if __name__ == "__main__":
    main()
