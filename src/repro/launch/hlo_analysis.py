"""Compat shim: the HLO analyzer moved to ``repro.analysis.hlo`` so the
repro-lint xray checkers and the launch roofline report share one
implementation (DESIGN.md §14).  Existing callers
(``launch/dryrun.py``, tests) keep importing from here."""

from repro.analysis.hlo import *  # noqa: F401,F403
from repro.analysis.hlo import (  # noqa: F401
    _DTYPE_BYTES,
    _shape_bytes_from_str,
    _shape_numel,
    _dot_flops,
)
