"""Post-SPMD HLO analysis: FLOPs, HBM traffic, collective bytes — with
while-loop (lax.scan) trip-count expansion.

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a
while body ONCE, so any scan-over-layers model (all of ours) is undercounted
by ~num_layers x. We therefore walk the per-device optimized HLO text
ourselves:

  * instruction table: every ``%name = shape op(operands)`` line, so operand
    shapes resolve through references;
  * call graph: while(condition/body) edges carry the loop trip count
    (largest integer constant in the condition computation — exact for
    lax.scan), fusion/call edges carry 1;
  * FLOPs: dot/convolution instructions (2 * numel(out) * contraction),
    walked through fusion bodies too;
  * HBM bytes: operand + output bytes of materialized instructions (fusion
    boundaries), skipping bookkeeping ops — the read+write traffic model;
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Everything is per device. ``compiled.cost_analysis()`` numbers are kept in
the report as a cross-check column.

Roofline (TPU v5e targets; container is CPU-only so terms are derived):
  compute term    = FLOPs / 197e12            per chip
  memory term     = HBM bytes / 819e9         per chip
  collective term = collective bytes / 50e9   per ICI link
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12        # bf16 FLOP/s per v5e chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    # *-done ops alias the corresponding -start buffers
    "all-gather-done", "all-reduce-done", "collective-permute-done",
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "u1": 1, "s1": 1,
}

_SHAPE_TOK = r"(?:" + "|".join(_DTYPE_BYTES) + r")\[[0-9,]*\](?:\{[^}]*\})?"
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?\s*?)\s*([a-z][a-z0-9\-]*)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _shape_bytes_from_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    line: str
    is_root: bool = False


@dataclasses.dataclass
class HLOReport:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    bytes_by_kind: dict[str, float]
    flops_by_op: dict[str, float]
    num_collectives: dict[str, int]


def parse_module(hlo_text: str):
    """-> (comps: name->list[Instr], entry_name, instr_table name->Instr)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    current = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if "->" in line and line.endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, shape, op = im.group(1), im.group(2), im.group(3)
        # operands: %refs inside the first paren group
        paren = line.find(op + "(") + len(op)
        depth, j = 0, paren
        end = len(line)
        for j in range(paren, len(line)):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        operands = _OPERAND_RE.findall(line[paren:end])
        comps[current].append(
            Instr(name, shape, op, operands, line, is_root="ROOT" in line.split("=")[0])
        )
    table = {i.name: i for instrs in comps.values() for i in instrs}
    return comps, entry, table


def _dot_flops(instr: Instr, table) -> float:
    """2 * numel(output) * prod(contraction dims of lhs)."""
    out_n = _shape_numel(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if not m or not instr.operands:
        return 2.0 * out_n  # degenerate
    lhs = table.get(instr.operands[0])
    if lhs is None:
        return 2.0 * out_n
    lm = _SHAPE_RE.search(lhs.shape)
    if not lm:
        return 2.0 * out_n
    dims = [int(d) for d in lm.group(2).split(",")] if lm.group(2) else []
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            k *= dims[idx]
    return 2.0 * out_n * k


def analyze(hlo_text: str, *, top_k: int = 0) -> HLOReport | tuple:
    comps, entry, table = parse_module(hlo_text)
    if entry is None:
        for cand in ("main", "main.0"):
            if cand in comps:
                entry = cand
        if entry is None and comps:
            entry = next(iter(comps))

    def trip_count(cond: str) -> int:
        best = 1
        for i in comps.get(cond, ()):  # largest int constant in the condition
            for c in _CONST_INT_RE.findall(i.line):
                best = max(best, int(c))
        return best

    # multiplicity of every computation, walking while/fusion/call edges
    mult: dict[str, float] = defaultdict(float)
    fusion_only: dict[str, bool] = {}   # True -> count flops but not bytes

    def visit(name: str, m: float, in_fusion: bool, depth=0):
        if depth > 64 or name not in comps:
            return
        mult[name] += m
        if name in fusion_only:
            fusion_only[name] = fusion_only[name] and in_fusion
        else:
            fusion_only[name] = in_fusion
        for i in comps[name]:
            if i.op == "while":
                c = _COND_RE.search(i.line)
                b = _BODY_RE.search(i.line)
                if b:
                    t = trip_count(c.group(1)) if c else 1
                    visit(b.group(1), m * t, in_fusion, depth + 1)
                    if c:
                        visit(c.group(1), m * t, True, depth + 1)  # cond: flops-only
            elif i.op in ("fusion", "call", "conditional", "custom-call", "map", "reduce", "sort", "scatter"):
                for cm in _CALLS_RE.finditer(i.line):
                    visit(cm.group(1), m, True, depth + 1)
                # conditional: branch computations appear as operands refs —
                # also matched via calls= when printed; branches w/o calls=
                # are rare in our graphs

    visit(entry, 1.0, False)

    flops_by_op: dict[str, float] = defaultdict(float)
    bytes_by_kind: dict[str, float] = defaultdict(float)
    num_collectives: dict[str, int] = defaultdict(int)
    hbm = 0.0

    def _dims_key(shape: str) -> str:
        """Dims signature ignoring dtype/layout: CPU-backend f32<->bf16
        promotion around dots must not defeat in-place alias detection
        (on TPU those converts don't exist)."""
        m = _SHAPE_RE.search(shape)
        return m.group(2) if m else shape.strip()

    # --- TPU normalization --------------------------------------------------
    # The CPU backend promotes bf16 dot/attention math to f32, materializing
    # convert chains (and duplicated f32 copies of bf16 buffers) that a TPU
    # module would not contain. Normalization rules (documented in DESIGN.md):
    #   * pure dtype-convert instructions/fusions cost 0 bytes;
    #   * operand reads resolve through convert/bitcast/copy chains and are
    #     charged at the NARROWEST width along the chain.

    _XPARENT_OPS = {"convert", "bitcast", "copy"}

    def _is_pure_convert_fusion(i: Instr) -> bool:
        # copy inside a convert fusion is layout assignment of the same
        # logical convert; on TPU none of this chain exists (native bf16/int8
        # operands feed the MXU directly)
        body = fusion_body(i)
        if not body:
            return False
        return all(s.op in ("parameter", "convert", "bitcast", "constant", "copy")
                   for s in body)

    _SLICE_CONVERT_BODY = {"parameter", "constant", "dynamic-slice", "slice",
                           "convert", "bitcast", "copy", "transpose"}

    def _is_slice_convert_fusion(i: Instr) -> bool:
        """Fusion that only selects a slice of a buffer and changes its
        dtype/layout (cache-layer pick + f32 promotion, int8 weight widening,
        weight transposes for CPU gemms). On TPU the consumer reads the
        source slice directly: charge nothing here; consumers charge the
        read at the narrowest width via effective_operand_bytes."""
        body = fusion_body(i)
        if not body:
            return False
        return all(s.op in _SLICE_CONVERT_BODY for s in body)

    def _min_chain_width(i: Instr) -> int:
        """Smallest dtype width appearing in a slice/convert fusion body."""
        widths = [
            _DTYPE_BYTES[m.group(1)]
            for s in fusion_body(i)
            for m in [_SHAPE_RE.search(s.shape)]
            if m
        ]
        m = _SHAPE_RE.search(i.shape)
        if m:
            widths.append(_DTYPE_BYTES[m.group(1)])
        return min(widths) if widths else 4

    def effective_operand_bytes(name: str, depth: int = 0) -> int:
        src = table.get(name)
        if src is None:
            return 0
        b = _shape_bytes_from_str(src.shape)
        if src.op == "fusion" and _is_slice_convert_fusion(src) and not \
                _is_pure_convert_fusion(src):
            return _shape_numel(src.shape) * _min_chain_width(src)
        if depth < 4 and src.operands:
            if src.op in _XPARENT_OPS or (
                src.op == "fusion" and _is_pure_convert_fusion(src)
            ):
                inner = effective_operand_bytes(src.operands[0], depth + 1)
                if inner:
                    b = min(b, inner)
        return b

    def operand_bytes(i: Instr, skip_dims: set[str] | None = None) -> int:
        tot = 0
        for o in i.operands:
            src = table.get(o)
            if src is None:
                continue
            if skip_dims is not None and _dims_key(src.shape) in skip_dims:
                continue
            tot += effective_operand_bytes(o)
        return tot

    def fusion_body(i: Instr):
        cm = _CALLS_RE.search(i.line)
        return comps.get(cm.group(1), []) if cm else []

    def fusion_root_op(i: Instr) -> str:
        """Root op, chasing through trailing converts/bitcasts (the CPU
        backend wraps DUS roots in dtype converts)."""
        body = fusion_body(i)
        root = next((s for s in body if s.is_root), None)
        by_name = {s.name: s for s in body}
        hops = 0
        while root is not None and root.op in ("convert", "bitcast") and hops < 4:
            nxt = by_name.get(root.operands[0]) if root.operands else None
            root = nxt
            hops += 1
        return root.op if root else ""

    _SLICE_OPS = {"dynamic-slice", "slice", "gather"}

    def fusion_read_bytes(i: Instr, skip_dims: set[str] | None = None) -> float:
        """Resolve reads through the fusion body: a fused operand consumed
        only by (dynamic-)slice/gather is read at the slice size (cache
        layer selection / embedding rows), not the full buffer."""
        body = fusion_body(i)
        if not body:
            return operand_bytes(i, skip_dims)
        params: dict[int, str] = {}
        for sub in body:
            if sub.op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", sub.line)
                if pm:
                    params[int(pm.group(1))] = sub.name
        total = 0.0
        for idx, oname in enumerate(i.operands):
            src = table.get(oname)
            if src is None:
                continue
            if skip_dims is not None and _dims_key(src.shape) in skip_dims:
                continue
            full = effective_operand_bytes(oname)
            pname = params.get(idx)
            if pname is None:
                total += full
                continue
            consumers = [s for s in body if pname in s.operands]
            if consumers and all(c.op in _SLICE_OPS for c in consumers):
                total += min(full, sum(_shape_bytes_from_str(c.shape) for c in consumers))
            else:
                total += full
        return total

    def instr_hbm_bytes(i: Instr) -> float:
        """Read+write traffic model with in-place / sparse-access semantics:
        dynamic-update-slice writes only the updated slice (the cache-append
        pattern of every decode step); slicing/gather reads only what it
        produces; fusion reads resolve through the body."""
        out_b = _shape_bytes_from_str(i.shape)
        is_fusion = i.op == "fusion"
        if i.op == "convert" or (is_fusion and _is_pure_convert_fusion(i)):
            return 0.0          # TPU normalization: no CPU f32-promotion
        if is_fusion and _is_slice_convert_fusion(i):
            return 0.0          # consumers charge the slice read (see above)
        root = fusion_root_op(i) if is_fusion else ""
        if i.op == "dynamic-update-slice" or (is_fusion and root == "dynamic-update-slice"):
            # in-place: read+write the update-sized data only; the aliased
            # (same-dims) destination operand is skipped
            small = fusion_read_bytes(i, skip_dims={_dims_key(i.shape)}) if is_fusion \
                else operand_bytes(i, skip_dims={_dims_key(i.shape)})
            return 2.0 * small
        if is_fusion and root == "select":
            # the CPU backend lowers strided dynamic-update-slice to a
            # full-buffer select(iota==pos); TPU performs an in-place DUS.
            # Pattern: exactly one operand matches the output dims+dtype and
            # every other operand is small -> charge the update only.
            shapes = [table[o].shape for o in i.operands if o in table]
            matching = [s for s in shapes if _dims_key(s) == _dims_key(i.shape)]
            others = [
                _shape_bytes_from_str(s) for s in shapes
                if _dims_key(s) != _dims_key(i.shape)
            ]
            if len(matching) == 1 and all(b <= out_b / 8 for b in others):
                return 2.0 * sum(others)
        if i.op in _SLICE_OPS:
            return 2.0 * out_b
        if i.op == "scatter":
            upd = (
                _shape_bytes_from_str(table[i.operands[2]].shape)
                if len(i.operands) >= 3 and i.operands[2] in table
                else out_b
            )
            return 2.0 * upd
        if is_fusion:
            return fusion_read_bytes(i) + out_b
        return operand_bytes(i) + out_b

    contributions: list[tuple[float, float, str, str, str]] = []
    for name, instrs in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        only_flops = fusion_only.get(name, False)
        for i in instrs:
            if i.op in ("dot", "convolution"):
                flops_by_op[i.op] += m * _dot_flops(i, table)
            if only_flops:
                continue
            base = i.op.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                b = operand_bytes(i) or _shape_bytes_from_str(i.shape)
                bytes_by_kind[base] += m * b
                num_collectives[base] += int(m)
                hbm += m * (b + _shape_bytes_from_str(i.shape))
                if top_k:
                    contributions.append((m * b, m, base, i.name, i.shape[:60]))
            elif i.op not in _SKIP_BYTES_OPS and i.op != "while":
                b = instr_hbm_bytes(i)
                hbm += m * b
                if top_k:
                    contributions.append((m * b, m, i.op, i.name, i.shape[:60]))

    if top_k:
        contributions.sort(reverse=True)
        return HLOReport(
            flops=sum(flops_by_op.values()),
            hbm_bytes=hbm,
            collective_bytes=sum(bytes_by_kind.values()),
            bytes_by_kind=dict(bytes_by_kind),
            flops_by_op=dict(flops_by_op),
            num_collectives=dict(num_collectives),
        ), contributions[:top_k]

    return HLOReport(
        flops=sum(flops_by_op.values()),
        hbm_bytes=hbm,
        collective_bytes=sum(bytes_by_kind.values()),
        bytes_by_kind=dict(bytes_by_kind),
        flops_by_op=dict(flops_by_op),
        num_collectives=dict(num_collectives),
    )


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    flops: float               # per device
    hbm_bytes: float           # per device
    collective_bytes: float    # per device
    chips: int
    model_flops: float = 0.0   # 6*N*D analytic (global)
    xla_flops: float = 0.0     # cost_analysis cross-check (per device, no loop mult)
    xla_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (global): remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """model FLOPs / (chips * peak * step_s): roofline-fraction score."""
        denom = self.chips * PEAK_FLOPS * self.step_s
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "xla_flops_per_device": self.xla_flops,
            "xla_bytes_per_device": self.xla_bytes,
        }


def roofline_from_compiled(compiled, chips: int, model_flops: float = 0.0) -> tuple[Roofline, HLOReport]:
    rep = analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = ca or {}
    rl = Roofline(
        flops=rep.flops,
        hbm_bytes=rep.hbm_bytes,
        collective_bytes=rep.collective_bytes,
        chips=chips,
        model_flops=model_flops,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
    )
    return rl, rep
