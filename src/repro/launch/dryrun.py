import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run (deliverable e): lower + compile every
# (architecture x input shape) cell on the production meshes and record
# memory/cost/roofline terms. MUST set XLA_FLAGS before any jax import.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import hlo as hlo_analysis  # noqa: E402
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec  # noqa: E402
from repro.core import flags as perf_flags  # noqa: E402
from repro.core.policy import quantize_params  # noqa: E402
from repro.dist import logical  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import ARCH_IDS, build, input_specs, load_config  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402

RESULTS_PATH = "experiments/dryrun_results.json"

ASSIGNED = [a for a in ARCH_IDS if a != "tinyllama-1.1b"]


def cell_skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 500k decode cache/attn is quadratic-class (DESIGN.md)"
    return None


def count_params(struct) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(struct)
               if hasattr(l, "shape") and l.ndim > 0)


def model_flops(cfg: ModelConfig, shape: ShapeSpec, n_params: int) -> float:
    """6*N*D (train) / 2*N*D (inference); N = active params for MoE."""
    n = n_params
    if cfg.moe:
        m = cfg.moe
        expert_p = cfg.num_layers * m.num_experts * 3 * m.d_expert * cfg.d_model
        active = cfg.num_layers * (m.top_k + m.num_shared) * 3 * m.d_expert * cfg.d_model
        n = n - expert_p + active
    if cfg.model_type == "encdec" and shape.kind != "train":
        n = n  # decoder+cross only dominate; keep total (conservative)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (fn, example_args, in_shardings, donate) for jit."""
    model = build(cfg)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        params = jax.eval_shape(model.init, key)
        opt = jax.eval_shape(adamw.init, params)
        batch = input_specs(cfg, shape)
        p_specs = shd.param_specs(params, mesh, "train")
        o_specs = adamw.AdamWState(
            step=P(),
            m=shd.param_specs(params, mesh, "train"),
            v=shd.param_specs(params, mesh, "train"),
        )
        b_specs = shd.batch_specs(batch, mesh)
        opt_cfg = adamw.AdamWConfig()
        step_fn = make_train_step(model, opt_cfg)
        in_sh = (shd.shardings(p_specs, mesh),
                 jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                              is_leaf=lambda x: isinstance(x, P)),
                 shd.shardings(b_specs, mesh))
        out_sh = (in_sh[0], in_sh[1],
                  jax.tree.map(lambda _: NamedSharding(mesh, P()), {"loss": 0, "grad_norm": 0, "lr": 0}))
        return step_fn, (params, opt, batch), in_sh, out_sh, (0, 1), params

    # serving cells run quantized weights in the config's format (paper
    # default W8A8; packed/mixed formats validate their shard geometry)
    params = jax.eval_shape(model.init, key)
    qparams = jax.eval_shape(
        lambda p: quantize_params(p, cfg.group_size, tp=mesh.shape["model"],
                                  formats=cfg.quant_format), params
    )
    shd.validate_quant_partition(qparams, mesh, mode="serve")
    qp_specs = shd.param_specs(qparams, mesh, "serve")
    qp_sh = shd.shardings(qp_specs, mesh)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        b_specs = shd.batch_specs(batch, mesh)

        def prefill_step(p, b):
            return model.prefill(p, b, shape.seq_len)

        cache = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len, cfg.cdtype()))
        c_specs = shd.cache_specs(cache, mesh, shape.global_batch)
        out_sh = (NamedSharding(mesh, shd.logits_spec(mesh, 2, shape.global_batch)), shd.shardings(c_specs, mesh))
        return prefill_step, (qparams, batch), (qp_sh, shd.shardings(b_specs, mesh)), out_sh, (), qparams

    # decode
    cache = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len, cfg.cdtype()))
    c_specs = shd.cache_specs(cache, mesh, shape.global_batch)
    c_sh = shd.shardings(c_specs, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    dp = shd.dp_axes(mesh)
    tok_sh = NamedSharding(
        mesh, P(dp) if shape.global_batch % max(1, int(jnp.prod(jnp.asarray([mesh.shape[a] for a in dp])))) == 0 and dp else P()
    )

    def serve_step(p, t, c, ps):
        return model.decode(p, t, c, ps)

    out_sh = (NamedSharding(mesh, shd.logits_spec(mesh, 2, shape.global_batch)), c_sh)
    return serve_step, (qparams, tok, cache, pos), (qp_sh, tok_sh, c_sh, NamedSharding(mesh, P())), out_sh, (2,), qparams


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline") -> dict:
    cfg = load_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": shape.step_name,
        "variant": variant,
    }
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate, params_struct = build_cell(cfg, shape, mesh)
        with mesh, logical.use_mesh_rules(mesh):
            lowered = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
            ).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            n_params = count_params(params_struct)
            mf = model_flops(cfg, shape, n_params)
            rl, rep = hlo_analysis.roofline_from_compiled(
                compiled, mesh.devices.size, model_flops=mf
            )
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "num_params": n_params,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            "roofline": rl.as_dict(),
            "collectives": {"bytes_by_kind": rep.bytes_by_kind,
                            "counts": rep.num_collectives},
        })
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def load_results(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_PATH)
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--variant", default="baseline",
                    help="label for this run; non-baseline keys get suffixed")
    ap.add_argument("--set", action="append", default=[], metavar="FLAG=VAL",
                    help="perf flag overrides, e.g. --set blockwise_attention=1")
    args = ap.parse_args()

    for kv in args.set:
        k, v = kv.split("=", 1)
        perf_flags.FLAGS[k] = int(v) if v.isdigit() else v

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = load_results(args.out)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if args.variant != "baseline":
                    key += f"|{args.variant}"
                if key in results and results[key]["status"] == "ok" and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key}", flush=True)
                rec = run_cell(arch, shape, mp, variant=args.variant)
                results[key] = rec
                save_results(args.out, results)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']} step={r['step_s']:.4f}s "
                             f"mfu={r['mfu']:.3f} compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{status}] {key}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
