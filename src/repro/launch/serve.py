"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Quantizes the weights with group-wise PTQ — the paper's W8A8 by default,
or any registry format / mixed-precision policy via --quantize-format —
then serves a batch of requests (greedy by default, like the paper's SQuAD
evaluation).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import format_breakdown
from repro.models.registry import build, load_config
from repro.serving.engine import InferenceEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=64, help="tokens to generate")
    ap.add_argument("--no-quantize", action="store_true",
                    help="fp32 'PS baseline' instead of quantized weights")
    ap.add_argument("--quantize-format", default=None,
                    help="registry format (int8, int4) or policy preset "
                         "(mixed); default: the arch config's quant_format")
    ap.add_argument("--kv-quant", default=None, choices=["int8", "fp8"],
                    help="store the KV cache quantized (per-row scales; "
                         "dequantized in-kernel). Needs a paged-capable "
                         "arch; incompatible with --spec-k")
    ap.add_argument("--sampler", default="greedy", choices=["greedy", "top_p"])
    ap.add_argument("--top-p", type=float, default=0.9,
                    help="nucleus mass for --sampler top_p")
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="softmax temperature for --sampler top_p")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ragged", action="store_true",
                    help="serve a mixed-length trace through serve_ragged "
                         "(paged/continuous-batching scheduler where supported)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots for --ragged continuous batching")
    ap.add_argument("--mode", default="auto",
                    help="--ragged scheduler: auto, paged, continuous, or "
                         "bucketed (auto prefers paged; validated against "
                         "the arch's capabilities, not a static list)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="KV block size (tokens) for the paged scheduler")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode chunk: verify the current token "
                         "plus spec_k-1 drafted candidates per forward pass "
                         "(0 = off; needs >= 2)")
    ap.add_argument("--drafter", default="ngram",
                    help="speculative drafter: 'ngram' (zero-weight "
                         "prompt-lookup) or 'model:<arch-id>' (small "
                         "registry model, greedy drafts)")
    ap.add_argument("--sanitize", action="store_true",
                    help="repro-san debug mode (DESIGN.md §13): shadow "
                         "block/slot tracking, poison-on-free UAF detection, "
                         "NaN/Inf tripwires (equivalent to REPRO_SAN=1)")
    args = ap.parse_args(argv)
    sampler_kw = ({"p": args.top_p, "temperature": args.temperature}
                  if args.sampler == "top_p" else None)
    spec_k = args.spec_k or None
    drafter = None
    if spec_k:
        from repro.serving.spec import resolve_drafter

        drafter = resolve_drafter(args.drafter, reduced=args.reduced,
                                  seed=args.seed + 7)

    cfg = load_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    cache_len = args.prompt_len + args.steps + (spec_k or 0)
    if args.ragged:
        from repro.serving.batching import bucket_length

        # ragged prompts are padded up to power-of-two buckets
        cache_len = max(cache_len, bucket_length(args.prompt_len))
    quantize: bool | str = not args.no_quantize
    if quantize and args.quantize_format is not None:
        quantize = args.quantize_format
    if spec_k and args.kv_quant:
        ap.error("--kv-quant is incompatible with --spec-k (the verify pass "
                 "rolls the cache write cursor back; quantized rows cannot "
                 "be partially rewritten)")
    try:
        engine = InferenceEngine(model, params, cache_len=cache_len,
                                 quantize=quantize, kv_quant=args.kv_quant,
                                 sanitize=True if args.sanitize else None)
    except ValueError as e:
        ap.error(str(e))
    breakdown = format_breakdown(engine.params)
    print(f"arch: {cfg.arch_id}  quantized bytes fraction: "
          f"{engine.quantized_fraction:.3f}  "
          + "  ".join(f"{k}: {v / 1e6:.2f}MB" for k, v in sorted(breakdown.items())))

    rng = np.random.default_rng(args.seed)

    if args.ragged:
        from repro.serving.batching import Request, serve_ragged

        lengths = rng.integers(2, args.prompt_len + 1, size=(args.batch,))
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=(n,)).tolist())
                for i, n in enumerate(lengths)]
        from repro.serving.batching import resolve_mode

        try:
            mode = resolve_mode(engine, args.mode)    # resolved for the report
        except ValueError as e:
            ap.error(str(e))    # lists the valid modes for this arch
        kw = dict(sampler=args.sampler, sampler_kw=sampler_kw,
                  slots=args.slots, mode=mode, block_size=args.block_size,
                  spec_k=spec_k, drafter=drafter)
        serve_ragged(engine, reqs, args.steps, **kw)     # warm/compile
        t0 = time.perf_counter()
        out = serve_ragged(engine, reqs, args.steps, **kw,
                           key=jax.random.PRNGKey(args.seed + 1))
        hot = time.perf_counter() - t0
        toks = sum(r.tokens.shape[0] for r in out)
        print(f"ragged ({mode}, lengths {sorted(lengths.tolist())}): "
              f"{toks} tokens in {hot:.2f}s ({toks / hot:.2f} tok/s)")
        print("first sequence:", out[0].tokens[:16].tolist())
        return out

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        dtype=jnp.int32)}
    if cfg.model_type == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))

    t0 = time.perf_counter()
    res = engine.generate(batch, args.steps, sampler=args.sampler,
                          sampler_kw=sampler_kw, spec_k=spec_k,
                          drafter=drafter,
                          key=jax.random.PRNGKey(args.seed))
    jax.block_until_ready(res.tokens)
    warm = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = engine.generate(batch, args.steps, sampler=args.sampler,
                          sampler_kw=sampler_kw, spec_k=spec_k,
                          drafter=drafter,
                          key=jax.random.PRNGKey(args.seed + 1))
    jax.block_until_ready(res.tokens)
    hot = time.perf_counter() - t0

    toks = args.batch * args.steps
    print(f"generated {toks} tokens: warm {warm:.2f}s, hot {hot:.2f}s "
          f"({toks / hot:.2f} tok/s)")
    if res.spec_stats:
        st = res.spec_stats
        acc = st["accepted"] / max(st["drafted"], 1)
        print(f"speculative: {st['verify_steps']} verify steps for "
              f"{st['generated']} tokens "
              f"({st['verify_steps'] / max(st['generated'], 1):.2f} fwd/tok, "
              f"acceptance {acc:.2f})")
    print("first sequence:", np.asarray(res.tokens[0])[:16].tolist())
    return res


if __name__ == "__main__":
    main()
