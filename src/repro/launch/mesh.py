"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod.

    Axes: data (DP/FSDP), model (TP/EP/SP); the leading pod axis carries
    cross-pod data parallelism with hierarchical gradient reduction.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now (tests / examples / elastic restart)."""
    from repro.ft.elastic import elastic_mesh

    return elastic_mesh()
