"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs on whatever devices exist (laptop CPU -> full pod): the mesh is built
elastically, sharding rules key off axis names, and --resume auto restores
the newest complete checkpoint (fault-tolerant restart path).
"""

from __future__ import annotations

import argparse

import jax

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist import logical
from repro.dist import sharding as shd
from repro.ft.elastic import elastic_mesh
from repro.models.registry import build, load_config
from repro.optim import adamw
from repro.train.loop import LoopConfig, make_train_step, run_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke/e2e runs)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = load_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = elastic_mesh(model_parallel=min(16, len(jax.devices())))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}  arch: {cfg.arch_id}")

    params = model.init(jax.random.PRNGKey(args.seed))
    p_specs = shd.param_specs(params, mesh, "train")
    params = jax.device_put(params, shd.shardings(p_specs, mesh))

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 20))
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir)

    with mesh, logical.use_mesh_rules(mesh):
        step_fn = jax.jit(make_train_step(model, opt_cfg))
        params, _, history = run_loop(
            model, params, data, opt_cfg, loop_cfg,
            train_step=step_fn, resume=not args.no_resume,
        )
    print(f"final loss: {history[-1]['loss']:.4f}")
    return history


if __name__ == "__main__":
    main()
