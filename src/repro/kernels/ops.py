"""Public jit'd entry points for quantized matmul kernels.

Dispatch is two-dimensional:

``impl`` (backend):
  'pallas'    pl.pallas_call, compiled for TPU (Mosaic)
  'interpret' same kernel body, Pallas interpreter on CPU (validation)
  'xla'       pure-XLA int8 dot_general path, bit-identical math; used by
              the distributed models and the dry-run, where the CPU backend
              cannot compile Mosaic kernels (see DESIGN.md §2)
  'auto'      pallas on TPU, xla elsewhere

kernel hook (weight format): every :class:`~repro.core.quant.QuantFormat`
names a hook (``fmt.kernel``); ``KERNEL_HOOKS`` maps it to the XLA oracle
and Pallas kernel pair for both the matrix-vector (GQMV) and batched (GQMM)
shapes. Registering a new weight format therefore means one
``QuantFormat`` entry in core/quant.py plus one ``KernelHook`` row here —
qlinear/policy/engine code never changes (DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax

from repro.core.quant import QuantizedTensor, get_format, quantize_activation
from repro.kernels import gqmv as _pallas
from repro.kernels import paged_attn as _paged
from repro.kernels import ref as _ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve(impl: str) -> str:
    return _default_impl() if impl == "auto" else impl


@dataclasses.dataclass(frozen=True)
class KernelHook:
    """GQMV/GQMM implementations for one weight storage format. All four
    callables share the signature (wq, ws, xq, xs, *, group_size[, ...]);
    ``wq`` is the format's STORAGE array (packed for sub-byte formats),
    activations are always int8 (W{b}A8)."""

    gqmv_xla: Callable
    gqmm_xla: Callable
    gqmv_pallas: Callable
    gqmm_pallas: Callable


KERNEL_HOOKS: dict[str, KernelHook] = {
    "gqmv_int8": KernelHook(
        gqmv_xla=_ref.gqmv_ref, gqmm_xla=_ref.gqmm_ref,
        gqmv_pallas=_pallas.gqmv_pallas, gqmm_pallas=_pallas.gqmm_pallas,
    ),
    "gqmv_int4": KernelHook(
        gqmv_xla=_ref.gqmv_int4_ref, gqmm_xla=_ref.gqmm_int4_ref,
        gqmv_pallas=_pallas.gqmv_int4_pallas, gqmm_pallas=_pallas.gqmm_int4_pallas,
    ),
    "gqmv_int3": KernelHook(
        gqmv_xla=_ref.gqmv_int3_ref, gqmm_xla=_ref.gqmm_int3_ref,
        gqmv_pallas=_pallas.gqmv_int3_pallas, gqmm_pallas=_pallas.gqmm_int3_pallas,
    ),
    "gqmv_fp8": KernelHook(
        gqmv_xla=_ref.gqmv_fp8_ref, gqmm_xla=_ref.gqmm_fp8_ref,
        gqmv_pallas=_pallas.gqmv_fp8_pallas, gqmm_pallas=_pallas.gqmm_fp8_pallas,
    ),
}


def _hook(kernel: str) -> KernelHook:
    try:
        return KERNEL_HOOKS[kernel]
    except KeyError:
        raise ValueError(
            f"unknown kernel hook {kernel!r} (a QuantFormat named a hook with "
            f"no KERNEL_HOOKS row); registered: {sorted(KERNEL_HOOKS)}"
        ) from None


@partial(jax.jit, static_argnames=("group_size", "impl", "kernel"))
def gqmv(
    wq: jax.Array,
    ws: jax.Array,
    xq: jax.Array,
    xs: jax.Array,
    *,
    group_size: int,
    impl: str = "auto",
    kernel: str = "gqmv_int8",
) -> jax.Array:
    """out (m,) = groupwise-quantized W (m,n) @ x (n,). Paper Alg. 1/3.

    ``wq`` is the storage array of the format that owns ``kernel`` (plain
    int8 rows for the default hook, packed nibbles for ``gqmv_int4``)."""
    impl = _resolve(impl)
    hook = _hook(kernel)
    if impl == "xla":
        return hook.gqmv_xla(wq, ws, xq, xs, group_size=group_size)
    return hook.gqmv_pallas(
        wq, ws, xq, xs, group_size=group_size, interpret=(impl == "interpret")
    )


@partial(jax.jit, static_argnames=("group_size", "impl", "kernel"))
def gqmm(
    wq: jax.Array,
    ws: jax.Array,
    xq: jax.Array,
    xs: jax.Array,
    *,
    group_size: int,
    impl: str = "auto",
    kernel: str = "gqmv_int8",
) -> jax.Array:
    """out (b, m) = batched GQMV; b = tokens for prefill / batch for decode."""
    impl = _resolve(impl)
    hook = _hook(kernel)
    if impl == "xla":
        return hook.gqmm_xla(wq, ws, xq, xs, group_size=group_size)
    return hook.gqmm_pallas(
        wq, ws, xq, xs, group_size=group_size, interpret=(impl == "interpret")
    )


def paged_attention(
    q: jax.Array,            # (b, KV, G, hd)
    k_pages: jax.Array,      # (NB, BS, KV, hd) one layer's block pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (b, MB) int32
    pos: jax.Array,          # (b,) int32
    k_new: jax.Array,        # (b, KV, hd) current-token row (uncommitted)
    v_new: jax.Array,
    mask: jax.Array,         # (b, MB * BS) additive decode mask
    *,
    scale: float,
    softcap: float | None = None,
    k_scales: jax.Array | None = None,   # (NB, BS, KV) per-row dequant scales
    v_scales: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    """One paged decode-attention step -> ctx (b, KV*G*hd).

    Same backend dispatch as gqmv/gqmm: the XLA path gathers the virtual
    sequence through the block table (bit-exact vs the contiguous deferred
    decode on identity tables); the Pallas kernel streams only the live
    physical blocks HBM->VMEM via scalar-prefetch index maps. With
    ``k_scales``/``v_scales`` the pool holds quantized rows (int8/fp8) and
    dequantization is fused into the attention read — the streamed KV bytes
    stay at storage width."""
    impl = _resolve(impl)
    if impl == "xla":
        return _ref.paged_attention_ref(
            q, k_pages, v_pages, block_table, pos, k_new, v_new, mask,
            scale=scale, softcap=softcap, k_scales=k_scales, v_scales=v_scales,
        )
    return _paged.paged_attention_pallas(
        q, k_pages, v_pages, block_table, pos, k_new, v_new, mask,
        scale=scale, softcap=softcap, k_scales=k_scales, v_scales=v_scales,
        interpret=(impl == "interpret"),
    )


def paged_verify(
    q: jax.Array,            # (b, S, KV, G, hd) verify-chunk queries
    k_pages: jax.Array,      # (NB, BS, KV, hd)
    v_pages: jax.Array,
    block_table: jax.Array,  # (b, MB) int32
    pos: jax.Array,          # (b,) int32 chunk start positions
    k_new: jax.Array,        # (b, S, KV, hd) the chunk's own K/V rows
    v_new: jax.Array,
    mask: jax.Array,         # (b, S, MB * BS) additive verify mask
    *,
    scale: float,
    softcap: float | None = None,
    impl: str = "auto",
) -> jax.Array:
    """k-token speculative-verify attention over the block pool -> ctx
    (b, S, KV*G*hd). The multi-query sibling of :func:`paged_attention`.

    The verify shape (a handful of query rows against a long virtual
    sequence) is served by the XLA gather path on every backend for now:
    the m<=8 chunk makes attention a tiny fraction of the verify step —
    the step's cost is the weight stream, which the GQMM kernels already
    amortize over the chunk — so a dedicated Mosaic kernel is future work,
    not a bandwidth lever (DESIGN.md §10)."""
    del impl  # one implementation today; signature mirrors paged_attention
    return _ref.paged_verify_ref(
        q, k_pages, v_pages, block_table, pos, k_new, v_new, mask,
        scale=scale, softcap=softcap,
    )


def quantized_matmul(
    x: jax.Array, w: QuantizedTensor, *, impl: str = "auto"
) -> jax.Array:
    """y = x @ dequant(w).T with run-time int8 activation quantization.

    ``x`` is float (..., n); weights are a QuantizedTensor (m, n logical)
    in ANY registered format with groups along n. Returns float32 (..., m).
    This is the composable entry point the model layers use (paper Alg. 2:
    "RMSNorm and quantize x; kernel1(...)"); the format's kernel hook picks
    the matching GQMV/GQMM pair.
    """
    fmt = get_format(w.fmt)
    xq = quantize_activation(x, group_size=w.group_size)
    lead = x.shape[:-1]
    if lead == ():
        out = gqmv(w.qvalues, w.scales, xq.qvalues, xq.scales,
                   group_size=w.group_size, impl=impl, kernel=fmt.kernel)
        return out
    flat_q = xq.qvalues.reshape(-1, x.shape[-1])
    flat_s = xq.scales.reshape(-1, xq.scales.shape[-1])
    out = gqmm(w.qvalues, w.scales, flat_q, flat_s,
               group_size=w.group_size, impl=impl, kernel=fmt.kernel)
    return out.reshape(*lead, w.shape[0])
