"""Public jit'd entry points for quantized matmul kernels.

Dispatch policy (``impl``):
  'pallas'    pl.pallas_call, compiled for TPU (Mosaic)
  'interpret' same kernel body, Pallas interpreter on CPU (validation)
  'xla'       pure-XLA int8 dot_general path, bit-identical math; used by
              the distributed models and the dry-run, where the CPU backend
              cannot compile Mosaic kernels (see DESIGN.md §2)
  'auto'      pallas on TPU, xla elsewhere
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, quantize_activation
from repro.kernels import gqmv as _pallas
from repro.kernels import ref as _ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve(impl: str) -> str:
    return _default_impl() if impl == "auto" else impl


@partial(jax.jit, static_argnames=("group_size", "impl"))
def gqmv(
    wq: jax.Array,
    ws: jax.Array,
    xq: jax.Array,
    xs: jax.Array,
    *,
    group_size: int,
    impl: str = "auto",
) -> jax.Array:
    """out (m,) = groupwise-quantized W (m,n) @ x (n,). Paper Alg. 1/3."""
    impl = _resolve(impl)
    if impl == "xla":
        return _ref.gqmv_ref(wq, ws, xq, xs, group_size=group_size)
    return _pallas.gqmv_pallas(
        wq, ws, xq, xs, group_size=group_size, interpret=(impl == "interpret")
    )


@partial(jax.jit, static_argnames=("group_size", "impl"))
def gqmm(
    wq: jax.Array,
    ws: jax.Array,
    xq: jax.Array,
    xs: jax.Array,
    *,
    group_size: int,
    impl: str = "auto",
) -> jax.Array:
    """out (b, m) = batched GQMV; b = tokens for prefill / batch for decode."""
    impl = _resolve(impl)
    if impl == "xla":
        return _ref.gqmm_ref(wq, ws, xq, xs, group_size=group_size)
    return _pallas.gqmm_pallas(
        wq, ws, xq, xs, group_size=group_size, interpret=(impl == "interpret")
    )


def quantized_matmul(
    x: jax.Array, w: QuantizedTensor, *, impl: str = "auto"
) -> jax.Array:
    """y = x @ dequant(w).T with run-time activation quantization (W8A8).

    ``x`` is float (..., n); weights are a QuantizedTensor (m, n) with groups
    along n. Returns float32 (..., m). This is the composable entry point the
    model layers use (paper Alg. 2: "RMSNorm and quantize x; kernel1(...)").
    """
    xq = quantize_activation(x, group_size=w.group_size)
    lead = x.shape[:-1]
    if lead == ():
        out = gqmv(w.qvalues, w.scales, xq.qvalues, xq.scales,
                   group_size=w.group_size, impl=impl)
        return out
    flat_q = xq.qvalues.reshape(-1, x.shape[-1])
    flat_s = xq.scales.reshape(-1, xq.scales.shape[-1])
    out = gqmm(w.qvalues, w.scales, flat_q, flat_s,
               group_size=w.group_size, impl=impl)
    return out.reshape(*lead, w.shape[0])
