"""Pure-jnp oracles for the GQMV/GQMM kernels (paper Algorithm 1).

These are the ground truth the Pallas kernels are validated against. They
follow the paper's arithmetic exactly:

  for each output row i:
    for each group j (of GS columns):
      group_sum = sum_k  xq[j*GS+k] * wq[i, j*GS+k]        # int8*int8 -> int32
      sum      += group_sum * ws[i, j] * xs[j]             # fp32 scaling
    out[i] = sum

i.e. integer accumulation *within* a group, float scale-and-accumulate
*across* groups.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, unpack_int3, unpack_int4


@partial(jax.jit, static_argnames=("group_size",))
def gqmv_ref(
    wq: jax.Array,   # int8 (m, n)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (n,)
    xs: jax.Array,   # float32 (n // GS,)
    *,
    group_size: int,
) -> jax.Array:
    """out[m] = GQMV(W, x) per paper Alg. 1. Returns float32 (m,)."""
    m, n = wq.shape
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,gk->mg", wg, xg)              # int32 (m, ng)
    scaled = group_sums.astype(jnp.float32) * ws * xs[None, :]  # fp32 (m, ng)
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmm_ref(
    wq: jax.Array,   # int8 (m, n)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # float32 (b, n // GS)
    *,
    group_size: int,
) -> jax.Array:
    """Batched GQMV: out[b, m]. The paper runs batch=1; this is the natural
    batched generalization (same per-row math for every batch element)."""
    m, n = wq.shape
    b = xq.shape[0]
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(b, ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,bgk->bmg", wg, xg)             # int32
    scaled = group_sums.astype(jnp.float32) * ws[None] * xs[:, None, :]
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmv_int4_ref(
    wp: jax.Array,   # int8 packed (m, n // 2) — two nibbles per byte
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (n,) — activations stay int8 (W4A8)
    xs: jax.Array,   # float32 (n // GS,)
    *,
    group_size: int,
) -> jax.Array:
    """Packed-int4 GQMV oracle: unpack nibbles to int8, then Alg. 1 math.

    The group sums are exact integers either way; the fp32 stage uses the
    COMBINED scale ``group_sums * (ws * xs)`` — the same association the
    Pallas kernels use — so on single-n-block shapes the interpret-mode
    kernel reproduces this oracle bit-for-bit (multi-block accumulation
    reassociates the cross-group sum and matches to fp32 rounding).
    """
    wq = unpack_int4(wp)
    m, n = wq.shape
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,gk->mg", wg, xg)               # int32 (m, ng)
    scaled = group_sums.astype(jnp.float32) * (ws * xs[None, :])
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmm_int4_ref(
    wp: jax.Array,   # int8 packed (m, n // 2)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # float32 (b, n // GS)
    *,
    group_size: int,
) -> jax.Array:
    """Batched packed-int4 GQMV oracle (see gqmv_int4_ref)."""
    wq = unpack_int4(wp)
    m, n = wq.shape
    b = xq.shape[0]
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(b, ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,bgk->bmg", wg, xg)             # int32
    # same association as the Pallas kernel: (sums * xs) * ws
    scaled = (group_sums.astype(jnp.float32) * xs[:, None, :]) * ws[None]
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmv_int3_ref(
    wp: jax.Array,   # uint8 packed (m, n // 8 * 3) — eight 3-bit fields per 3 bytes
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (n,) — activations stay int8 (W3A8)
    xs: jax.Array,   # float32 (n // GS,)
    *,
    group_size: int,
) -> jax.Array:
    """Packed-int3 GQMV oracle: unpack the 3-bit fields to int8, then Alg. 1
    math with the same combined-scale association as the Pallas kernel (see
    gqmv_int4_ref for the bit-exactness argument)."""
    wq = unpack_int3(wp)
    m, n = wq.shape
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,gk->mg", wg, xg)               # int32 (m, ng)
    scaled = group_sums.astype(jnp.float32) * (ws * xs[None, :])
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmm_int3_ref(
    wp: jax.Array,   # uint8 packed (m, n // 8 * 3)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # float32 (b, n // GS)
    *,
    group_size: int,
) -> jax.Array:
    """Batched packed-int3 GQMV oracle (see gqmv_int3_ref)."""
    wq = unpack_int3(wp)
    m, n = wq.shape
    b = xq.shape[0]
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(b, ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,bgk->bmg", wg, xg)             # int32
    scaled = (group_sums.astype(jnp.float32) * xs[:, None, :]) * ws[None]
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmv_fp8_ref(
    wq: jax.Array,   # float8_e4m3fn (m, n)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (n,) — activations stay int8 (W8A8, float weights)
    xs: jax.Array,   # float32 (n // GS,)
    *,
    group_size: int,
) -> jax.Array:
    """fp8-weight GQMV oracle: the group dot runs in f32 (no exact integer
    stage), so kernel-vs-oracle comparisons are tolerance-based — f32 dot
    reassociation across lanes is allowed to differ."""
    m, n = wq.shape
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.float32)
    xg = xq.reshape(ng, group_size).astype(jnp.float32)
    group_sums = jnp.einsum("mgk,gk->mg", wg, xg)               # f32 (m, ng)
    scaled = group_sums * (ws * xs[None, :])
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmm_fp8_ref(
    wq: jax.Array,   # float8_e4m3fn (m, n)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # float32 (b, n // GS)
    *,
    group_size: int,
) -> jax.Array:
    """Batched fp8-weight GQMV oracle (see gqmv_fp8_ref)."""
    m, n = wq.shape
    b = xq.shape[0]
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.float32)
    xg = xq.reshape(b, ng, group_size).astype(jnp.float32)
    group_sums = jnp.einsum("mgk,bgk->bmg", wg, xg)             # f32
    scaled = (group_sums * xs[:, None, :]) * ws[None]
    return jnp.sum(scaled, axis=-1)


def paged_attention_ref(
    q: jax.Array,            # (b, KV, G, hd) decode-step queries, grouped
    k_pages: jax.Array,      # (NB, BS, KV, hd) one layer's block pool
    v_pages: jax.Array,      # (NB, BS, KV, hd)
    block_table: jax.Array,  # (b, MB) int32 physical block per virtual block
    pos: jax.Array,          # (b,) int32 current decode position per row
    k_new: jax.Array,        # (b, KV, hd) current token's K (not yet committed)
    v_new: jax.Array,        # (b, KV, hd)
    mask: jax.Array,         # (b, T) additive decode mask, T = MB * BS
    *,
    scale: float,
    softcap: float | None = None,
    k_scales: jax.Array | None = None,   # (NB, BS, KV) quantized-pool scales
    v_scales: jax.Array | None = None,
) -> jax.Array:
    """Block-table gather attention oracle for one decode step.

    Mirrors ``gqa_decode_deferred``'s arithmetic exactly — same einsums, same
    operation order — over a gathered virtual sequence: row i's keys live in
    pool blocks ``block_table[i]``, virtual position t maps to physical slot
    ``(block_table[i, t // BS], t % BS)``. The current token is handled
    explicitly (its score overwrites column ``pos``; its value is added after
    zeroing the attention weight at ``pos``), so STALE data in recycled or
    sink blocks is harmless: every unwritten column is either masked
    (``k > pos``) or overwritten. With an identity block table over a
    reshaped contiguous cache this is bit-exact against the contiguous
    deferred decode path (tests/test_paged.py).

    With ``k_scales``/``v_scales`` the pool rows are quantized (int8/fp8,
    one scale per (block row, kv head), group = head_dim) and the scales are
    factored OUTSIDE the dots — ``(q . k_q) * k_s`` and
    ``(attn * v_s) . v_q`` — the exact association of the contiguous
    quantized decode path (models/attention.py::gqa_decode_deferred_quant),
    so paged and contiguous quantized decode agree on identity tables.

    Returns ctx (b, KV * G * hd) in the contiguous path's head order.
    """
    b, kv, g, hd = q.shape
    nb, bs = k_pages.shape[:2]
    mb = block_table.shape[1]
    # gather (b, MB, BS, KV, hd) -> virtual (b, T, KV, hd)
    k = k_pages[block_table].reshape(b, mb * bs, kv, hd)
    v = v_pages[block_table].reshape(b, mb * bs, kv, hd)
    quant = k_scales is not None
    if quant:
        k = k.astype(q.dtype)
        ks = k_scales[block_table].reshape(b, mb * bs, kv)       # (b,T,KV)
        vs = v_scales[block_table].reshape(b, mb * bs, kv)
    scores = jnp.einsum("bkgh,btkh->bkgt", q, k).astype(jnp.float32)
    if quant:
        scores = scores * ks.transpose(0, 2, 1)[:, :, None, :]   # (b,KV,1,T)
    cur = jnp.einsum("bkgh,bkh->bkg", q, k_new).astype(jnp.float32)
    barng = jnp.arange(b)
    scores = scores.at[barng, :, :, pos].set(cur)
    scores = scores * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + mask[:, None, None, :]
    attn = jax.nn.softmax(scores, axis=-1)
    # zero the current column before the value gather: the pool slot at pos
    # holds stale data (it is committed AFTER attention); the real
    # contribution is the explicit k_new/v_new term
    attn_cur = attn[barng, :, :, pos][..., None].astype(q.dtype)  # (b,KV,G,1)
    attn_z = attn.at[barng, :, :, pos].set(0.0)
    if quant:
        attn_z = attn_z * vs.transpose(0, 2, 1)[:, :, None, :]
        v = v.astype(q.dtype)
    ctx = jnp.einsum("bkgt,btkh->bkgh", attn_z.astype(q.dtype), v)
    ctx = ctx + attn_cur * v_new[:, :, None, :]
    return ctx.reshape(b, kv * g * hd)


def paged_poison_counts(
    k_pages: jax.Array,      # (L, NB, BS, KV, hd) full block pool, all layers
    v_pages: jax.Array,      # (L, NB, BS, KV, hd)
    block_table: jax.Array,  # (b, MB) int32 physical block per virtual block
    pos: jax.Array,          # (b,) int32 current decode position per row
    poison: float,
) -> jax.Array:
    """repro-san's use-after-free detector: per (layer, slot, virtual block)
    counts of COMMITTED positions whose gathered K or V contains the poison
    fill value (analysis/shadow.py POISON, written over freed blocks).

    Mirrors :func:`paged_attention_ref`'s gather exactly — the same
    ``pages[block_table]`` indirection attention reads through — so a hit
    means poisoned (freed) data is REACHABLE by a live slot at a position
    the mask does not exclude: a freed block its table still maps. Only
    positions ``t < pos[slot]`` count; lookahead blocks (allocated ahead of
    the write frontier, possibly recycled-and-poisoned) and finished slots'
    sink-mapped rows sit at ``t >= pos`` or block 0 and stay clean.

    Returns int32 (L, b, MB). Runs under jit inside the sanitizer's single
    per-round check program (one host sync for all tripwires).
    """
    ell, nb, bs = k_pages.shape[:3]
    b, mb = block_table.shape
    t = jnp.arange(mb * bs, dtype=jnp.int32)
    committed = (t[None, :] < pos[:, None]).reshape(b, mb, bs)
    out = jnp.zeros((ell, b, mb), jnp.int32)
    for pages in (k_pages, v_pages):
        g = pages[:, block_table]                # (L, b, MB, BS, KV, hd)
        bad = (g == jnp.asarray(poison, g.dtype)).reshape(
            ell, b, mb, bs, -1).any(-1)
        out = out + jnp.sum(bad & committed[None], axis=-1).astype(jnp.int32)
    return out


def verify_attend(
    scores: jax.Array,       # (b, KV, G, S, T) chunk queries vs the sequence
    cur: jax.Array,          # (b, KV, G, S, M) intra-chunk q.k products
    chunk_v: jax.Array,      # (b, M, KV, hd) the chunk's own V rows
    v_source: jax.Array,     # (b, T, KV, hd) committed sequence values
    pos: jax.Array,          # (b,) int32 virtual position of chunk row 0
    mask: jax.Array,         # (b, S, T) additive verify mask
    *,
    scale: float,
    softcap: float | None = None,
) -> jax.Array:
    """The speculative-verify score arrangement, shared by the contiguous
    path (models/attention.py::gqa_verify_deferred) and the paged gather
    path (:func:`paged_verify_ref`) so the two cannot drift.

    The intra-chunk scores are SCATTERED into columns ``pos + m`` of the T
    axis — the exact layout m successive single-token decode steps would
    produce — so softmax sums in the same column order as vanilla decode
    and greedy speculative output stays token-identical. The chunk
    columns' attention weights are then pulled out, zeroed in place (the
    sequence source may hold zeros there — contiguous deferred cache — or
    stale recycled data — paged pool; either way unreachable), and their
    value contribution is added explicitly from ``chunk_v``.

    Returns ctx (b, S, KV * G * hd) in the contiguous path's head order.
    """
    b, kv, g, s, t = scores.shape
    m = cur.shape[-1]
    hd = chunk_v.shape[-1]
    rows = jnp.arange(b)[:, None]
    cols = pos[:, None] + jnp.arange(m, dtype=jnp.int32)[None, :]    # (b, m)
    # advanced-index layout: [rows, :, :, :, cols] -> (b, m, kv, g, s)
    scores = scores.at[rows, :, :, :, cols].set(cur.transpose(0, 4, 1, 2, 3))
    scores = scores * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + mask[:, None, None, :, :]
    attn = jax.nn.softmax(scores, axis=-1).astype(chunk_v.dtype)
    attn_chunk = attn[rows, :, :, :, cols].transpose(0, 2, 3, 4, 1)  # (b,kv,g,s,m)
    attn_z = attn.at[rows, :, :, :, cols].set(0.0)
    ctx = jnp.einsum("bkgst,btkh->bkgsh", attn_z, v_source)
    ctx = ctx + jnp.einsum("bkgsm,bmkh->bkgsh", attn_chunk, chunk_v)
    return ctx.transpose(0, 3, 1, 2, 4).reshape(b, s, kv * g * hd)


def paged_verify_ref(
    q: jax.Array,            # (b, S, KV, G, hd) verify-chunk queries, grouped
    k_pages: jax.Array,      # (NB, BS, KV, hd) one layer's block pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (b, MB) int32
    pos: jax.Array,          # (b,) int32 virtual position of chunk row 0
    k_new: jax.Array,        # (b, S, KV, hd) the chunk's own K rows
    v_new: jax.Array,
    mask: jax.Array,         # (b, S, T) additive verify mask, T = MB * BS
    *,
    scale: float,
    softcap: float | None = None,
) -> jax.Array:
    """Block-table gather attention for a k-token speculative-verify chunk:
    gather row i's keys/values through its block table into a virtual
    (b, T, KV, hd) sequence, then run the shared :func:`verify_attend`
    arrangement — identical math to the contiguous verify path on identity
    tables (tests/test_spec.py).

    Returns ctx (b, S, KV * G * hd) in the contiguous path's head order.
    """
    b, s, kv, g, hd = q.shape
    nb, bs = k_pages.shape[:2]
    mb = block_table.shape[1]
    k = k_pages[block_table].reshape(b, mb * bs, kv, hd)
    v = v_pages[block_table].reshape(b, mb * bs, kv, hd)
    qg = q.transpose(0, 2, 3, 1, 4)                              # (b,kv,g,s,hd)
    scores = jnp.einsum("bkgsh,btkh->bkgst", qg, k).astype(jnp.float32)
    cur = jnp.einsum("bkgsh,bmkh->bkgsm", qg, k_new).astype(jnp.float32)
    return verify_attend(scores, cur, v_new, v, pos, mask,
                         scale=scale, softcap=softcap)


def gqmv_from_qt(w: QuantizedTensor, x: QuantizedTensor) -> jax.Array:
    assert w.group_size == x.group_size
    return gqmv_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=w.group_size)


def gqmm_from_qt(w: QuantizedTensor, x: QuantizedTensor) -> jax.Array:
    assert w.group_size == x.group_size
    return gqmm_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=w.group_size)
