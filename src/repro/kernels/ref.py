"""Pure-jnp oracles for the GQMV/GQMM kernels (paper Algorithm 1).

These are the ground truth the Pallas kernels are validated against. They
follow the paper's arithmetic exactly:

  for each output row i:
    for each group j (of GS columns):
      group_sum = sum_k  xq[j*GS+k] * wq[i, j*GS+k]        # int8*int8 -> int32
      sum      += group_sum * ws[i, j] * xs[j]             # fp32 scaling
    out[i] = sum

i.e. integer accumulation *within* a group, float scale-and-accumulate
*across* groups.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, unpack_int4


@partial(jax.jit, static_argnames=("group_size",))
def gqmv_ref(
    wq: jax.Array,   # int8 (m, n)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (n,)
    xs: jax.Array,   # float32 (n // GS,)
    *,
    group_size: int,
) -> jax.Array:
    """out[m] = GQMV(W, x) per paper Alg. 1. Returns float32 (m,)."""
    m, n = wq.shape
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,gk->mg", wg, xg)              # int32 (m, ng)
    scaled = group_sums.astype(jnp.float32) * ws * xs[None, :]  # fp32 (m, ng)
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmm_ref(
    wq: jax.Array,   # int8 (m, n)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # float32 (b, n // GS)
    *,
    group_size: int,
) -> jax.Array:
    """Batched GQMV: out[b, m]. The paper runs batch=1; this is the natural
    batched generalization (same per-row math for every batch element)."""
    m, n = wq.shape
    b = xq.shape[0]
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(b, ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,bgk->bmg", wg, xg)             # int32
    scaled = group_sums.astype(jnp.float32) * ws[None] * xs[:, None, :]
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmv_int4_ref(
    wp: jax.Array,   # int8 packed (m, n // 2) — two nibbles per byte
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (n,) — activations stay int8 (W4A8)
    xs: jax.Array,   # float32 (n // GS,)
    *,
    group_size: int,
) -> jax.Array:
    """Packed-int4 GQMV oracle: unpack nibbles to int8, then Alg. 1 math.

    The group sums are exact integers either way; the fp32 stage uses the
    COMBINED scale ``group_sums * (ws * xs)`` — the same association the
    Pallas kernels use — so on single-n-block shapes the interpret-mode
    kernel reproduces this oracle bit-for-bit (multi-block accumulation
    reassociates the cross-group sum and matches to fp32 rounding).
    """
    wq = unpack_int4(wp)
    m, n = wq.shape
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,gk->mg", wg, xg)               # int32 (m, ng)
    scaled = group_sums.astype(jnp.float32) * (ws * xs[None, :])
    return jnp.sum(scaled, axis=-1)


@partial(jax.jit, static_argnames=("group_size",))
def gqmm_int4_ref(
    wp: jax.Array,   # int8 packed (m, n // 2)
    ws: jax.Array,   # float32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # float32 (b, n // GS)
    *,
    group_size: int,
) -> jax.Array:
    """Batched packed-int4 GQMV oracle (see gqmv_int4_ref)."""
    wq = unpack_int4(wp)
    m, n = wq.shape
    b = xq.shape[0]
    ng = n // group_size
    wg = wq.reshape(m, ng, group_size).astype(jnp.int32)
    xg = xq.reshape(b, ng, group_size).astype(jnp.int32)
    group_sums = jnp.einsum("mgk,bgk->bmg", wg, xg)             # int32
    # same association as the Pallas kernel: (sums * xs) * ws
    scaled = (group_sums.astype(jnp.float32) * xs[:, None, :]) * ws[None]
    return jnp.sum(scaled, axis=-1)


def paged_attention_ref(
    q: jax.Array,            # (b, KV, G, hd) decode-step queries, grouped
    k_pages: jax.Array,      # (NB, BS, KV, hd) one layer's block pool
    v_pages: jax.Array,      # (NB, BS, KV, hd)
    block_table: jax.Array,  # (b, MB) int32 physical block per virtual block
    pos: jax.Array,          # (b,) int32 current decode position per row
    k_new: jax.Array,        # (b, KV, hd) current token's K (not yet committed)
    v_new: jax.Array,        # (b, KV, hd)
    mask: jax.Array,         # (b, T) additive decode mask, T = MB * BS
    *,
    scale: float,
    softcap: float | None = None,
) -> jax.Array:
    """Block-table gather attention oracle for one decode step.

    Mirrors ``gqa_decode_deferred``'s arithmetic exactly — same einsums, same
    operation order — over a gathered virtual sequence: row i's keys live in
    pool blocks ``block_table[i]``, virtual position t maps to physical slot
    ``(block_table[i, t // BS], t % BS)``. The current token is handled
    explicitly (its score overwrites column ``pos``; its value is added after
    zeroing the attention weight at ``pos``), so STALE data in recycled or
    sink blocks is harmless: every unwritten column is either masked
    (``k > pos``) or overwritten. With an identity block table over a
    reshaped contiguous cache this is bit-exact against the contiguous
    deferred decode path (tests/test_paged.py).

    Returns ctx (b, KV * G * hd) in the contiguous path's head order.
    """
    b, kv, g, hd = q.shape
    nb, bs = k_pages.shape[:2]
    mb = block_table.shape[1]
    # gather (b, MB, BS, KV, hd) -> virtual (b, T, KV, hd)
    k = k_pages[block_table].reshape(b, mb * bs, kv, hd)
    v = v_pages[block_table].reshape(b, mb * bs, kv, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", q, k).astype(jnp.float32)
    cur = jnp.einsum("bkgh,bkh->bkg", q, k_new).astype(jnp.float32)
    barng = jnp.arange(b)
    scores = scores.at[barng, :, :, pos].set(cur)
    scores = scores * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + mask[:, None, None, :]
    attn = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    # zero the current column before the value gather: the pool slot at pos
    # holds stale data (it is committed AFTER attention); the real
    # contribution is the explicit k_new/v_new term
    attn_cur = attn[barng, :, :, pos][..., None]                 # (b,KV,G,1)
    attn_z = attn.at[barng, :, :, pos].set(0.0)
    ctx = jnp.einsum("bkgt,btkh->bkgh", attn_z, v)
    ctx = ctx + attn_cur * v_new[:, :, None, :]
    return ctx.reshape(b, kv * g * hd)


def gqmv_from_qt(w: QuantizedTensor, x: QuantizedTensor) -> jax.Array:
    assert w.group_size == x.group_size
    return gqmv_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=w.group_size)


def gqmm_from_qt(w: QuantizedTensor, x: QuantizedTensor) -> jax.Array:
    assert w.group_size == x.group_size
    return gqmm_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=w.group_size)
