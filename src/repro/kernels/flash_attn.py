"""Pallas TPU flash attention (chunked online-softmax).

Beyond-paper optimization: the paper keeps attention on the host CPU (its
batch-1 profile makes attention negligible, Table II). At the assigned
train_4k/prefill_32k shapes attention dominates the memory roofline term
instead, so we adapt the paper's own streaming idea — keep the working set
in fast memory, stream the big operand — to attention itself: K/V stream
HBM->VMEM chunk by chunk (grid pipelining), scores/softmax state never
leave VMEM.

HBM traffic becomes O(q + k + v + o) instead of O(b*h*s*t) materialized
scores — the same argument as FlashAttention, expressed with the paper's
vocabulary.

Supports GQA (kv-head broadcast via BlockSpec index arithmetic), causal and
sliding-window masks, gemma2 logit softcap. Validated in interpret mode
against ref.py's naive oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                   # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                # (bq, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (bq, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)             # fully-masked rows -> 0
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,    # (bh, s, hd)  -- batch*heads flattened
    k: jax.Array,    # (bkv, t, hd) -- batch*kv_heads flattened
    v: jax.Array,
    *,
    group: int,              # q heads per kv head (GQA broadcast)
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    bh, s, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, t)
    while t % bk:
        bk //= 2
    nk = t // bk
    grid = (bh, s // bq, nk)

    def kv_index(i, iq, ik):
        # head i -> kv head: (batch, head) flattening is row-major, so
        # kv row = (i // heads_per_batch) * kv_per_batch + (i % heads) // group
        return (i // group, ik, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, iq, ik: (i, iq, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, iq, ik: (i, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
