"""Pallas TPU kernels for group-wise quantized matrix-vector/matrix multiply.

TPU adaptation of the paper's 3-stage pipelined FPGA accelerator (§IV):

  FPGA stage            TPU analogue (this file)
  -------------------   ----------------------------------------------------
  pre-processing:       Pallas grid pipelining: each (bm, bn) int8 weight
  DDR->BRAM streaming   block is DMA'd HBM->VMEM double-buffered while the
  of wq/ws blocks       previous block computes  (paper C3, Fig. 2)
  dot-product: SIMD     jax.lax.dot_general int8 x int8 with
  mul + depth-8 adder   preferred_element_type=int32, batched over groups
  tree per group        (the MXU/VPU reduction replaces the adder tree)
  accumulate: fp32      group_sums * (ws * xs) in fp32, accumulated across
  scale + writeback     n-blocks into the VMEM output block

Progressive INT8->INT16->INT32 widening from the paper is collapsed to
int8 MACs with native int32 accumulation (FPGA DSP packing artifact; see
DESIGN.md §2). Group size GS=256 = 2x128 TPU lanes, so group reductions
are lane-aligned.

Kernels are written for TPU (BlockSpec/VMEM) and validated on CPU with
``interpret=True`` against ``ref.py``.

Four weight formats share the compute stages (see core/quant.py registry):

  int8  wq streamed as int8 blocks (the paper's layout)
  int4  wq streamed PACKED (two nibbles per byte, half the HBM traffic of
        int8 — the paper's §II-B bandwidth lever pushed below one byte) and
        sign-extended to int8 nibble values in VMEM just before the group
        dot. Only the DMA'd bytes shrink; the dot-product and accumulate
        stages are byte-for-byte the int8 ones.
  int3  wq streamed as true 3-bit packing (8 values per 3 uint8 bytes,
        0.375 B/weight) and sign-extended in VMEM — the sub-int4 point of
        the same streaming argument.
  fp8   wq streamed as float8_e4m3fn bytes; the group dot runs in f32
        (same VMEM blocks, float datapath instead of the int8 MACs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import unpack_int3, unpack_int4

DEFAULT_BM = 256   # output rows per block
DEFAULT_BN = 1024  # contraction columns per block (multiple of GS)
DEFAULT_BB = 128   # batch rows per block (GQMM)

_INT8_GROUP_DOT = (((2,), (1,)), ((0,), (0,)))  # (g,bm,GS) x (g,GS) -> (g,bm)


def _pick_block(dim: int, preferred: int, multiple_of: int = 1) -> int:
    """Largest block <= preferred that divides dim and is a multiple of
    ``multiple_of`` (the quantization group size for the n axis)."""
    cand = min(preferred, dim)
    cand -= cand % multiple_of
    while cand >= multiple_of:
        if dim % cand == 0 and cand % multiple_of == 0:
            return cand
        cand -= multiple_of
    if multiple_of == 1:
        return 1
    raise ValueError(f"no block for dim={dim} multiple_of={multiple_of}")


def _check_divides(dim: int, blk: int, axis: str, multiple_of: int = 1) -> int:
    """Validate a (possibly caller-supplied) block size: the grid is built
    as ``dim // blk``, so a non-dividing block would silently drop the tail
    rows; the n axis must additionally stay a whole number of quantization
    groups / storage elements."""
    if dim % blk or blk % multiple_of:
        raise ValueError(
            f"block {blk} invalid for {axis}={dim} "
            f"(multiple_of={multiple_of}): the grid would drop the tail")
    return blk


# ---------------------------------------------------------------------------
# GQMV: out (1, m)  =  W(q) (m, n)  @  x(q) (1, n)     -- paper's batch-1 core
# ---------------------------------------------------------------------------

def _gqmv_compute(wq, xq_ref, xs_ref, ws_ref, out_ref, *, group_size: int):
    """Dot-product + accumulate stages shared by every weight format; ``wq``
    is the already-unpacked (bm, bn) weight block in VMEM — int8 values for
    the integer formats, float8 for fp8 (the dot then runs in f32)."""
    j = pl.program_id(1)           # n-block index (innermost grid dim)
    bm, bn = wq.shape
    ng = bn // group_size
    integer = jnp.issubdtype(wq.dtype, jnp.integer)

    # --- dot-product stage: int8 x int8 -> int32 group sums (fp8: f32) -----
    wg = wq.reshape(bm, ng, group_size).transpose(1, 0, 2)            # (g,bm,GS)
    xg = xq_ref[0].reshape(ng, group_size)                            # (g,GS)
    if not integer:
        wg, xg = wg.astype(jnp.float32), xg.astype(jnp.float32)
    group_sums = jax.lax.dot_general(
        wg, xg, _INT8_GROUP_DOT,
        preferred_element_type=jnp.int32 if integer else jnp.float32,
    )                                                                 # (g,bm)

    # --- accumulate stage: fp32 scale and cross-group reduction ------------
    scale = ws_ref[...] * xs_ref[0][None, :]                          # (bm,g)
    partial = jnp.sum(group_sums.astype(jnp.float32).T * scale, axis=-1)

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] += partial


def _gqmv_kernel(xq_ref, xs_ref, wq_ref, ws_ref, out_ref, *, group_size: int):
    _gqmv_compute(wq_ref[...], xq_ref, xs_ref, ws_ref, out_ref,
                  group_size=group_size)


def _gqmv_int4_kernel(xq_ref, xs_ref, wp_ref, ws_ref, out_ref, *, group_size: int):
    # pre-processing stage streamed half the bytes; sign-extend in VMEM
    _gqmv_compute(unpack_int4(wp_ref[...]), xq_ref, xs_ref, ws_ref, out_ref,
                  group_size=group_size)


def _gqmv_int3_kernel(xq_ref, xs_ref, wp_ref, ws_ref, out_ref, *, group_size: int):
    # 3 streamed bytes carry 8 weights; sign-extend the 3-bit fields in VMEM
    _gqmv_compute(unpack_int3(wp_ref[...]), xq_ref, xs_ref, ws_ref, out_ref,
                  group_size=group_size)


def _gqmv_call(kernel, wq, ws, xq, xs, *, group_size, pack,
               block_m, block_n, interpret, pack_storage=1):
    """Shared pallas_call plumbing; pack geometry is ``pack`` logical
    elements per ``pack_storage`` storage elements (wq's trailing axis holds
    n // pack * pack_storage storage elements)."""
    m = wq.shape[0]
    n = xq.shape[-1]
    gmult = max(group_size, pack)
    bm = _check_divides(m, block_m or _pick_block(m, DEFAULT_BM), "m")
    bn = _check_divides(
        n, block_n or _pick_block(n, DEFAULT_BN, multiple_of=gmult), "n",
        multiple_of=gmult)
    ng = bn // group_size
    bw = bn // pack * pack_storage
    grid = (m // bm, n // bn)

    return pl.pallas_call(
        functools.partial(kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),            # xq
            pl.BlockSpec((1, ng), lambda i, j: (0, j)),            # xs
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),           # wq (streamed)
            pl.BlockSpec((bm, ng), lambda i, j: (i, j)),           # ws (streamed)
        ],
        out_specs=pl.BlockSpec((1, bm), lambda i, j: (0, i)),      # out row block
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        interpret=interpret,
    )(xq[None, :], xs[None, :], wq, ws)[0]


def gqmv_pallas(
    wq: jax.Array,   # int8 (m, n)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (n,)
    xs: jax.Array,   # f32 (n // GS,)
    *,
    group_size: int,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmv_call(_gqmv_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=1, block_m=block_m, block_n=block_n,
                      interpret=interpret)


def gqmv_int4_pallas(
    wq: jax.Array,   # int8 PACKED (m, n // 2)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (n,)
    xs: jax.Array,   # f32 (n // GS,)
    *,
    group_size: int,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmv_call(_gqmv_int4_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=2, block_m=block_m, block_n=block_n,
                      interpret=interpret)


def gqmv_int3_pallas(
    wq: jax.Array,   # uint8 PACKED (m, n // 8 * 3)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (n,)
    xs: jax.Array,   # f32 (n // GS,)
    *,
    group_size: int,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmv_call(_gqmv_int3_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=8, pack_storage=3, block_m=block_m, block_n=block_n,
                      interpret=interpret)


def gqmv_fp8_pallas(
    wq: jax.Array,   # float8_e4m3fn (m, n)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (n,)
    xs: jax.Array,   # f32 (n // GS,)
    *,
    group_size: int,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    # fp8 storage needs no unpack stage; the shared compute switches to the
    # f32 datapath off the weight dtype.
    return _gqmv_call(_gqmv_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=1, block_m=block_m, block_n=block_n,
                      interpret=interpret)


# ---------------------------------------------------------------------------
# GQMM: out (b, m) = X(q) (b, n) @ W(q)^T -- batched prefill / batched decode
# ---------------------------------------------------------------------------

def _gqmm_compute(wq, xq_ref, xs_ref, ws_ref, out_ref, *, group_size: int):
    j = pl.program_id(2)           # n-block index (innermost)
    bm, bn = wq.shape
    bb = xq_ref.shape[0]
    ng = bn // group_size

    integer = jnp.issubdtype(wq.dtype, jnp.integer)
    wg = wq.reshape(bm, ng, group_size).transpose(1, 0, 2)            # (g,bm,GS)
    xg = xq_ref[...].reshape(bb, ng, group_size).transpose(1, 0, 2)   # (g,bb,GS)
    if not integer:
        wg, xg = wg.astype(jnp.float32), xg.astype(jnp.float32)
    # (g,bb,GS) x (g,bm,GS) -> (g,bb,bm) int32 group sums (fp8: f32)
    group_sums = jax.lax.dot_general(
        xg, wg, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32 if integer else jnp.float32,
    )
    scaled = (
        group_sums.astype(jnp.float32)
        * xs_ref[...].T[:, :, None]          # (g,bb,1)
        * ws_ref[...].T[:, None, :]          # (g,1,bm)
    )
    partial = jnp.sum(scaled, axis=0)        # (bb, bm)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += partial


def _gqmm_kernel(xq_ref, xs_ref, wq_ref, ws_ref, out_ref, *, group_size: int):
    _gqmm_compute(wq_ref[...], xq_ref, xs_ref, ws_ref, out_ref,
                  group_size=group_size)


def _gqmm_int4_kernel(xq_ref, xs_ref, wp_ref, ws_ref, out_ref, *, group_size: int):
    _gqmm_compute(unpack_int4(wp_ref[...]), xq_ref, xs_ref, ws_ref, out_ref,
                  group_size=group_size)


def _gqmm_int3_kernel(xq_ref, xs_ref, wp_ref, ws_ref, out_ref, *, group_size: int):
    _gqmm_compute(unpack_int3(wp_ref[...]), xq_ref, xs_ref, ws_ref, out_ref,
                  group_size=group_size)


def _gqmm_call(kernel, wq, ws, xq, xs, *, group_size, pack,
               block_b, block_m, block_n, interpret, pack_storage=1):
    m = wq.shape[0]
    b, n = xq.shape
    gmult = max(group_size, pack)
    bb = _check_divides(b, block_b or _pick_block(b, DEFAULT_BB), "b")
    bm = _check_divides(m, block_m or _pick_block(m, DEFAULT_BM), "m")
    bn = _check_divides(
        n, block_n or _pick_block(n, DEFAULT_BN, multiple_of=gmult), "n",
        multiple_of=gmult)
    ng = bn // group_size
    bw = bn // pack * pack_storage
    grid = (b // bb, m // bm, n // bn)

    return pl.pallas_call(
        functools.partial(kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bn), lambda ib, im, j: (ib, j)),          # xq
            pl.BlockSpec((bb, ng), lambda ib, im, j: (ib, j)),          # xs
            pl.BlockSpec((bm, bw), lambda ib, im, j: (im, j)),          # wq
            pl.BlockSpec((bm, ng), lambda ib, im, j: (im, j)),          # ws
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda ib, im, j: (ib, im)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(xq, xs, wq, ws)


def gqmm_pallas(
    wq: jax.Array,   # int8 (m, n)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # f32 (b, n // GS)
    *,
    group_size: int,
    block_b: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmm_call(_gqmm_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=1, block_b=block_b, block_m=block_m,
                      block_n=block_n, interpret=interpret)


def gqmm_int4_pallas(
    wq: jax.Array,   # int8 PACKED (m, n // 2)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # f32 (b, n // GS)
    *,
    group_size: int,
    block_b: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmm_call(_gqmm_int4_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=2, block_b=block_b, block_m=block_m,
                      block_n=block_n, interpret=interpret)


def gqmm_int3_pallas(
    wq: jax.Array,   # uint8 PACKED (m, n // 8 * 3)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # f32 (b, n // GS)
    *,
    group_size: int,
    block_b: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmm_call(_gqmm_int3_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=8, pack_storage=3, block_b=block_b, block_m=block_m,
                      block_n=block_n, interpret=interpret)


def gqmm_fp8_pallas(
    wq: jax.Array,   # float8_e4m3fn (m, n)
    ws: jax.Array,   # f32 (m, n // GS)
    xq: jax.Array,   # int8 (b, n)
    xs: jax.Array,   # f32 (b, n // GS)
    *,
    group_size: int,
    block_b: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _gqmm_call(_gqmm_kernel, wq, ws, xq, xs, group_size=group_size,
                      pack=1, block_b=block_b, block_m=block_m,
                      block_n=block_n, interpret=interpret)
