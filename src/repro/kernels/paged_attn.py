"""Pallas TPU paged-attention decode kernel (block-table gather).

The paged KV pool keeps one layer's cache as (NB, BS, KV, hd) fixed-size
blocks; each decode row owns a BLOCK TABLE of physical block ids. The XLA
oracle (kernels/ref.py::paged_attention_ref) materializes the gathered
(b, T, KV, hd) virtual sequence; this kernel never does — the block table
rides in as a scalar-prefetch argument and the BlockSpec index maps DMA each
row's *physical* K/V blocks HBM->VMEM directly, so HBM traffic is the live
blocks only (the same streaming argument as kernels/flash_attn.py, applied
to the paged layout).

Grid: (b, KV, MB) — one program per (row, kv head, virtual block), online
softmax state in VMEM scratch across the MB dimension. The current token's
K/V (not yet committed to the pool) is handled in-kernel: its score
overwrites the virtual column at ``pos`` and its value row replaces the
stale pool row, so recycled/sink blocks never leak. Validated in interpret
mode against the oracle (tests/test_paged.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attend(j, q, k, v, k_new, v_new, mask_ref, pos_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float | None, bs: int, nb: int):
    """Online-softmax accumulate over one (g, bs) score tile; ``k``/``v`` are
    the already-dequantized f32 block rows in VMEM (shared by the float and
    quantized-pool kernels)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, bs)

    # current token: its pool slot is committed AFTER attention, so the row
    # at ``pos`` holds stale data — substitute the fresh K score / V row
    col = pos_ref[pl.program_id(0)] - j * bs
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    at_cur = iota == col                               # (1, bs); off-block: none
    cur = (q * k_new[None, :]).sum(axis=-1)            # (g,)
    s = jnp.where(at_cur, cur[:, None], s)
    v = jnp.where(at_cur.reshape(bs, 1), v_new[None, :], v)

    s = s * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s + mask_ref[0, 0].astype(jnp.float32)[None, :]   # (1->g, bs) additive

    m_prev, l_prev = m_scr[...], l_scr[...]            # (g, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (g, bs)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                  mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, softcap: float | None, bs: int, nb: int):
    j = pl.program_id(2)                               # virtual block index

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)             # (bs, hd)
    k_new = kn_ref[0, 0].astype(jnp.float32)           # (hd,)
    v_new = vn_ref[0, 0].astype(jnp.float32)           # (hd,)
    _attend(j, q, k, v, k_new, v_new, mask_ref, pos_ref, o_ref,
            m_scr, l_scr, acc_scr, scale=scale, softcap=softcap, bs=bs, nb=nb)


def _paged_quant_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                        kn_ref, vn_ref, mask_ref, o_ref, m_scr, l_scr,
                        acc_scr, *,
                        scale: float, softcap: float | None, bs: int, nb: int):
    """Quantized-pool variant: the DMA'd K/V blocks are int8/fp8 storage rows
    plus per-row f32 scales; dequantization happens here in VMEM, so the
    HBM stream stays at storage width (the cache-side twin of the GQMV
    unpack-in-VMEM argument)."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    k_new = kn_ref[0, 0].astype(jnp.float32)           # (hd,)
    v_new = vn_ref[0, 0].astype(jnp.float32)           # (hd,)
    _attend(j, q, k, v, k_new, v_new, mask_ref, pos_ref, o_ref,
            m_scr, l_scr, acc_scr, scale=scale, softcap=softcap, bs=bs, nb=nb)


def paged_attention_pallas(
    q: jax.Array,            # (b, KV, G, hd)
    k_pages: jax.Array,      # (NB, BS, KV, hd)
    v_pages: jax.Array,
    block_table: jax.Array,  # (b, MB) int32
    pos: jax.Array,          # (b,) int32
    k_new: jax.Array,        # (b, KV, hd)
    v_new: jax.Array,
    mask: jax.Array,         # (b, MB * BS) additive float32
    *,
    scale: float,
    softcap: float | None = None,
    k_scales: jax.Array | None = None,   # (NB, BS, KV) quantized-pool scales
    v_scales: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, kv, g, hd = q.shape
    bs = k_pages.shape[1]
    mb = block_table.shape[1]
    mask = mask.reshape(b, mb, bs)
    quant = k_scales is not None

    def kv_index(ib, ik, j, bt, pos_s):
        # scalar-prefetched block table picks the physical block to DMA
        # (index maps receive grid indices first, then the scalar refs)
        return (bt[ib, j], 0, ik, 0)

    def scale_index(ib, ik, j, bt, pos_s):
        return (bt[ib, j], 0, ik)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda ib, ik, j, bt, ps: (ib, ik, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), kv_index),
        pl.BlockSpec((1, bs, 1, hd), kv_index),
    ]
    if quant:
        # per-row f32 scales ride the same block-table DMA as their rows
        in_specs += [
            pl.BlockSpec((1, bs, 1), scale_index),
            pl.BlockSpec((1, bs, 1), scale_index),
        ]
    in_specs += [
        pl.BlockSpec((1, 1, hd), lambda ib, ik, j, bt, ps: (ib, ik, 0)),
        pl.BlockSpec((1, 1, hd), lambda ib, ik, j, bt, ps: (ib, ik, 0)),
        pl.BlockSpec((1, 1, bs), lambda ib, ik, j, bt, ps: (ib, j, 0)),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_table, pos
        grid=(b, kv, mb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda ib, ik, j, bt, ps: (ib, ik, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max
            pltpu.VMEM((g, 1), jnp.float32),     # running denominator
            pltpu.VMEM((g, hd), jnp.float32),    # output accumulator
        ],
    )
    kernel = functools.partial(
        _paged_quant_kernel if quant else _paged_kernel,
        scale=scale, softcap=softcap, bs=bs, nb=mb)
    operands = [q, k_pages, v_pages]
    if quant:
        operands += [k_scales, v_scales]
    operands += [k_new, v_new, mask]
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), pos.astype(jnp.int32), *operands)
    return out.reshape(b, kv * g * hd)
