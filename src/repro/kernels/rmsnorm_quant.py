"""Fused RMSNorm + group-wise int8 activation quantization (Pallas TPU).

Paper Alg. 2 lines 3/11/16: every GQMV is preceded by "RMSNorm and quantize
x". Unfused, that chain costs 4 HBM round-trips of the activation (read x,
write normed, read normed, write q+scales); fused in VMEM it is one read +
one (int8!) write — the decode-path traffic item measured as
``copy_abs_fusion`` in EXPERIMENTS.md §Perf C.

Layout: x (m, n) with quantization groups along n (GS divides n). One grid
step processes a (bm, n) row block entirely in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, q_ref, s_ref, *, group_size: int, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (bm, n)
    bm, n = x.shape
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    normed = x * inv * w_ref[...].astype(jnp.float32)[None, :]
    g = normed.reshape(bm, n // group_size, group_size)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scales = absmax * (2.0 / 255.0)
    safe = jnp.where(scales > 0, scales, 1.0)
    q = jnp.clip(jnp.round(g / safe[..., None]), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(bm, n)
    s_ref[...] = scales


def rmsnorm_quant_pallas(
    x: jax.Array,     # (m, n)
    w: jax.Array,     # (n,)
    *,
    group_size: int,
    eps: float = 1e-5,
    block_m: int = 256,
    interpret: bool = False,
):
    """-> (qvalues int8 (m, n), scales f32 (m, n/GS))."""
    m, n = x.shape
    bm = min(block_m, m)
    while m % bm:
        bm //= 2
    ng = n // group_size
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_kernel, group_size=group_size, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, ng), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, ng), jnp.float32),
        ],
        interpret=interpret,
    )(x, w)


def rmsnorm_quant_ref(x, w, *, group_size: int, eps: float = 1e-5):
    """Pure-jnp oracle: models/common.rmsnorm + core/quant.quantize_groupwise."""
    from repro.core.quant import quantize_groupwise
    from repro.models.common import rmsnorm

    normed = rmsnorm(x.astype(jnp.float32), w, eps)
    qt = quantize_groupwise(normed, group_size)
    return qt.qvalues, qt.scales
