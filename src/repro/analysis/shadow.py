"""repro-san shadow state: host-side mirrors of the cache adapters' memory.

The serving stack's failure mode is SILENT: ``BlockPool`` recycles KV blocks
without zeroing (serving/paged.py), so a use-after-free or leaked block
feeds stale-but-plausible KV into attention and corrupts generations
without crashing. This module holds the host-side half of the sanitizer
(analysis/sanitizer.py drives it and owns the device programs):

- :class:`ShadowBlockTracker` mirrors one ``BlockPool``: per-block owner
  slot + a generation counter bumped on every free. Double-reserve and
  unowned-free raise immediately; frees enqueue the block for poison-fill;
  per-request and end-of-serve audits catch leaks (blocks still owned after
  ``on_finish`` should have returned them).
- :class:`SlotShadow` mirrors per-slot liveness for every adapter kind:
  double-admit, writes to frozen/finished slots (position drift), pad rows
  entering a recurrent prefill, snapshots of non-live slots.
- :data:`POISON` is the freed-block fill value. Poisoned data that is
  REACHABLE (a live slot's table still maps a freed block at a committed
  position) is detected by the paged gather oracle mirror
  (``kernels/ref.paged_poison_counts``).

Layering: this module is host-only (numpy) and must not import the serving
package — serving/core.py imports the sanitizer, not the other way around.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "OVERFLOW_LIMIT",
    "POISON",
    "SanitizerError",
    "ShadowBlockTracker",
    "SlotShadow",
]

# Poison pattern written over freed KV blocks. Deliberately FINITE:
# 0xDEADBEEF reinterpreted as float32 (~ -6.26e18) survives the cast to the
# cache dtype, sits below the overflow tripwire, and — critically — keeps a
# sanitized run bit-identical to the unsanitized one: every legitimately
# unreachable poisoned column is masked, its softmax weight underflows to
# exactly 0.0, and 0.0 * poison contributes the same -0.0 a stale recycled
# value would. NaN poison would infect the masked softmax (0 * NaN = NaN)
# and break the parity sweep.
POISON = float(np.frombuffer(np.uint32(0xDEADBEEF).tobytes(),
                             dtype=np.float32)[0])

# |x| above this at a checked boundary counts as overflow; the poison value
# itself stays well below it so freed-block fills never trip the numerics
# check.
OVERFLOW_LIMIT = 1e30


class SanitizerError(AssertionError):
    """A repro-san invariant violation, with block/slot/layer attribution."""


class ShadowBlockTracker:
    """Mirror of one ``BlockPool``: per-block owner slot + generation.

    Attached as ``pool.shadow``; the pool calls :meth:`on_alloc` /
    :meth:`on_free` from inside ``alloc``/``free`` so every allocation path
    (admission, ``_ensure_blocks`` growth, direct frees in tests) is seen.
    ``set_context`` names the slot about to allocate (the sanitizer sets it
    at admission, the adapter before on-demand growth).
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self.owner: dict[int, int] = {}       # block -> owning slot
        self.generation = [0] * num_blocks    # bumped on every free
        self.pending_poison: list[int] = []
        self._slot = -1                       # current allocation context

    def set_context(self, slot: int) -> None:
        self._slot = slot

    def on_alloc(self, blocks) -> None:
        for b in blocks:
            if b in self.owner:
                raise SanitizerError(
                    f"repro-san[paged]: double-reserve of block {b} "
                    f"(generation {self.generation[b]}): owned by slot "
                    f"{self.owner[b]}, handed out again to slot {self._slot}")
            self.owner[b] = self._slot

    def on_free(self, blocks) -> None:
        for b in blocks:
            if b not in self.owner:
                raise SanitizerError(
                    f"repro-san[paged]: free of unowned block {b} "
                    f"(generation {self.generation[b] if 0 <= b < self.num_blocks else '?'}): "
                    "double-free, the sink, or a block the shadow never saw "
                    "allocated")
            del self.owner[b]
            self.generation[b] += 1
            self.pending_poison.append(b)

    def drain_poison(self) -> list[int]:
        out, self.pending_poison = self.pending_poison, []
        return out

    def slot_blocks(self, s: int) -> list[int]:
        return sorted(b for b, owner in self.owner.items() if owner == s)

    def audit_request(self, s: int, req_id) -> None:
        """After ``on_finish`` the slot must own nothing."""
        leaked = self.slot_blocks(s)
        if leaked:
            raise SanitizerError(
                f"repro-san[paged]: leak — request {req_id} finished but "
                f"slot {s} still owns block(s) {leaked}: on_finish must "
                "free everything on_admit/_ensure_blocks reserved")

    def audit_final(self) -> None:
        if self.owner:
            held = dict(sorted(self.owner.items()))
            raise SanitizerError(
                "repro-san[paged]: leak at finalize — block(s) still owned "
                f"at end of serve: {held} (block -> slot)")


class SlotShadow:
    """Per-slot liveness mirror shared by every adapter kind."""

    FREE, LIVE, FROZEN = "free", "live", "frozen"

    def __init__(self, n_slots: int, kind: str):
        self.kind = kind
        self.state = [self.FREE] * n_slots
        self.req: list = [None] * n_slots
        self.frozen_pos: list = [None] * n_slots

    def on_admit(self, s: int, req_id) -> None:
        if self.state[s] == self.LIVE:
            raise SanitizerError(
                f"repro-san[{self.kind}]: double-admit — slot {s} is still "
                f"live for request {self.req[s]} but was handed request "
                f"{req_id}")
        self.state[s] = self.LIVE
        self.req[s] = req_id
        self.frozen_pos[s] = None

    def on_finish(self, s: int, pos) -> None:
        if self.state[s] != self.LIVE:
            raise SanitizerError(
                f"repro-san[{self.kind}]: finish of non-live slot {s} "
                f"(state {self.state[s]})")
        self.state[s] = self.FROZEN
        self.frozen_pos[s] = int(pos)

    def check_frozen(self, pos) -> None:
        """Frozen slot positions must not drift: movement means some write
        path advanced a slot after its request finished (dead-slot write)."""
        for s, st in enumerate(self.state):
            if st == self.FROZEN and int(pos[s]) != self.frozen_pos[s]:
                raise SanitizerError(
                    f"repro-san[{self.kind}]: write to frozen slot {s} "
                    f"(request {self.req[s]} already finished): position "
                    f"moved {self.frozen_pos[s]} -> {int(pos[s])}")

    def check_prefill_group(self, group_slots, req_lens, length: int) -> None:
        """Recurrent prefill must see exact-length groups — a padded row
        feeds pad tokens INTO the recurrence and corrupts the slot state."""
        if self.kind != "recurrent":
            return
        for s, n in zip(group_slots, req_lens):
            if n != length:
                raise SanitizerError(
                    "repro-san[recurrent]: pad rows entering the recurrence "
                    f"— slot {s}'s prompt has {n} tokens but its admission "
                    f"group prefills at padded length {length}")

    def live_slots(self) -> list[int]:
        return [s for s, st in enumerate(self.state) if st == self.LIVE]

    def check_snapshot(self, slots) -> None:
        for s in slots:
            if self.state[s] != self.LIVE:
                raise SanitizerError(
                    f"repro-san[{self.kind}]: snapshot of non-live slot {s} "
                    f"(state {self.state[s]}) — snapshotting freed state is "
                    "a use-after-free on the snapshot path")
