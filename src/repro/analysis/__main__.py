"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 findings (or stale allowlist entries with
``--strict-allowlist``), 2 usage/setup error.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

from repro.analysis import default_checkers
from repro.analysis.engine import Allowlist, run_analysis

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "launch")


def find_root(start: str) -> str:
    """Nearest ancestor containing pyproject.toml (else ``start``)."""
    d = os.path.abspath(start)
    while True:
        if os.path.isfile(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start)
        d = parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: repo-specific static analysis")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to scan (default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect via pyproject.toml)")
    ap.add_argument("--allowlist", default=".repro-lint-allow",
                    help="allowlist file, repo-relative (default: %(default)s)")
    ap.add_argument("--select", action="append", default=None, metavar="ID",
                    help="run only these checker ids; fnmatch globs allowed, "
                         "e.g. 'xray-*' (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list checker ids and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON lines")
    ap.add_argument("--strict-allowlist", action="store_true",
                    help="fail on unused allowlist entries too")
    args = ap.parse_args(argv)

    checkers = default_checkers()
    if args.list:
        for c in checkers:
            print(f"{c.id:20s} {c.description}")
        return 0
    if args.select:
        known = {c.id for c in checkers}
        bad = [pat for pat in args.select
               if not any(fnmatch.fnmatch(k, pat) for k in known)]
        if bad:
            print(f"no checker matches {sorted(set(bad))}; "
                  f"known: {sorted(known)}", file=sys.stderr)
            return 2
        checkers = [c for c in checkers
                    if any(fnmatch.fnmatch(c.id, pat) for pat in args.select)]

    root = os.path.abspath(args.root) if args.root else find_root(os.getcwd())
    allow_path = os.path.join(root, args.allowlist)
    try:
        allowlist = (Allowlist.load(allow_path) if os.path.isfile(allow_path)
                     else Allowlist.empty())
    except ValueError as e:
        print(str(e), file=sys.stderr)
        return 2

    paths = args.paths or [p for p in DEFAULT_PATHS
                           if os.path.exists(os.path.join(root, p))]
    findings, suppressed = run_analysis(checkers, paths, root, allowlist)

    if args.as_json:
        for f in findings:
            print(json.dumps({
                "checker": f.checker, "path": f.path, "line": f.line,
                "col": f.col, "severity": f.severity,
                "message": f.message, "anchor": f.anchor,
            }))
    else:
        for f in findings:
            print(f.render())

    unused = allowlist.unused()
    for rule in unused:
        print(f"{args.allowlist}:{rule.lineno}: warning[allowlist] unused "
              f"entry `{rule.checker} {rule.pattern}` — remove it or the "
              "file rots", file=sys.stderr)

    n_err = len(findings)
    summary = (f"repro-lint: {n_err} finding(s), "
               f"{len(suppressed)} suppressed by allowlist, "
               f"{len(checkers)} checker(s)")
    print(summary, file=sys.stderr)
    if n_err or (args.strict_allowlist and unused):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
