"""repro-xray: compiled-program contracts (DESIGN.md §14).

repro-lint (§11) checks Python source and repro-san (§13) checks runtime
values; neither sees what XLA *actually compiles*.  A missing
``donate_argnums`` that silently copies the whole KV pool every round, or
an "int4" format that lowers to a full f32 weight materialization, passes
both.  xray closes that gap: it compiles every serving-critical jitted
program on CPU from ``eval_shape``-sized inputs (no weights are ever
materialized), then checks contracts against the optimized HLO via the
shared ``analysis/hlo.py`` parser:

  xray-donation    cache/pool inputs appear in the module's
                   ``input_output_alias`` map and the program updates a
                   cache-shaped buffer in place (dynamic-update-slice or
                   scatter root) instead of rebuilding it.
  xray-dequant     decode never materializes a weight-logical-shaped
                   float buffer above a threshold: quantized weights must
                   dequantize inside fusions, never as standalone buffers.
  xray-bytes       HLO HBM traffic per decode step agrees with the
                   registry ``nbytes``/``bits_per_weight`` model within
                   ``BYTES_RTOL`` for every quant preset — "int4" that
                   streams f32 fails here.
  xray-collective  decode contains only the collectives the sharding
                   policy predicts (none on a single device) and the
                   layer scan's trip count equals ``num_layers``.

The program catalog covers the contiguous / paged / recurrent adapters'
decode, verify, insert, and prefill programs on reduced archs (tinyllama
GQA, deepseek MLA, rwkv6 state), plus full-size tinyllama single-request
decode per quant preset for the traffic contract.  It is compiled once
per process and shared by all four checkers and ``benchmarks/xray_bench``.

Contract point for the bytes audit: batch 1, short context (the paper's
real-time decode setting), where weight streaming dominates and the
nbytes model is exact; cache and activation traffic are modeled
explicitly (see ``expected_decode_bytes``).
"""

from __future__ import annotations

import dataclasses
import os
from collections import Counter
from typing import Callable, Iterable

from repro.analysis.engine import BaseChecker, Finding
from repro.analysis.hlo import Module, dims_key, shape_bytes

XRAY_ANCHOR = "src/repro/analysis/xray.py"

# f32 weight-shaped buffers smaller than this are tolerated (reduced-arch
# test weights, per-row dequants of gathered embedding rows)
DEQUANT_THRESHOLD = 1 << 16

# bytes-per-step model-vs-HLO relative tolerance. Measured headroom on the
# current tree (B=1, T=64): int8 +3%, int4/mixed +6%, int3/mixed3 +7%,
# fp8 +9%, kv-quant rows +2% — the residual is CPU-materialized
# activation/cache-slab traffic the TPU normalization cannot fully remove.
# A preset streaming weights at the wrong width blows through this by 2x
# or more.
BYTES_RTOL = 0.15

BYTES_PRESETS = ("int8", "int4", "mixed", "int3", "fp8", "mixed3")

# quantized-KV decode programs: weight preset int8 (the paper baseline), the
# cache stored at kv_quant width plus per-row f32 scale leaves — the bytes
# model accounts cache leaves generically at their storage itemsize
KV_QUANT_PRESETS = ("int8", "fp8")
BYTES_ARCH = "tinyllama-1.1b"
BYTES_BATCH = 1
BYTES_CACHE_LEN = 64

_FLOAT_DTYPES = {"f16", "bf16", "f32", "f64"}


@dataclasses.dataclass
class XrayProgram:
    """One compiled serving program plus its contract expectations."""

    name: str                      # e.g. "tinyllama-1.1b/contiguous/decode_chunk"
    kind: str                      # decode | prefill | verify | insert
    hlo_text: str
    path: str                      # repo-relative source anchor of the jit
    line: int
    cache_sigs: Counter            # dims sigs of cache/pool INPUT leaves
    require_alias: bool = False    # cache inputs must be donated/aliased
    require_dus: bool = False      # in-place write of a cache-shaped buffer
    weight_sigs: frozenset = frozenset()   # quantized-weight logical dims sigs
    num_layers: int | None = None  # expected layer-scan trip count
    expected_collectives: frozenset = frozenset()
    expected_bytes: float | None = None    # nbytes-model bytes per decode step
    fmt: str | None = None         # quant preset (bytes rows)

    def module(self) -> Module:
        return Module(self.hlo_text)


def _sig(shape) -> str:
    return ",".join(str(d) for d in shape)


def _cache_sigs(struct) -> Counter:
    import jax

    return Counter(_sig(leaf.shape) for leaf in jax.tree.leaves(struct))


def _anchor(fn) -> tuple[str, int]:
    """Repo-relative (path, line) of a (possibly jit-wrapped) function."""
    code = getattr(getattr(fn, "__wrapped__", fn), "__code__", None)
    if code is None:
        return XRAY_ANCHOR, 1
    path = code.co_filename
    marker = os.sep + "src" + os.sep + "repro" + os.sep
    if marker in path:
        path = "src/repro/" + path.split(marker, 1)[1].replace(os.sep, "/")
    return path, code.co_firstlineno


def weight_dims_sigs(qparams) -> frozenset:
    """Dims signatures a dequantized weight buffer could take in HLO:
    each QuantizedTensor's logical shape, its per-layer slice, and the
    transposed variants (CPU gemms transpose weights freely)."""
    import jax

    from repro.core.quant import QuantizedTensor

    sigs: set[str] = set()
    for leaf in jax.tree.leaves(
            qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if not isinstance(leaf, QuantizedTensor):
            continue
        shp = tuple(leaf.logical_shape)
        variants = [shp, shp[:-2] + (shp[-1], shp[-2])]
        if len(shp) >= 3:
            variants += [shp[1:], (shp[2], shp[1]),
                         (1,) + shp[1:], (1, shp[2], shp[1])]
        sigs.update(_sig(v) for v in variants)
    return frozenset(sigs)


def expected_decode_bytes(qparams, cache_struct, batch: int, vocab: int) -> float:
    """Registry-model HBM bytes for one decode step: every quantized leaf
    at its ``nbytes()`` storage size (the embedding table at ``batch``
    gathered rows) plus its GQMV group-sums intermediate — the XLA oracle
    materializes a scales-shaped s32/f32 buffer between the grouped dot and
    the scale combine (dot write + combine read; the Pallas kernel keeps it
    in VMEM, but the audited artifact is the CPU-compiled program) — float
    leaves in full, the cache once for attention reads plus a read+write
    layer-slab commit per layer (the baseline ``deferred_decode_cache=False``
    dataflow), and the f32 logits write."""
    import jax
    import jax.numpy as jnp
    import jax.tree_util as jtu

    from repro.core.policy import leaf_class
    from repro.core.quant import QuantizedTensor

    total = 0.0
    for path, leaf in jtu.tree_leaves_with_path(
            qparams, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if isinstance(leaf, QuantizedTensor):
            nb = leaf.nbytes()
            gsum = 2.0 * leaf.scales.size * 4   # group-sums: dot write + read
            if leaf_class(p) == "embed":
                nb = nb * batch / leaf.logical_shape[0]   # row gather
                gsum = 0.0                      # gathered rows skip the GQMV
            total += nb + gsum
        else:
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    for leaf in jax.tree.leaves(cache_struct):
        total += 3.0 * leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total + batch * vocab * 4


# ---------------------------------------------------------------------------
# program catalog
# ---------------------------------------------------------------------------

_CATALOG: list[XrayProgram] | None = None


def catalog() -> list[XrayProgram]:
    """All serving-critical compiled programs, built once per process."""
    global _CATALOG
    if _CATALOG is None:
        _CATALOG = _build_bytes_programs() + _build_serving_programs()
    return _CATALOG


def _build_bytes_programs() -> list[XrayProgram]:
    """Full-size single-request decode per quant preset: the traffic,
    dequant-streaming, and trip-count contract rows."""
    import jax
    import jax.numpy as jnp

    from repro.core.policy import quantize_params
    from repro.models.registry import build, load_config

    cfg = load_config(BYTES_ARCH)
    model = build(cfg)
    pstruct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    tok = jax.ShapeDtypeStruct((BYTES_BATCH,), jnp.int32)
    pos = jax.ShapeDtypeStruct((BYTES_BATCH,), jnp.int32)
    path, line = _anchor(model.decode)
    decode = jax.jit(model.decode, donate_argnums=(2,))

    progs = []
    for fmt in BYTES_PRESETS:
        qstruct = jax.eval_shape(
            lambda p, f=fmt: quantize_params(p, cfg.group_size, formats=f),
            pstruct)
        cstruct = jax.eval_shape(
            lambda: model.init_cache(BYTES_BATCH, BYTES_CACHE_LEN, cfg.cdtype()))
        hlo = decode.lower(qstruct, tok, cstruct, pos).compile().as_text()
        progs.append(XrayProgram(
            name=f"{BYTES_ARCH}/decode[{fmt}]", kind="decode",
            hlo_text=hlo, path=path, line=line,
            cache_sigs=_cache_sigs(cstruct),
            require_alias=True, require_dus=True,
            weight_sigs=weight_dims_sigs(qstruct),
            num_layers=cfg.num_layers,
            expected_bytes=expected_decode_bytes(
                qstruct, cstruct, BYTES_BATCH, cfg.vocab_size),
            fmt=fmt,
        ))

    # quantized-KV rows: int8 weights, cache at kv_quant storage width +
    # per-row f32 scales. expected_decode_bytes sums cache leaves at their
    # dtype itemsize, so the narrower pool and its scale overhead are both
    # in the model — a decode path that silently dequantizes the cache to
    # f32 slabs blows the bytes contract here.
    qstruct = jax.eval_shape(
        lambda p: quantize_params(p, cfg.group_size, formats="int8"), pstruct)
    for kvq in KV_QUANT_PRESETS:
        kcfg = dataclasses.replace(cfg, kv_quant=kvq)
        kmodel = build(kcfg)
        kdecode = jax.jit(kmodel.decode, donate_argnums=(2,))
        kpath, kline = _anchor(kmodel.decode)
        cstruct = jax.eval_shape(
            lambda m=kmodel: m.init_cache(BYTES_BATCH, BYTES_CACHE_LEN,
                                          kcfg.cdtype()))
        hlo = kdecode.lower(qstruct, tok, cstruct, pos).compile().as_text()
        progs.append(XrayProgram(
            name=f"{BYTES_ARCH}/decode[int8+kv_{kvq}]", kind="decode",
            hlo_text=hlo, path=kpath, line=kline,
            cache_sigs=_cache_sigs(cstruct),
            require_alias=True, require_dus=True,
            weight_sigs=weight_dims_sigs(qstruct),
            num_layers=cfg.num_layers,
            expected_bytes=expected_decode_bytes(
                qstruct, cstruct, BYTES_BATCH, cfg.vocab_size),
            fmt=f"int8+kv_{kvq}",
        ))
    return progs


def _build_serving_programs() -> list[XrayProgram]:
    """Reduced-arch adapter sweep: every CacheAdapter's decode / verify /
    insert / prefill programs, lowered from eval_shape-sized inputs."""
    import jax
    import jax.numpy as jnp

    from repro.models.registry import build, load_config
    from repro.serving.core import ContiguousAdapter, RecurrentAdapter, SchedulerCore
    from repro.serving.engine import InferenceEngine
    from repro.serving.paged import PagedAdapter

    SLOTS, CHUNK, K, CACHE_LEN, PLEN, GROUP = 2, 3, 2, 64, 8, 2

    def lower(fn, *args):
        return fn.lower(*args).compile().as_text()

    def structs(**kw):
        return {k: jax.ShapeDtypeStruct(shape, dt) for k, (shape, dt) in kw.items()}

    progs: list[XrayProgram] = []
    i32, b8, u32 = jnp.int32, jnp.bool_, jnp.uint32

    for arch, adapter_cls, spec in (
        ("tinyllama-1.1b", ContiguousAdapter, True),
        ("tinyllama-1.1b", PagedAdapter, True),
        ("deepseek-v2-lite-16b", ContiguousAdapter, False),
        ("rwkv6-7b", RecurrentAdapter, False),
    ):
        cfg = load_config(arch).reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = InferenceEngine(model, params, cache_len=CACHE_LEN,
                                 sanitize=False)
        adapter = adapter_cls(engine)
        SchedulerCore(engine, adapter, slots=SLOTS, chunk=CHUNK,
                      spec_k=K if spec else None, sanitize=False)

        kind = adapter.kind
        tag = f"{arch}/{kind}"
        s = structs(
            tok=((SLOTS,), i32), pos=((SLOTS,), i32), live=((SLOTS,), b8),
            keys=((CHUNK, 2), u32), key=((2,), u32),
            lens=((GROUP,), i32), toks=((GROUP, PLEN), i32),
            chunk=((SLOTS, K), i32), remaining=((SLOTS,), i32),
            slots=((GROUP,), i32),
        )
        state = model.cache_kind == "state"

        if kind == "paged":
            pool = jax.eval_shape(lambda: model.init_paged_cache(
                adapter.num_blocks, adapter.block_size, cfg.cdtype()))
            table = jax.ShapeDtypeStruct((SLOTS, adapter.blocks_per_req), i32)
            psig = _cache_sigs(pool)
            p, ln = _anchor(adapter._decode_until)
            progs.append(XrayProgram(
                name=f"{tag}/decode_until", kind="decode",
                hlo_text=lower(adapter._decode_until, engine.params, s["tok"],
                               pool, table, s["pos"], s["live"],
                               s["remaining"], s["keys"]),
                path=p, line=ln, cache_sigs=psig,
                require_alias=True, require_dus=True))
            rows = jax.eval_shape(lambda: model.init_cache(GROUP, PLEN, cfg.cdtype()))
            itables = jax.ShapeDtypeStruct(
                (GROUP, PLEN // adapter.block_size), i32)
            p, ln = _anchor(adapter._insert)
            progs.append(XrayProgram(
                name=f"{tag}/insert", kind="insert",
                hlo_text=lower(adapter._insert, pool, rows, itables),
                path=p, line=ln, cache_sigs=psig,
                require_alias=True, require_dus=True))
            p, ln = _anchor(adapter._verify_step)
            progs.append(XrayProgram(
                name=f"{tag}/verify", kind="verify",
                hlo_text=lower(adapter._verify_step, engine.params, s["chunk"],
                               pool, table, s["pos"], s["live"],
                               s["remaining"], s["key"]),
                path=p, line=ln, cache_sigs=psig,
                require_alias=True, require_dus=True))
            continue

        cache = jax.eval_shape(lambda: model.init_cache(
            SLOTS, CACHE_LEN, cfg.cdtype()))
        csig = _cache_sigs(cache)
        p, ln = _anchor(adapter._decode_chunk)
        progs.append(XrayProgram(
            name=f"{tag}/decode_chunk", kind="decode",
            hlo_text=lower(adapter._decode_chunk, engine.params, s["tok"],
                           cache, s["pos"], s["live"], s["keys"]),
            path=p, line=ln, cache_sigs=csig,
            require_alias=True, require_dus=not state))
        if not state:
            rows = jax.eval_shape(lambda: model.init_cache(
                GROUP, CACHE_LEN, cfg.cdtype()))
            p, ln = _anchor(adapter._insert)
            progs.append(XrayProgram(
                name=f"{tag}/insert_slots", kind="insert",
                hlo_text=lower(adapter._insert, cache, rows, s["slots"]),
                path=p, line=ln, cache_sigs=csig,
                require_alias=True, require_dus=True))
        if spec:
            p, ln = _anchor(adapter._verify_step)
            progs.append(XrayProgram(
                name=f"{tag}/verify", kind="verify",
                hlo_text=lower(adapter._verify_step, engine.params, s["chunk"],
                               cache, s["pos"], s["live"], s["remaining"],
                               s["key"]),
                path=p, line=ln, cache_sigs=csig,
                require_alias=True, require_dus=True))
        pf = adapter.prefill(PLEN)
        p, ln = _anchor(pf)
        progs.append(XrayProgram(
            name=f"{tag}/prefill", kind="prefill",
            hlo_text=lower(pf, engine.params, s["toks"], s["lens"], s["key"]),
            path=p, line=ln, cache_sigs=Counter()))
    return progs


# ---------------------------------------------------------------------------
# audits
# ---------------------------------------------------------------------------

def audit_donation(prog: XrayProgram) -> Iterable[Finding]:
    """Cache/pool inputs must be donated (module input_output_alias) and —
    for kv caches — updated in place via DUS/scatter, not rebuilt."""
    if not prog.require_alias:
        return
    mod = prog.module()
    pshapes = mod.param_shapes()
    aliased = Counter(
        dims_key(pshapes[p]) for (_, p, _, _) in mod.aliases() if p in pshapes)
    missing = prog.cache_sigs - aliased
    for sig, n in sorted(missing.items()):
        # name the offending parameter instruction(s)
        params = [f"%p{idx}: {shp}" for idx, shp in sorted(pshapes.items())
                  if dims_key(shp) == sig]
        yield Finding(
            "xray-donation", prog.path, prog.line,
            f"{prog.name}: {n} cache input(s) of dims [{sig}] are not in the "
            f"compiled module's input_output_alias map ({params[:n]}) — the "
            "program copies the cache every call; donate the cache argument "
            "(donate_argnums) so XLA aliases it in place")
    if prog.require_dus:
        dus = mod.dus_dims_keys()
        if not any(sig in dus for sig in prog.cache_sigs):
            yield Finding(
                "xray-donation", prog.path, prog.line,
                f"{prog.name}: no dynamic-update-slice/scatter writes a "
                f"cache-shaped buffer (cache dims {sorted(prog.cache_sigs)}, "
                f"in-place writes {sorted(dus)}) — the cache update lowered "
                "to a full rebuild instead of an in-place commit")


def audit_dequant(prog: XrayProgram,
                  threshold: int = DEQUANT_THRESHOLD) -> Iterable[Finding]:
    """No weight-logical-shaped float buffer above ``threshold`` may be
    materialized: dequantization must stay inside fusions feeding the
    matmul, never become a standalone weight copy."""
    if not prog.weight_sigs:
        return
    mod = prog.module()
    seen: set[str] = set()
    for i, _ in mod.materialized_instrs():
        dt = i.shape.split("[", 1)[0].strip("() ")
        if dt not in _FLOAT_DTYPES:
            continue
        if dims_key(i.shape) not in prog.weight_sigs:
            continue
        if shape_bytes(i.shape) < threshold:
            continue
        if mod.instr_hbm_bytes(i) <= 0.0:
            continue        # normalized convert/slice chains: not a buffer
        if i.name in seen:
            continue
        seen.add(i.name)
        yield Finding(
            "xray-dequant", prog.path, prog.line,
            f"{prog.name}: %{i.name} materializes a weight-shaped float "
            f"buffer {i.shape.strip()} ({shape_bytes(i.shape) / 1e6:.1f} MB) "
            "— quantized weights must dequantize inside the consuming "
            "fusion and stream at storage width, never as a standalone "
            "dequantized copy")


def audit_bytes(prog: XrayProgram, rtol: float = BYTES_RTOL) -> Iterable[Finding]:
    """HLO-derived HBM bytes per decode step must agree with the registry
    nbytes model within ``rtol``."""
    if prog.expected_bytes is None:
        return
    from repro.analysis.hlo import analyze

    rep = analyze(prog.hlo_text)
    delta = rep.hbm_bytes / prog.expected_bytes - 1.0
    if abs(delta) <= rtol:
        return
    _, top = analyze(prog.hlo_text, top_k=1)
    worst = (f"; top contributor %{top[0][3]} ({top[0][2]} {top[0][4]}, "
             f"{top[0][0] / 1e6:.1f} MB)") if top else ""
    yield Finding(
        "xray-bytes", prog.path, prog.line,
        f"{prog.name}: compiled decode moves {rep.hbm_bytes / 1e6:.1f} MB/step "
        f"but the registry nbytes model says {prog.expected_bytes / 1e6:.1f} MB "
        f"({delta:+.1%}, tolerance ±{rtol:.0%}) — the {prog.fmt} format is "
        f"not streaming weights at its declared width{worst}")


def audit_collectives(prog: XrayProgram) -> Iterable[Finding]:
    """Decode contains only the collectives the sharding policy predicts,
    and the layer scan's trip count equals num_layers."""
    mod = prog.module()
    for i, _, base in mod.collective_instrs():
        if base not in prog.expected_collectives:
            yield Finding(
                "xray-collective", prog.path, prog.line,
                f"{prog.name}: unexpected {base} %{i.name} ({i.shape.strip()}) "
                f"— the sharding policy predicts "
                f"{sorted(prog.expected_collectives) or 'no collectives'} for "
                "this program; an unpredicted collective means an input lost "
                "its sharding annotation and is being re-gathered every step")
    if prog.num_layers is not None:
        trips = mod.while_trip_counts()
        if prog.num_layers not in trips:
            yield Finding(
                "xray-collective", prog.path, prog.line,
                f"{prog.name}: no while loop runs num_layers={prog.num_layers} "
                f"trips (found {sorted(trips)}) — the layer scan unrolled or "
                "lost iterations; per-step traffic no longer scales the way "
                "the roofline model assumes")


# ---------------------------------------------------------------------------
# checkers (repro-lint engine plumbing)
# ---------------------------------------------------------------------------

class _XrayChecker(BaseChecker):
    """Shared plumbing: build/reuse the program catalog, wrap failures."""

    audit: Callable = None
    only_kinds: tuple = ()

    def __init__(self, catalog_fn: Callable[[], list[XrayProgram]] | None = None):
        self._catalog_fn = catalog_fn or catalog

    def check_project(self, root: str) -> Iterable[Finding]:
        try:
            progs = self._catalog_fn()
        except Exception as e:  # noqa: BLE001 — surface as a finding, not a crash
            yield Finding(self.id, XRAY_ANCHOR, 1,
                          f"xray program catalog failed to build: {e!r}")
            return
        for prog in progs:
            if self.only_kinds and prog.kind not in self.only_kinds:
                continue
            yield from type(self).audit(prog)


class XrayDonationChecker(_XrayChecker):
    id = "xray-donation"
    description = ("compiled serving programs donate their cache/pool "
                   "inputs (HLO input_output_alias) and commit updates "
                   "in place via dynamic-update-slice")
    audit = staticmethod(audit_donation)


class XrayDequantChecker(_XrayChecker):
    id = "xray-dequant"
    description = ("compiled decode never materializes a weight-shaped "
                   "float buffer: quantized weights stream at storage "
                   "width and dequantize inside fusions")
    audit = staticmethod(audit_dequant)
    only_kinds = ("decode",)


class XrayBytesChecker(_XrayChecker):
    id = "xray-bytes"
    description = ("HLO HBM bytes per decode step match the registry "
                   "nbytes/bits_per_weight model within tolerance for "
                   "every quant preset")
    audit = staticmethod(audit_bytes)
    only_kinds = ("decode",)


class XrayCollectiveChecker(_XrayChecker):
    id = "xray-collective"
    description = ("compiled decode contains only the collectives the "
                   "sharding policy predicts and the layer scan runs "
                   "exactly num_layers trips")
    audit = staticmethod(audit_collectives)
