"""host-sync checker: the async-pipeline contract, statically.

LlamaF's pipeline never lets the host block the accelerator (§IV); our
serving replay of that invariant is the PR 5 hoist — ONE device round-trip
per scheduler chunk. Two rules lock it in:

1. **No sync inside a jitted scope.** ``jax.device_get`` / ``np.asarray`` /
   ``.item()`` / ``.block_until_ready()`` on a tracer either fails at trace
   time or (worse) silently constant-folds; none of them belong inside a
   function that is ``jax.jit``-ed (directly, via ``partial(jax.jit, ...)``
   or by being passed to ``jax.jit(f)``).

2. **Chunk-loop budget** (scheduler files only): inside a ``while`` serve
   loop, each execution path may perform at most ``max_per_path`` (default
   2: one admission transfer + one chunk transfer) device round-trips, and
   NONE may sit inside a nested ``for`` — a per-item sync is exactly the
   regression that re-serializes the pipeline per request instead of per
   chunk. Paths are split on ``if ...: ... continue`` arms (the speculative
   vs vanilla chunk branches).

Sync sites counted: ``jax.device_get``, ``jax.block_until_ready``,
``.item()``, ``.block_until_ready()``, and — on a name tainted as a device
value (assigned from a jitted/self-underscore callable, a call-of-a-call
like ``self._prefill_fn(n)(...)``, or carrying the ``*_d`` device-naming
convention) — the converters ``np.asarray``/``np.array`` and the IMPLICIT
casts ``float(x)`` / ``int(x)``. The casts are the sneaky ones: a
``float()`` on a device scalar compiles, runs, and blocks the pipeline
exactly like ``.item()``, with nothing in the name to give it away.
Names already fetched (e.g. assigned from ``jax.device_get``) are host
values and stay clean.
"""

from __future__ import annotations

import ast
import fnmatch
from typing import Iterable

from repro.analysis.engine import (
    BaseChecker,
    Finding,
    assigned_names,
    dotted_name,
    is_jit_expr,
)

SYNC_FUNCS = {"jax.device_get", "jax.block_until_ready"}
NP_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
IMPLICIT_CASTS = {"float", "int"}
SYNC_METHODS = {"item", "block_until_ready"}

DEFAULT_LOOP_FILES = (
    "*serving/batching.py",
    "*serving/core.py",
    "*serving/paged.py",
    "*serving/engine.py",
)


def _jitted_defs(tree: ast.AST) -> list[ast.FunctionDef]:
    """Function defs that become traced scopes: jit-decorated, or passed by
    name to a ``jax.jit(f, ...)`` call anywhere in the module."""
    by_name: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)
    out, seen = [], set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(is_jit_expr(d) for d in node.decorator_list):
                if id(node) not in seen:
                    seen.add(id(node))
                    out.append(node)
        elif isinstance(node, ast.Call) and dotted_name(node.func) in ("jax.jit", "jit"):
            if node.args and isinstance(node.args[0], ast.Name):
                for fd in by_name.get(node.args[0].id, ()):
                    if id(fd) not in seen:
                        seen.add(id(fd))
                        out.append(fd)
    return out


def _sync_call_kind(node: ast.Call, tainted: set[str]) -> str | None:
    """Classify a call node as a host sync; returns a short label or None."""
    name = dotted_name(node.func)
    if name in SYNC_FUNCS:
        return name
    if name in NP_CONVERTERS or name in IMPLICIT_CASTS:
        if node.args and isinstance(node.args[0], ast.Name):
            arg = node.args[0].id
            if arg in tainted or arg.endswith("_d"):
                return f"{name}({arg})"
        return None
    if isinstance(node.func, ast.Attribute) and node.func.attr in SYNC_METHODS:
        # x.item() / x.block_until_ready(); skip np.* lookalikes
        base = dotted_name(node.func.value)
        if base.split(".")[0] not in ("np", "numpy", "math"):
            return f".{node.func.attr}()"
    return None


def _taint(fn: ast.AST) -> set[str]:
    """Names in ``fn`` bound to device values: results of self._* calls,
    call-of-call expressions, or locally jitted functions."""
    local_jitted = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(is_jit_expr(d) for d in node.decorator_list):
                local_jitted.add(node.name)
    tainted: set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign) or not isinstance(node.value, ast.Call):
            continue
        callee = node.value.func
        device_call = (
            isinstance(callee, ast.Call)  # self._prefill_fn(n)(...)
            or (isinstance(callee, ast.Attribute)
                and isinstance(callee.value, ast.Name)
                and callee.value.id == "self" and callee.attr.startswith("_"))
            or (isinstance(callee, ast.Name) and callee.id in local_jitted)
        )
        if device_call:
            for t in node.targets:
                tainted.update(assigned_names(t))
    return tainted


class _SyncSites(ast.NodeVisitor):
    """Collect sync call sites under one statement, without descending into
    nested function definitions (their bodies run elsewhere)."""

    def __init__(self, tainted: set[str]):
        self.tainted = tainted
        self.sites: list[tuple[ast.Call, str, bool]] = []  # node, label, in_for
        self._for_depth = 0

    def visit_FunctionDef(self, node):  # do not descend
        pass

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    def visit_For(self, node):
        self._for_depth += 1
        self.generic_visit(node)
        self._for_depth -= 1

    def visit_Call(self, node):
        kind = _sync_call_kind(node, self.tainted)
        if kind is not None:
            self.sites.append((node, kind, self._for_depth > 0))
        self.generic_visit(node)


def _sites(stmts, tainted) -> list[tuple[ast.Call, str, bool]]:
    v = _SyncSites(tainted)
    for s in stmts:
        v.visit(s)
    return v.sites


def _ends_in_continue(body: list[ast.stmt]) -> bool:
    return bool(body) and isinstance(body[-1], ast.Continue)


class HostSyncChecker(BaseChecker):
    id = "host-sync"
    description = ("no device round-trips inside jitted scopes; at most "
                   "max_per_path per scheduler chunk-loop path, none inside "
                   "a nested for")

    def __init__(self, loop_files=DEFAULT_LOOP_FILES, max_per_path: int = 2):
        self.loop_files = loop_files
        self.max_per_path = max_per_path

    # -- rule 1: jitted scopes ---------------------------------------------
    def _check_jit_scopes(self, path, tree) -> Iterable[Finding]:
        for fn in _jitted_defs(tree):
            for node, kind, _ in _sites(fn.body, tainted=set()):
                yield Finding(
                    self.id, path, node.lineno,
                    f"host sync {kind} inside jitted `{fn.name}`: device "
                    "round-trips in a traced scope stall the pipeline (or "
                    "constant-fold a tracer)", col=node.col_offset)

    # -- rule 2: chunk loops ------------------------------------------------
    def _check_chunk_loops(self, path, tree) -> Iterable[Finding]:
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            tainted = _taint(fn)
            for loop in ast.walk(fn):
                if not isinstance(loop, ast.While):
                    continue
                yield from self._check_loop(path, fn, loop, tainted)

    def _check_loop(self, path, fn, loop, tainted) -> Iterable[Finding]:
        # nested-for rule
        for node, kind, in_for in _sites(loop.body, tainted):
            if in_for:
                yield Finding(
                    self.id, path, node.lineno,
                    f"host sync {kind} inside a for-loop of `{fn.name}`'s "
                    "serve loop: per-item round-trips re-serialize the "
                    "pipeline — batch the transfer and sync once per chunk",
                    col=node.col_offset)
        # path budget: one path per `if ...: ... continue` arm + fallthrough
        paths: list[list] = []
        prefix: list = []
        for stmt in loop.body:
            if isinstance(stmt, ast.If) and _ends_in_continue(stmt.body):
                paths.append(prefix + _sites(stmt.body, tainted))
                prefix = prefix + _sites(stmt.orelse, tainted)
            else:
                prefix = prefix + _sites([stmt], tainted)
        paths.append(prefix)
        for sites in paths:
            sites = [s for s in sites if not s[2]]  # for-loop sites already flagged
            if len(sites) > self.max_per_path:
                node, kind, _ = sites[self.max_per_path]
                yield Finding(
                    self.id, path, node.lineno,
                    f"{len(sites)} host syncs on one path of `{fn.name}`'s "
                    f"serve loop (budget {self.max_per_path}): the chunk "
                    "contract is one admission transfer + one chunk "
                    f"transfer; extra site is {kind}", col=node.col_offset)

    def check_file(self, path, tree, source) -> Iterable[Finding]:
        yield from self._check_jit_scopes(path, tree)
        if any(fnmatch.fnmatch(path, g) for g in self.loop_files):
            yield from self._check_chunk_loops(path, tree)
