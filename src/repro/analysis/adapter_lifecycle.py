"""adapter-lifecycle checker: the CacheAdapter alloc/free contract, statically.

The scheduling core (serving/core.py) owns ONE cache lifecycle — alloc on
admit, insert on prefill, commit per round, free on finish — and every
``CacheAdapter`` subclass re-implements some slice of it. The repro-san
shadow tracker (analysis/shadow.py) catches violations at runtime; this
checker catches the *structural* ones before a request ever runs:

1. **alloc without free** — an adapter class whose own body calls
   ``.alloc(...)`` anywhere outside ``on_finish`` must define an
   ``on_finish`` that calls ``.free(...)``. An adapter that reserves pool
   blocks but never returns them leaks the pool dry one finished request
   at a time; the shadow audit would catch it per-request, this catches it
   per-commit.

2. **concrete adapter without san_state** — a class declaring a concrete
   ``kind`` (a string other than ``"abstract"``, plain or annotated
   assign) must define ``san_state`` in its OWN body. The sanitizer
   mirrors whatever the adapter allocates through ``san_state()``; an
   inherited stub means a new allocator ships with zero shadow coverage
   (see the shadow-coverage checker for the registry-side ledger).

3. **serve loop without end_serve** — a function that contains a
   ``while`` loop AND calls ``.begin_serve()`` must also call
   ``.end_serve()``, and must not ``return`` from inside the ``while``:
   an early return skips the adapter's pool accounting and the
   sanitizer's finalize audit. (Straight-line setup code — fixtures,
   tests that poke one adapter method — has no serve loop and is exempt.)

Adapter classes are recognized by a base name ending in ``Adapter`` or an
own-body ``kind`` string assignment; helper classes (pools, trackers) are
not audited.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import BaseChecker, Finding

ABSTRACT_KIND = "abstract"


def _own_kind(cls: ast.ClassDef) -> str | None:
    """The class's own-body ``kind = "<str>"`` value (Assign or AnnAssign),
    or None when not declared locally."""
    for stmt in cls.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            target, value = stmt.targets[0], stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            target, value = stmt.target, stmt.value
        else:
            continue
        if (isinstance(target, ast.Name) and target.id == "kind"
                and isinstance(value, ast.Constant)
                and isinstance(value.value, str)):
            return value.value
    return None


def _is_adapter_class(cls: ast.ClassDef) -> bool:
    if any(isinstance(b, (ast.Name, ast.Attribute))
           and _base_name(b).endswith("Adapter") for b in cls.bases):
        return True
    return cls.name.endswith("Adapter") or _own_kind(cls) is not None


def _base_name(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _method_calls(node: ast.AST) -> Iterable[ast.Call]:
    """All ``<expr>.<attr>(...)`` calls under ``node``."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute):
            yield n


def _shallow_walk(stmts: list[ast.stmt]) -> Iterable[ast.AST]:
    """Walk statements without descending into nested function/class
    definitions or lambdas (their bodies run in another lifecycle)."""
    stack: list[ast.AST] = list(stmts)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class AdapterLifecycleChecker(BaseChecker):
    id = "adapter-lifecycle"
    description = ("CacheAdapter subclasses: alloc implies an on_finish that "
                   "frees; concrete kinds define san_state; serve loops "
                   "reach end_serve")

    # -- rules 1 + 2: per adapter class --------------------------------------
    def _check_class(self, path: str, cls: ast.ClassDef) -> Iterable[Finding]:
        methods = {stmt.name: stmt for stmt in cls.body
                   if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))}

        # rule 1: .alloc( outside on_finish => on_finish containing .free(
        alloc_site = None
        for name, fn in methods.items():
            if name == "on_finish":
                continue
            for call in _method_calls(fn):
                if call.func.attr == "alloc":
                    alloc_site = (name, call)
                    break
            if alloc_site:
                break
        if alloc_site is not None:
            name, call = alloc_site
            on_finish = methods.get("on_finish")
            frees = on_finish is not None and any(
                c.func.attr == "free" for c in _method_calls(on_finish))
            if not frees:
                yield Finding(
                    self.id, path, call.lineno,
                    f"{cls.name}.{name} allocates (`.alloc(...)`) but the "
                    "class defines no on_finish that frees: finished "
                    "requests leak their blocks and the pool drains — pair "
                    "every alloc with a `.free(...)` in on_finish",
                    col=call.col_offset)

        # rule 2: concrete kind => own-body san_state
        kind = _own_kind(cls)
        if (kind is not None and kind != ABSTRACT_KIND
                and "san_state" not in methods):
            yield Finding(
                self.id, path, cls.lineno,
                f"{cls.name} declares kind={kind!r} but no own-body "
                "san_state: the repro-san shadow tracker cannot mirror this "
                "adapter's allocator — define san_state() returning "
                "{'pool': ..., 'table': ...} (None for slot-only adapters)",
                col=cls.col_offset)

    # -- rule 3: serve-loop lifecycle ----------------------------------------
    def _check_serve_fn(self, path: str,
                        fn: ast.FunctionDef) -> Iterable[Finding]:
        shallow = list(_shallow_walk(fn.body))
        begins = [n for n in shallow
                  if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                  and n.func.attr == "begin_serve"]
        whiles = [n for n in shallow if isinstance(n, ast.While)]
        if not begins or not whiles:
            return
        ends = any(isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                   and n.func.attr == "end_serve" for n in shallow)
        if not ends:
            yield Finding(
                self.id, path, begins[0].lineno,
                f"`{fn.name}` serves (begin_serve + while loop) but never "
                "calls end_serve: pool accounting and the sanitizer finalize "
                "audit are skipped", col=begins[0].col_offset)
        for loop in whiles:
            for n in _shallow_walk(loop.body):
                if isinstance(n, ast.Return):
                    yield Finding(
                        self.id, path, n.lineno,
                        f"return inside `{fn.name}`'s serve while-loop: "
                        "early exit skips end_serve (and the sanitizer "
                        "leak audit) — break out and return after the loop",
                        col=n.col_offset)

    def check_file(self, path, tree, source) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and _is_adapter_class(node):
                yield from self._check_class(path, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_serve_fn(path, node)
