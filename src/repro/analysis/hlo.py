"""Shared post-SPMD HLO analysis: FLOPs, HBM traffic, collective bytes,
aliasing — with while-loop (lax.scan) trip-count expansion.

This is the library half of what used to live in ``launch/hlo_analysis.py``
(that module is now a thin re-export shim).  It is consumed by two very
different callers:

  * ``launch/dryrun.py`` — the roofline report (``roofline_from_compiled``);
  * ``analysis/xray.py`` — compiled-program contract checkers (donation,
    dequant streaming, bytes-per-step, collectives; DESIGN.md §14).

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a
while body ONCE, so any scan-over-layers model (all of ours) is undercounted
by ~num_layers x.  We therefore walk the per-device optimized HLO text
ourselves:

  * instruction table: every ``%name = shape op(operands)`` line, so operand
    shapes resolve through references;
  * call graph: while(condition/body) edges carry the loop trip count
    (largest integer constant in the condition computation — exact for
    lax.scan), fusion/call edges carry 1;
  * FLOPs: dot/convolution instructions (2 * numel(out) * contraction),
    walked through fusion bodies too;
  * HBM bytes: operand + output bytes of materialized instructions (fusion
    boundaries), skipping bookkeeping ops — the read+write traffic model;
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Byte accounting is bits-based: sub-byte dtypes (s4/u4 = 4 bits, u1/s1 =
1 bit) are charged at their packed size, ``ceil(numel * bits / 8)`` — a
packed-int4 buffer costs half an int8 one, not the same (the old table
said 1 byte/elem for s4 and overstated int4 traffic ~2x).

TPU normalization (documented in DESIGN.md §5): the CPU backend promotes
bf16 math to f32 and materializes int4 nibble-unpacking as full-width
integer buffers; a TPU module contains neither.  Rules:

  * pure dtype-convert instructions/fusions cost 0 bytes;
  * operand reads resolve through convert/bitcast/copy chains and are
    charged at the NARROWEST width along the chain;
  * slice+convert fusions cost 0 bytes; consumers charge the slice read;
  * integer unpack fusions (slices + shifts/bitwise ops, no arithmetic —
    the pack_int4 nibble-decode) cost 0 bytes; consumers charge the
    PACKED slice read resolved through the fusion body.

Everything is per device.  ``compiled.cost_analysis()`` numbers are kept
in the roofline report as a cross-check column.

Roofline (TPU v5e targets; container is CPU-only so terms are derived):
  compute term    = FLOPs / 197e12            per chip
  memory term     = HBM bytes / 819e9         per chip
  collective term = collective bytes / 50e9   per ICI link
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict

PEAK_FLOPS = 197e12        # bf16 FLOP/s per v5e chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    # *-done ops alias the corresponding -start buffers
    "all-gather-done", "all-reduce-done", "collective-permute-done",
}

# Bits per element. Sub-byte dtypes are the whole point: s4/u4 pack two
# elements per byte, pred/u1/s1 one per bit in packed layouts.
DTYPE_BITS = {
    "pred": 8, "s8": 8, "u8": 8, "s16": 16, "u16": 16, "s32": 32,
    "u32": 32, "s64": 64, "u64": 64, "f16": 16, "bf16": 16, "f32": 32,
    "f64": 64, "c64": 64, "c128": 128, "s4": 4, "u4": 4,
    "f8e4m3fn": 8, "f8e5m2": 8, "u1": 1, "s1": 1,
    # remaining fp8 spellings XLA emits; keep prefixes ("f8e4m3") AFTER the
    # longer variants — _SHAPE_RE alternation tries keys in insertion order
    "f8e4m3b11fnuz": 8, "f8e4m3fnuz": 8, "f8e5m2fnuz": 8, "f8e4m3": 8,
}

_SHAPE_RE = re.compile(r"(" + "|".join(DTYPE_BITS) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?\s*?)\s*([a-z][a-z0-9\-]*)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_ALIAS_RE = re.compile(
    r"\{([0-9,\s]*)\}:\s*\((\d+),\s*\{([0-9,\s]*)\}(?:,\s*(may-alias|must-alias))?\)"
)


def shape_bytes(s: str) -> float:
    """Total bytes of every shape token in ``s`` (tuples sum), bits-exact
    for sub-byte dtypes (``ceil(numel * bits / 8)`` per token)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += (n * DTYPE_BITS[dt] + 7) // 8
    return total


def shape_numel(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def dims_key(shape: str) -> str:
    """Dims signature ignoring dtype/layout: CPU-backend f32<->bf16
    promotion around dots must not defeat in-place alias detection
    (on TPU those converts don't exist)."""
    m = _SHAPE_RE.search(shape)
    return m.group(2) if m else shape.strip()


def shape_dtype(shape: str) -> str:
    m = _SHAPE_RE.search(shape)
    return m.group(1) if m else ""


# Back-compat: fractional bytes/elem (s4 = 0.5). Old callers indexed a
# whole-byte table; new code should use DTYPE_BITS.
_DTYPE_BYTES = {dt: bits / 8 for dt, bits in DTYPE_BITS.items()}

_shape_bytes_from_str = shape_bytes
_shape_numel = shape_numel


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    line: str
    is_root: bool = False


@dataclasses.dataclass
class HLOReport:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    bytes_by_kind: dict[str, float]
    flops_by_op: dict[str, float]
    num_collectives: dict[str, int]


def parse_module(hlo_text: str):
    """-> (comps: name->list[Instr], entry_name, instr_table name->Instr)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    current = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if "->" in line and line.endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, shape, op = im.group(1), im.group(2), im.group(3)
        # operands: %refs inside the first paren group
        paren = line.find(op + "(") + len(op)
        depth, j = 0, paren
        end = len(line)
        for j in range(paren, len(line)):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        operands = _OPERAND_RE.findall(line[paren:end])
        comps[current].append(
            Instr(name, shape, op, operands, line, is_root="ROOT" in line.split("=")[0])
        )
    table = {i.name: i for instrs in comps.values() for i in instrs}
    return comps, entry, table


def parse_input_output_aliases(hlo_text: str) -> list[tuple[tuple, int, tuple, str]]:
    """Parse the module-header ``input_output_alias={ {out}: (param, {idx},
    kind) }`` donation/aliasing map from optimized HLO text.

    -> [(output_index_tuple, param_number, param_index_tuple, kind)].
    Empty list when the module declares no aliasing (nothing donated)."""
    for line in hlo_text.splitlines():
        if "input_output_alias=" not in line:
            continue
        seg = line.split("input_output_alias=", 1)[1]
        depth, end = 0, len(seg)
        for j, ch in enumerate(seg):
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
                if depth == 0:
                    end = j + 1
                    break
        out = []
        for m in _ALIAS_RE.finditer(seg[:end]):
            oidx = tuple(int(x) for x in m.group(1).replace(" ", "").split(",") if x)
            pidx = tuple(int(x) for x in m.group(3).replace(" ", "").split(",") if x)
            out.append((oidx, int(m.group(2)), pidx, m.group(4) or "may-alias"))
        return out
    return []


def entry_param_shapes(comps: dict, entry: str | None) -> dict[int, str]:
    """Param number -> shape string, from the entry computation's
    ``parameter(N)`` instructions."""
    out: dict[int, str] = {}
    for i in comps.get(entry, []):
        if i.op != "parameter":
            continue
        m = _PARAM_IDX_RE.search(i.line)
        if m:
            out[int(m.group(1))] = i.shape
    return out


def _dot_flops(instr: Instr, table) -> float:
    """2 * numel(output) * prod(contraction dims of lhs)."""
    out_n = shape_numel(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if not m or not instr.operands:
        return 2.0 * out_n  # degenerate
    lhs = table.get(instr.operands[0])
    if lhs is None:
        return 2.0 * out_n
    lm = _SHAPE_RE.search(lhs.shape)
    if not lm:
        return 2.0 * out_n
    dims = [int(d) for d in lm.group(2).split(",")] if lm.group(2) else []
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            k *= dims[idx]
    return 2.0 * out_n * k


_XPARENT_OPS = {"convert", "bitcast", "copy"}

_SLICE_CONVERT_BODY = {"parameter", "constant", "dynamic-slice", "slice",
                       "convert", "bitcast", "copy", "transpose"}

# pack_int4 nibble-decode as XLA CPU lowers it: slice the packed s8 buffer,
# shift-left + shift-right-arithmetic (or logical + mask) each nibble out,
# interleave with concatenate/broadcast. Critically NO multiply/add/subtract:
# a fusion doing float dequant arithmetic must never be normalized away.
_UNPACK_BODY = _SLICE_CONVERT_BODY | {
    "broadcast", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "and", "or", "xor", "concatenate",
    "reshape", "pad", "bitcast-convert",
}

_INT_DTYPES = {"s4", "u4", "s8", "u8", "s16", "u16", "s32", "u32",
               "s64", "u64", "u1", "s1", "pred"}

_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


class Module:
    """Parsed HLO module with the traffic-model predicates as methods, so
    ``analyze`` (roofline) and ``analysis.xray`` (contract checkers) share
    one implementation."""

    def __init__(self, hlo_text: str):
        self.text = hlo_text
        self.comps, self.entry, self.table = parse_module(hlo_text)
        if self.entry is None:
            for cand in ("main", "main.0"):
                if cand in self.comps:
                    self.entry = cand
            if self.entry is None and self.comps:
                self.entry = next(iter(self.comps))

    # -- structure ---------------------------------------------------------

    def aliases(self):
        return parse_input_output_aliases(self.text)

    def param_shapes(self) -> dict[int, str]:
        return entry_param_shapes(self.comps, self.entry)

    def trip_count(self, cond: str) -> int:
        best = 1
        for i in self.comps.get(cond, ()):  # largest int constant in the cond
            for c in _CONST_INT_RE.findall(i.line):
                best = max(best, int(c))
        return best

    def while_trip_counts(self) -> list[int]:
        """Trip count of every while loop reachable from entry."""
        out = []
        for instrs in self.comps.values():
            for i in instrs:
                if i.op != "while":
                    continue
                c = _COND_RE.search(i.line)
                out.append(self.trip_count(c.group(1)) if c else 1)
        return out

    def multiplicity(self) -> tuple[dict[str, float], dict[str, bool]]:
        """Computation name -> execution count (while trip counts expanded),
        plus a fusion_only map (True -> count flops but not bytes)."""
        mult: dict[str, float] = defaultdict(float)
        fusion_only: dict[str, bool] = {}

        def visit(name: str, m: float, in_fusion: bool, depth=0):
            if depth > 64 or name not in self.comps:
                return
            mult[name] += m
            if name in fusion_only:
                fusion_only[name] = fusion_only[name] and in_fusion
            else:
                fusion_only[name] = in_fusion
            for i in self.comps[name]:
                if i.op == "while":
                    c = _COND_RE.search(i.line)
                    b = _BODY_RE.search(i.line)
                    if b:
                        t = self.trip_count(c.group(1)) if c else 1
                        visit(b.group(1), m * t, in_fusion, depth + 1)
                        if c:
                            visit(c.group(1), m * t, True, depth + 1)  # cond: flops-only
                elif i.op in ("fusion", "call", "conditional", "custom-call",
                              "map", "reduce", "sort", "scatter"):
                    for cm in _CALLS_RE.finditer(i.line):
                        visit(cm.group(1), m, True, depth + 1)

        visit(self.entry, 1.0, False)
        return mult, fusion_only

    def fusion_body(self, i: Instr) -> list[Instr]:
        cm = _CALLS_RE.search(i.line)
        return self.comps.get(cm.group(1), []) if cm else []

    def fusion_root_op(self, i: Instr) -> str:
        """Root op, chasing through trailing converts/bitcasts (the CPU
        backend wraps DUS roots in dtype converts)."""
        body = self.fusion_body(i)
        root = next((s for s in body if s.is_root), None)
        by_name = {s.name: s for s in body}
        hops = 0
        while root is not None and root.op in ("convert", "bitcast") and hops < 4:
            nxt = by_name.get(root.operands[0]) if root.operands else None
            root = nxt
            hops += 1
        return root.op if root else ""

    # -- TPU-normalization predicates (DESIGN.md §5) -----------------------

    def is_pure_convert_fusion(self, i: Instr) -> bool:
        # copy inside a convert fusion is layout assignment of the same
        # logical convert; on TPU none of this chain exists (native bf16/int8
        # operands feed the MXU directly)
        body = self.fusion_body(i)
        if not body:
            return False
        return all(s.op in ("parameter", "convert", "bitcast", "constant", "copy")
                   for s in body)

    def is_slice_convert_fusion(self, i: Instr) -> bool:
        """Fusion that only selects a slice of a buffer and changes its
        dtype/layout (cache-layer pick + f32 promotion, int8 weight widening,
        weight transposes for CPU gemms). On TPU the consumer reads the
        source slice directly: charge nothing here; consumers charge the
        read at the narrowest width via effective_operand_bytes."""
        body = self.fusion_body(i)
        if not body:
            return False
        return all(s.op in _SLICE_CONVERT_BODY for s in body)

    def is_unpack_fusion(self, i: Instr) -> bool:
        """Integer-typed fusion whose body is only slicing, shifting,
        masking and interleaving — the packed-int4 nibble decode.  The CPU
        backend materializes it as a full-width (s8/s32) weight-shaped
        buffer; on TPU the decode fuses into the consuming dot, which reads
        the PACKED buffer.  No multiply/add allowed in the body: float
        dequant arithmetic is real work and must never be normalized."""
        if shape_dtype(i.shape) not in _INT_DTYPES:
            return False
        body = self.fusion_body(i)
        if not body:
            return False
        return all(s.op in _UNPACK_BODY for s in body)

    def min_chain_width_bits(self, i: Instr) -> int:
        """Smallest dtype width (bits) appearing in a slice/convert fusion
        body."""
        widths = [
            DTYPE_BITS[m.group(1)]
            for s in self.fusion_body(i)
            for m in [_SHAPE_RE.search(s.shape)]
            if m
        ]
        m = _SHAPE_RE.search(i.shape)
        if m:
            widths.append(DTYPE_BITS[m.group(1)])
        return min(widths) if widths else 32

    # -- traffic model -----------------------------------------------------

    def effective_operand_bytes(self, name: str, depth: int = 0) -> float:
        src = self.table.get(name)
        if src is None:
            return 0.0
        b = shape_bytes(src.shape)
        if src.op == "fusion" and self.is_slice_convert_fusion(src) and not \
                self.is_pure_convert_fusion(src):
            n = shape_numel(src.shape)
            return (n * self.min_chain_width_bits(src) + 7) // 8
        if src.op == "fusion" and self.is_unpack_fusion(src):
            # read resolves to the packed slice the body actually loads
            return min(b, self.fusion_read_bytes(src))
        if depth < 4 and src.operands:
            if src.op in _XPARENT_OPS or (
                src.op == "fusion" and self.is_pure_convert_fusion(src)
            ):
                inner = self.effective_operand_bytes(src.operands[0], depth + 1)
                if inner:
                    b = min(b, inner)
        return b

    def operand_bytes(self, i: Instr, skip_dims: set[str] | None = None) -> float:
        tot = 0.0
        for o in i.operands:
            src = self.table.get(o)
            if src is None:
                continue
            if skip_dims is not None and dims_key(src.shape) in skip_dims:
                continue
            tot += self.effective_operand_bytes(o)
        return tot

    def fusion_read_bytes(self, i: Instr, skip_dims: set[str] | None = None) -> float:
        """Resolve reads through the fusion body: a fused operand consumed
        only by (dynamic-)slice/gather is read at the slice size (cache
        layer selection / embedding rows), not the full buffer."""
        body = self.fusion_body(i)
        if not body:
            return self.operand_bytes(i, skip_dims)
        params: dict[int, str] = {}
        for sub in body:
            if sub.op == "parameter":
                pm = _PARAM_IDX_RE.search(sub.line)
                if pm:
                    params[int(pm.group(1))] = sub.name
        total = 0.0
        for idx, oname in enumerate(i.operands):
            src = self.table.get(oname)
            if src is None:
                continue
            if skip_dims is not None and dims_key(src.shape) in skip_dims:
                continue
            full = self.effective_operand_bytes(oname)
            pname = params.get(idx)
            if pname is None:
                total += full
                continue
            consumers = [s for s in body if pname in s.operands]
            if consumers and all(c.op in _SLICE_OPS for c in consumers):
                total += min(full, sum(shape_bytes(c.shape) for c in consumers))
            else:
                total += full
        return total

    def instr_hbm_bytes(self, i: Instr) -> float:
        """Read+write traffic model with in-place / sparse-access semantics:
        dynamic-update-slice writes only the updated slice (the cache-append
        pattern of every decode step); slicing/gather reads only what it
        produces; fusion reads resolve through the body."""
        out_b = shape_bytes(i.shape)
        is_fusion = i.op == "fusion"
        if i.op == "convert" or (is_fusion and self.is_pure_convert_fusion(i)):
            return 0.0          # TPU normalization: no CPU f32-promotion
        if is_fusion and self.is_slice_convert_fusion(i):
            return 0.0          # consumers charge the slice read (see above)
        if is_fusion and self.is_unpack_fusion(i):
            return 0.0          # consumers charge the packed slice read
        root = self.fusion_root_op(i) if is_fusion else ""
        if i.op == "dynamic-update-slice" or (is_fusion and root == "dynamic-update-slice"):
            # in-place: read+write the update-sized data only; the aliased
            # (same-dims) destination operand is skipped
            small = self.fusion_read_bytes(i, skip_dims={dims_key(i.shape)}) if is_fusion \
                else self.operand_bytes(i, skip_dims={dims_key(i.shape)})
            return 2.0 * small
        if is_fusion and root == "select":
            # the CPU backend lowers strided dynamic-update-slice to a
            # full-buffer select(iota==pos); TPU performs an in-place DUS.
            # Pattern: exactly one operand matches the output dims+dtype and
            # every other operand is small -> charge the update only.
            shapes = [self.table[o].shape for o in i.operands if o in self.table]
            matching = [s for s in shapes if dims_key(s) == dims_key(i.shape)]
            others = [
                shape_bytes(s) for s in shapes
                if dims_key(s) != dims_key(i.shape)
            ]
            if len(matching) == 1 and all(b <= out_b / 8 for b in others):
                return 2.0 * sum(others)
        if i.op in _SLICE_OPS:
            return 2.0 * out_b
        if i.op == "scatter":
            upd = (
                shape_bytes(self.table[i.operands[2]].shape)
                if len(i.operands) >= 3 and i.operands[2] in self.table
                else out_b
            )
            return 2.0 * upd
        if is_fusion:
            return self.fusion_read_bytes(i) + out_b
        return self.operand_bytes(i) + out_b

    # -- contract-checker views (analysis.xray) ----------------------------

    def materialized_instrs(self):
        """Yield (Instr, multiplicity) for instructions whose output is an
        actual buffer under the traffic model: executed computations that
        are not fusion-only bodies, skipping bookkeeping ops."""
        mult, fusion_only = self.multiplicity()
        for name, instrs in self.comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0 or fusion_only.get(name, False):
                continue
            for i in instrs:
                if i.op in _SKIP_BYTES_OPS or i.op == "while":
                    continue
                yield i, m

    def collective_instrs(self):
        """(Instr, multiplicity, base-op) for every executed collective."""
        for i, m in self.materialized_instrs():
            base = i.op.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                yield i, m, base

    def dus_dims_keys(self) -> Counter:
        """Dims signatures written in-place (dynamic-update-slice roots and
        scatter), with multiplicity — the donation audit's evidence that a
        cache buffer is updated in place rather than rebuilt."""
        out: Counter = Counter()
        for i, m in self.materialized_instrs():
            root = self.fusion_root_op(i) if i.op == "fusion" else i.op
            if root in ("dynamic-update-slice", "scatter") or \
                    i.op in ("dynamic-update-slice", "scatter"):
                out[dims_key(i.shape)] += int(m) or 1
        return out


def analyze(hlo_text: str, *, top_k: int = 0) -> HLOReport | tuple:
    mod = Module(hlo_text)
    mult, fusion_only = mod.multiplicity()
    table = mod.table

    flops_by_op: dict[str, float] = defaultdict(float)
    bytes_by_kind: dict[str, float] = defaultdict(float)
    num_collectives: dict[str, int] = defaultdict(int)
    hbm = 0.0

    contributions: list[tuple[float, float, str, str, str]] = []
    for name, instrs in mod.comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        only_flops = fusion_only.get(name, False)
        for i in instrs:
            if i.op in ("dot", "convolution"):
                flops_by_op[i.op] += m * _dot_flops(i, table)
            if only_flops:
                continue
            base = i.op.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                b = mod.operand_bytes(i) or shape_bytes(i.shape)
                bytes_by_kind[base] += m * b
                num_collectives[base] += int(m)
                hbm += m * (b + shape_bytes(i.shape))
                if top_k:
                    contributions.append((m * b, m, base, i.name, i.shape[:60]))
            elif i.op not in _SKIP_BYTES_OPS and i.op != "while":
                b = mod.instr_hbm_bytes(i)
                hbm += m * b
                if top_k:
                    contributions.append((m * b, m, i.op, i.name, i.shape[:60]))

    report = HLOReport(
        flops=sum(flops_by_op.values()),
        hbm_bytes=hbm,
        collective_bytes=sum(bytes_by_kind.values()),
        bytes_by_kind=dict(bytes_by_kind),
        flops_by_op=dict(flops_by_op),
        num_collectives=dict(num_collectives),
    )
    if top_k:
        contributions.sort(reverse=True)
        return report, contributions[:top_k]
    return report


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    flops: float               # per device
    hbm_bytes: float           # per device
    collective_bytes: float    # per device
    chips: int
    model_flops: float = 0.0   # 6*N*D analytic (global)
    xla_flops: float = 0.0     # cost_analysis cross-check (per device, no loop mult)
    xla_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (global): remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """model FLOPs / (chips * peak * step_s): roofline-fraction score."""
        denom = self.chips * PEAK_FLOPS * self.step_s
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "xla_flops_per_device": self.xla_flops,
            "xla_bytes_per_device": self.xla_bytes,
        }


def roofline_from_compiled(compiled, chips: int, model_flops: float = 0.0) -> tuple[Roofline, HLOReport]:
    rep = analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = ca or {}
    rl = Roofline(
        flops=rep.flops,
        hbm_bytes=rep.hbm_bytes,
        collective_bytes=rep.collective_bytes,
        chips=chips,
        model_flops=model_flops,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
    )
    return rl, rep
