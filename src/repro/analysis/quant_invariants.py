"""quant-invariants checker: the format registry and pack/shard geometry.

The paper's compression (4.4 GB -> 1.1 GB) and the PR 3 format registry
both live or die on arithmetic that nothing in the type system states:
``bits * pack`` must fill ``pack_storage`` storage elements exactly (int4:
4x2=8x1, int3: 3x8=8x3), ``qmax`` must be the symmetric range of ``bits``
for integer grids (float grids record max-finite magnitude instead),
packed formats must ship pack/unpack hooks and
a GQMV kernel hook, and — the invariant `dist/sharding.py` only enforces at
RUNTIME via ``validate_quant_partition`` — no tensor-parallel shard
boundary may fall inside a pack group, or one storage byte would hold
elements of two shards.

This is a **project** checker: it imports the live registries (quant
formats, arch configs) and validates the objects, not their source text.
Fixture tests inject synthetic formats/configs through the constructor.

Straddle check, statically: for every arch config, every quantizable dim
(d_model, q/kv projections, d_ff, vocab_padded, expert/MLA dims) and every
tp degree we serve at, the per-shard contraction length must stay a whole
number of storage elements for every packed format, and the per-leaf group
size ``largest_pow2_group`` would pick must be a multiple of ``pack``.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from repro.analysis.engine import BaseChecker, Finding

TP_DEGREES = (1, 2, 4, 8)
REGISTRY_ANCHOR = "src/repro/core/quant.py"
CONFIG_ANCHOR = "src/repro/configs"


def _config_dims(cfg) -> dict[str, int]:
    """Named quantizable contraction/output dims of one arch config."""
    dims = {
        "d_model": cfg.d_model,
        "q_dim": cfg.q_dim,
        "kv_dim": cfg.kv_dim,
        "d_ff": cfg.d_ff,
        "vocab_padded": cfg.vocab_padded,
    }
    if cfg.moe:
        dims["moe.d_expert"] = cfg.moe.d_expert
    if cfg.mla:
        dims["mla.kv_lora_rank"] = cfg.mla.kv_lora_rank
        if cfg.mla.q_lora_rank:
            dims["mla.q_lora_rank"] = cfg.mla.q_lora_rank
    if cfg.ssm:
        dims["ssm.d_inner"] = cfg.ssm.expand * cfg.d_model
    return dims


class QuantInvariantsChecker(BaseChecker):
    id = "quant-invariants"
    description = ("QuantFormat entries internally consistent; no tp shard "
                   "boundary can straddle a pack group on any arch config")

    def __init__(self, formats=None, configs=None, kernel_hooks=None,
                 tp_degrees: Sequence[int] = TP_DEGREES):
        """``formats``: {name: QuantFormat}-like mapping; ``configs``:
        iterable of ModelConfig; ``kernel_hooks``: set of valid kernel hook
        names. Defaults (None) load the live repo registries."""
        self._formats = formats
        self._configs = configs
        self._kernel_hooks = kernel_hooks
        self.tp_degrees = tuple(tp_degrees)

    # -- lazy registry access (fixtures inject, prod imports) ---------------
    def _load(self):
        import numpy as np

        if self._formats is None:
            from repro.core import quant
            self._formats = dict(quant._FORMATS)
        if self._kernel_hooks is None:
            from repro.kernels.ops import KERNEL_HOOKS
            self._kernel_hooks = set(KERNEL_HOOKS)
        if self._configs is None:
            from repro.models.registry import ARCH_IDS, load_config
            self._configs = [load_config(a) for a in ARCH_IDS]
        self._np = np

    def check_project(self, root: str) -> Iterable[Finding]:
        self._load()
        yield from self._check_formats()
        yield from self._check_straddle()

    # -- per-format internal consistency ------------------------------------
    def _check_formats(self) -> Iterable[Finding]:
        def err(msg):
            return Finding(self.id, REGISTRY_ANCHOR, 1, msg)

        for name, fmt in sorted(self._formats.items()):
            tag = f"format {name!r}:"
            storage_bits = 8 * self._np.dtype(fmt.storage_dtype).itemsize
            if fmt.pack < 1 or fmt.pack & (fmt.pack - 1):
                yield err(f"{tag} pack factor {fmt.pack} must be a power of "
                          "two (group sizes are powers of two; any other "
                          "pack cannot tile a group)")
                continue
            pack_storage = getattr(fmt, "pack_storage", 1)
            if fmt.bits * fmt.pack != storage_bits * pack_storage:
                yield err(f"{tag} bits({fmt.bits}) x pack({fmt.pack}) = "
                          f"{fmt.bits * fmt.pack} does not fill "
                          f"pack_storage({pack_storage}) x {storage_bits}-bit "
                          "storage elements — packed bytes would carry dead "
                          "or truncated bits")
            if getattr(fmt, "kind", "int") == "int" \
                    and fmt.qmax != 2 ** (fmt.bits - 1) - 1:
                yield err(f"{tag} qmax {fmt.qmax} != 2^{fmt.bits - 1}-1 = "
                          f"{2 ** (fmt.bits - 1) - 1} — the symmetric range "
                          "of Eq. 1 for this bit width")
            if fmt.pack > 1 and (fmt.pack_fn is None or fmt.unpack_fn is None):
                yield err(f"{tag} pack > 1 requires pack_fn/unpack_fn "
                          "(checkpoint resharding round-trips through "
                          "logical values)")
            if fmt.kernel not in self._kernel_hooks:
                yield err(f"{tag} kernel hook {fmt.kernel!r} not in "
                          f"kernels/ops.py KERNEL_HOOKS "
                          f"{sorted(self._kernel_hooks)} — qlinear would "
                          "fall back to dequantize-then-matmul silently")

    # -- pack-group vs shard geometry ---------------------------------------
    def _check_straddle(self) -> Iterable[Finding]:
        from repro.core.quant import largest_pow2_group

        packed = [(n, f) for n, f in sorted(self._formats.items()) if f.pack > 1]
        if not packed:
            return
        for cfg in self._configs:
            gs_pref = cfg.group_size
            if gs_pref & (gs_pref - 1):
                yield Finding(
                    self.id, CONFIG_ANCHOR, 1,
                    f"{cfg.arch_id}: group_size {gs_pref} is not a power of "
                    "two — the per-leaf GS descent assumes pow2")
                continue
            for dim_name, n in _config_dims(cfg).items():
                for tp in self.tp_degrees:
                    if n % tp:
                        continue  # this (dim, tp) is not shardable; skip
                    shard = n // tp
                    gs = largest_pow2_group(shard, gs_pref, min_gs=16)
                    if gs is None:
                        # no pow2 group >= 16 divides this shard: the PTQ
                        # driver leaves such leaves unquantized (policy.py
                        # leaf_group_size -> None), so there is no packed
                        # storage to straddle at this geometry
                        continue
                    for fname, fmt in packed:
                        if shard % fmt.pack:
                            yield Finding(
                                self.id, CONFIG_ANCHOR, 1,
                                f"{cfg.arch_id}: {dim_name}={n} at tp={tp} "
                                f"gives shard {shard}, not a multiple of "
                                f"{fname}'s pack {fmt.pack} — a storage "
                                "element would straddle the shard boundary")
                        elif gs is not None and gs % fmt.pack:
                            yield Finding(
                                self.id, CONFIG_ANCHOR, 1,
                                f"{cfg.arch_id}: {dim_name}={n} at tp={tp} "
                                f"picks GS={gs}, not a multiple of "
                                f"{fname}'s pack {fmt.pack} — a pack group "
                                "would straddle a quantization group")
