"""repro-lint: repo-specific static analysis (DESIGN.md §11).

``python -m repro.analysis`` runs every registered checker over the tree
and exits non-zero on findings; deliberate exceptions live in
``.repro-lint-allow``. See ``engine.py`` for the Checker protocol and
``__main__.py`` for the CLI.
"""

from __future__ import annotations

from repro.analysis.engine import (
    Allowlist,
    BaseChecker,
    Checker,
    Finding,
    run_analysis,
)
from repro.analysis.adapter_lifecycle import AdapterLifecycleChecker
from repro.analysis.host_sync import HostSyncChecker
from repro.analysis.pallas_contract import PallasContractChecker
from repro.analysis.quant_invariants import QuantInvariantsChecker
from repro.analysis.recompile import (
    JitTraceCounter,
    RecompileChecker,
    count_jit_traces,
)
from repro.analysis.registry_coverage import RegistryCoverageChecker
from repro.analysis.shadow_coverage import ShadowCoverageChecker
from repro.analysis.xray import (
    XrayBytesChecker,
    XrayCollectiveChecker,
    XrayDequantChecker,
    XrayDonationChecker,
)

__all__ = [
    "Allowlist",
    "BaseChecker",
    "Checker",
    "Finding",
    "run_analysis",
    "HostSyncChecker",
    "RecompileChecker",
    "PallasContractChecker",
    "QuantInvariantsChecker",
    "RegistryCoverageChecker",
    "AdapterLifecycleChecker",
    "ShadowCoverageChecker",
    "XrayDonationChecker",
    "XrayDequantChecker",
    "XrayBytesChecker",
    "XrayCollectiveChecker",
    "JitTraceCounter",
    "count_jit_traces",
    "default_checkers",
]


def default_checkers() -> list:
    """Fresh instances of the eleven repo checkers, in stable order: the
    seven source/runtime checkers, then the four compiled-program xray
    contracts (DESIGN.md §14 — these compile the serving catalog once per
    process and share it)."""
    return [
        HostSyncChecker(),
        RecompileChecker(),
        PallasContractChecker(),
        QuantInvariantsChecker(),
        RegistryCoverageChecker(),
        AdapterLifecycleChecker(),
        ShadowCoverageChecker(),
        XrayDonationChecker(),
        XrayDequantChecker(),
        XrayBytesChecker(),
        XrayCollectiveChecker(),
    ]
