"""pallas-contract checker: BlockSpec/grid invariants for every pallas_call.

The GQMV/attention kernels replay the paper's 3-stage pipeline with Pallas
grid pipelining; the contract that keeps the pipeline stall-free (and
CORRECT) is structural and checkable before any kernel runs:

- **index_map arity == grid rank (+ scalar-prefetch args)**: a mismatched
  lambda fails deep inside Mosaic with a shape error far from the bug.
- **block sizes divide their dims, or the tail is provably handled**: our
  grids are built as ``dim // block``; a caller-supplied block that does
  not divide the dim silently TRUNCATES the grid (the tail rows are never
  computed). The checker demands evidence of divisibility per divisor: the
  value comes from ``_pick_block``/a ``*check*`` validator, or a
  ``while dim % blk: blk //= 2`` descent, or an explicit raise/assert on
  ``%``.
- **out_specs/out_shape cardinality agree** when both are lists.
- **estimated VMEM footprint under budget**: sum of block-spec and scratch
  bytes (double-buffered), resolving block names through local assignments
  and module constants (unknown names assume ``ASSUMED_DIM``) — a coarse
  gate that catches order-of-magnitude mistakes, not a cycle model.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import BaseChecker, Finding, dotted_name

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # ~16 MB/core (pallas guide)
ASSUMED_DIM = 128                      # fallback for unresolvable dims
ASSUMED_DTYPE_BYTES = 4


def _int_constants(tree: ast.AST) -> dict[str, int]:
    out: dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Constant):
            if isinstance(node.value.value, int):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = node.value.value
    return out


class _FnInfo:
    """Per-function context: local assignments, nested defs, guard names."""

    def __init__(self, fn: ast.FunctionDef):
        self.fn = fn
        self.assigns: dict[str, ast.expr] = {}
        self.defs: dict[str, ast.FunctionDef] = {}
        self.guarded: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id not in self.assigns:
                        self.assigns[t.id] = node.value
            elif isinstance(node, ast.FunctionDef) and node is not fn:
                self.defs.setdefault(node.name, node)
        self._collect_guards(fn)

    def _collect_guards(self, fn):
        def mod_operands(expr):
            for n in ast.walk(expr):
                if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
                    for side in (n.left, n.right):
                        if isinstance(side, ast.Name):
                            yield side.id

        for node in ast.walk(fn):
            if isinstance(node, ast.While):
                self.guarded.update(mod_operands(node.test))
            elif isinstance(node, ast.Assert):
                self.guarded.update(mod_operands(node.test))
            elif isinstance(node, ast.If) and any(
                    isinstance(s, ast.Raise) for s in node.body):
                self.guarded.update(mod_operands(node.test))
            elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                callee = dotted_name(node.value.func)
                if "pick_block" in callee or "check" in callee:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            self.guarded.add(t.id)
            elif isinstance(node, ast.Call):
                callee = dotted_name(node.func)
                if "check" in callee:
                    for a in node.args:
                        if isinstance(a, ast.Name):
                            self.guarded.add(a.id)


def _resolve(expr: ast.expr, info: _FnInfo, consts: dict[str, int],
             depth: int = 0) -> int | None:
    """Best-effort integer evaluation of a block/shape expression."""
    if depth > 8 or expr is None:
        return None
    if isinstance(expr, ast.Constant):
        return expr.value if isinstance(expr.value, int) else None
    if isinstance(expr, ast.Name):
        if expr.id in consts:
            return consts[expr.id]
        if expr.id in info.assigns:
            return _resolve(info.assigns[expr.id], info, consts, depth + 1)
        return None
    if isinstance(expr, ast.BinOp):
        ln = _resolve(expr.left, info, consts, depth + 1)
        r = _resolve(expr.right, info, consts, depth + 1)
        if ln is None or r is None:
            return None
        try:
            if isinstance(expr.op, ast.FloorDiv):
                return ln // r if r else None
            if isinstance(expr.op, ast.Mult):
                return ln * r
            if isinstance(expr.op, ast.Add):
                return ln + r
            if isinstance(expr.op, ast.Sub):
                return ln - r
        except ZeroDivisionError:
            return None
        return None
    if isinstance(expr, ast.BoolOp) and isinstance(expr.op, ast.Or):
        # `block_m or _pick_block(m, DEFAULT_BM)` — take any resolvable arm
        for v in expr.values:
            got = _resolve(v, info, consts, depth + 1)
            if got is not None:
                return got
        return None
    if isinstance(expr, ast.Call):
        callee = dotted_name(expr.func)
        if "pick_block" in callee and len(expr.args) >= 2:
            return _resolve(expr.args[1], info, consts, depth + 1)
        if callee in ("min", "max") and expr.args:
            vals = [_resolve(a, info, consts, depth + 1) for a in expr.args]
            vals = [v for v in vals if v is not None]
            if vals:
                return min(vals) if callee == "min" else max(vals)
    return None


def _blockspec_parts(call: ast.Call):
    """(shape_tuple_expr, index_map_expr) of a pl.BlockSpec(...) call."""
    shape = call.args[0] if call.args else None
    index_map = call.args[1] if len(call.args) > 1 else None
    for kw in call.keywords:
        if kw.arg == "index_map":
            index_map = kw.value
        elif kw.arg == "block_shape":
            shape = kw.value
    return shape, index_map


def _arity(index_map: ast.expr, info: _FnInfo) -> int | None:
    if isinstance(index_map, ast.Lambda):
        a = index_map.args
        return len(a.posonlyargs) + len(a.args)
    if isinstance(index_map, ast.Name):
        fd = info.defs.get(index_map.id)
        if fd is not None:
            return len(fd.args.posonlyargs) + len(fd.args.args)
        target = info.assigns.get(index_map.id)
        if target is not None and target is not index_map:
            return _arity(target, info)
    return None


def _imap_signature(index_map: ast.expr, info: _FnInfo):
    """(param names, body AST) of an index_map — a Lambda, or a Name bound
    to a lambda/def. None when unresolvable (e.g. built by a factory)."""
    if isinstance(index_map, ast.Lambda):
        a = index_map.args
        return [p.arg for p in (*a.posonlyargs, *a.args)], index_map.body
    if isinstance(index_map, ast.Name):
        fd = info.defs.get(index_map.id)
        if fd is not None:
            a = fd.args
            return [p.arg for p in (*a.posonlyargs, *a.args)], fd
        target = info.assigns.get(index_map.id)
        if target is not None and target is not index_map:
            return _imap_signature(target, info)
    return None


def _spec_list(expr: ast.expr, info: _FnInfo) -> list[ast.Call] | None:
    """Resolve in_specs/out_specs to the list of BlockSpec calls (or a
    single spec as a one-element list). None when unresolvable."""
    if isinstance(expr, ast.Name):
        expr = info.assigns.get(expr.id, expr)
    if isinstance(expr, (ast.List, ast.Tuple)):
        out = []
        for e in expr.elts:
            if isinstance(e, ast.Call) and dotted_name(e.func).endswith("BlockSpec"):
                out.append(e)
            else:
                return None
        return out
    if isinstance(expr, ast.Call) and dotted_name(expr.func).endswith("BlockSpec"):
        return [expr]
    return None


class PallasContractChecker(BaseChecker):
    id = "pallas-contract"
    description = ("pallas_call BlockSpec/grid contracts: index_map arity, "
                   "divisible blocks, out_specs/out_shape cardinality, "
                   "VMEM budget")

    def __init__(self, vmem_budget: int = VMEM_BUDGET_BYTES):
        self.vmem_budget = vmem_budget

    def check_file(self, path, tree, source) -> Iterable[Finding]:
        if "pallas_call" not in source:
            return
        consts = _int_constants(tree)
        for fn in ast.walk(tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            calls = [n for n in ast.walk(fn)
                     if isinstance(n, ast.Call)
                     and dotted_name(n.func).endswith("pallas_call")]
            if not calls:
                continue
            info = _FnInfo(fn)
            for call in calls:
                yield from self._check_call(path, fn, call, info, consts)

    # -- one pallas_call ----------------------------------------------------
    def _check_call(self, path, fn, call, info, consts) -> Iterable[Finding]:
        kws = {kw.arg: kw.value for kw in call.keywords}
        grid_expr = kws.get("grid")
        n_prefetch = 0
        in_specs, out_specs = kws.get("in_specs"), kws.get("out_specs")
        scratch = kws.get("scratch_shapes")

        gs_expr = kws.get("grid_spec")
        if gs_expr is not None:
            if isinstance(gs_expr, ast.Name):
                gs_expr = info.assigns.get(gs_expr.id)
            if isinstance(gs_expr, ast.Call):
                gkws = {kw.arg: kw.value for kw in gs_expr.keywords}
                grid_expr = gkws.get("grid", grid_expr)
                in_specs = gkws.get("in_specs", in_specs)
                out_specs = gkws.get("out_specs", out_specs)
                scratch = gkws.get("scratch_shapes", scratch)
                np_expr = gkws.get("num_scalar_prefetch")
                if isinstance(np_expr, ast.Constant) and isinstance(np_expr.value, int):
                    n_prefetch = np_expr.value

        if isinstance(grid_expr, ast.Name):
            grid_expr = info.assigns.get(grid_expr.id, grid_expr)
        grid_elts: list[ast.expr] | None = None
        if isinstance(grid_expr, (ast.Tuple, ast.List)):
            grid_elts = list(grid_expr.elts)
        elif grid_expr is not None and not isinstance(grid_expr, ast.Name):
            grid_elts = [grid_expr]       # grid=8 scalar form

        # 1. index_map arity -------------------------------------------------
        specs = (_spec_list(in_specs, info) or []) + (_spec_list(out_specs, info) or [])
        if grid_elts is not None:
            want = len(grid_elts) + n_prefetch
            for spec in specs:
                _, imap = _blockspec_parts(spec)
                if imap is None:
                    continue
                got = _arity(imap, info)
                if got is not None and got != want:
                    yield Finding(
                        self.id, path, spec.lineno,
                        f"BlockSpec index_map takes {got} args but the grid "
                        f"rank is {len(grid_elts)}"
                        + (f" + {n_prefetch} scalar-prefetch refs" if n_prefetch else "")
                        + f" = {want} (in `{fn.name}`)", col=spec.col_offset)

        # 1b. declared scalar prefetch must be USED by some index_map --------
        # The prefetch args ride LAST in every index_map signature
        # (index_map(*grid, *prefetch_refs)). Declaring num_scalar_prefetch
        # without any index_map reading the refs means the scalar DMA is
        # dead weight — or, worse, a block-table kernel whose index maps
        # ignore the table and read the same physical blocks at every grid
        # step. Fires only when at least one index_map resolved (factories
        # that build maps dynamically stay out of reach of this rule).
        if n_prefetch > 0 and specs:
            any_resolved = any_used = False
            for spec in specs:
                _, imap = _blockspec_parts(spec)
                if imap is None:
                    continue
                sig = _imap_signature(imap, info)
                if sig is None or len(sig[0]) < n_prefetch:
                    continue
                names, body = sig
                any_resolved = True
                pref = set(names[-n_prefetch:])
                if any(isinstance(n, ast.Name) and n.id in pref
                       for n in ast.walk(body)):
                    any_used = True
                    break
            if any_resolved and not any_used:
                anchor = gs_expr if isinstance(gs_expr, ast.Call) else call
                yield Finding(
                    self.id, path, anchor.lineno,
                    f"num_scalar_prefetch={n_prefetch} declared but no "
                    "index_map reads the prefetched ref(s): the scalar DMA "
                    "is dead weight, or a block-table kernel is ignoring "
                    f"its table (in `{fn.name}`)", col=anchor.col_offset)

        # 2. divisible blocks ------------------------------------------------
        for elt in grid_elts or []:
            if isinstance(elt, ast.Name):
                elt = info.assigns.get(elt.id, elt)
            if isinstance(elt, ast.BinOp) and isinstance(elt.op, ast.FloorDiv):
                div = elt.right
                if isinstance(div, ast.Name) and div.id not in info.guarded:
                    yield Finding(
                        self.id, path, elt.lineno,
                        f"grid dim `{ast.unparse(elt)}` floor-divides by "
                        f"`{div.id}` with no divisibility guard in "
                        f"`{fn.name}`: a non-dividing block silently drops "
                        "the tail rows — validate (raise) or derive the "
                        "block via _pick_block/a % descent",
                        col=elt.col_offset)

        # 3. out_specs/out_shape cardinality ---------------------------------
        out_shape = kws.get("out_shape")
        if isinstance(out_shape, ast.Name):
            out_shape = info.assigns.get(out_shape.id)
        os_specs = _spec_list(out_specs, info)
        if (isinstance(out_shape, (ast.List, ast.Tuple)) and os_specs is not None
                and isinstance(out_specs, (ast.List, ast.Tuple))):
            if len(out_shape.elts) != len(os_specs):
                yield Finding(
                    self.id, path, call.lineno,
                    f"out_shape has {len(out_shape.elts)} entries but "
                    f"out_specs has {len(os_specs)} (in `{fn.name}`)",
                    col=call.col_offset)

        # 4. VMEM footprint estimate -----------------------------------------
        total = 0
        for spec in specs:
            shape, _ = _blockspec_parts(spec)
            total += 2 * self._shape_bytes(shape, info, consts)  # double-buffered
        if isinstance(scratch, ast.Name):
            scratch = info.assigns.get(scratch.id)
        if isinstance(scratch, (ast.List, ast.Tuple)):
            for s in scratch.elts:
                if isinstance(s, ast.Call) and s.args:
                    total += self._shape_bytes(s.args[0], info, consts)
        if total > self.vmem_budget:
            yield Finding(
                self.id, path, call.lineno,
                f"estimated VMEM footprint ~{total / 2**20:.1f} MiB exceeds "
                f"the {self.vmem_budget / 2**20:.0f} MiB budget (blocks "
                f"double-buffered, unknown dims assumed {ASSUMED_DIM}) in "
                f"`{fn.name}` — shrink the block sizes",
                severity="warning", col=call.col_offset)

    def _shape_bytes(self, shape, info, consts) -> int:
        if isinstance(shape, ast.Name):
            shape = info.assigns.get(shape.id, shape)
        if not isinstance(shape, (ast.Tuple, ast.List)):
            return 0
        n = 1
        for d in shape.elts:
            v = _resolve(d, info, consts)
            n *= v if v is not None and v > 0 else ASSUMED_DIM
        return n * ASSUMED_DTYPE_BYTES
