"""shadow-coverage checker: every cache-bearing family rides the sanitizer.

The repro-san shadow tracker (analysis/shadow.py, analysis/sanitizer.py)
only protects the families it is exercised against. Coverage is a ledger,
same shape as registry-coverage's capability matrix:

1. Every registry arch with ``cache_kind`` of ``kv`` or ``state`` — i.e.
   every family the scheduling core serves with a cache the sanitizer can
   shadow — must appear in ``SANITIZED_ARCHS`` in ``tests/arch_matrix.py``.
   A family missing from the list runs serve-parity tests without the
   sanitizer armed, so a cache-corruption bug in its adapter path ships
   silently.

2. The list must not overstate: no unknown arch ids, no ``cache_kind ==
   "none"`` families (nothing to shadow — listing one claims coverage
   that cannot exist).

3. The sanitizer test module (default ``tests/test_sanitizer.py``) must
   exist and reference ``SANITIZED_ARCHS`` by name — the ledger is only as
   good as the test that consumes it.

Like registry-coverage this is a project checker: it imports the live
registry, so additions to ``ARCH_IDS`` are audited the moment they land,
not when someone remembers to update a hand-written list here.
"""

from __future__ import annotations

import os
from typing import Iterable

from repro.analysis.engine import BaseChecker, Finding
from repro.analysis.registry_coverage import DEFAULT_MATRIX, _matrix_lists

SANITIZED_LIST = "SANITIZED_ARCHS"
DEFAULT_TEST = "tests/test_sanitizer.py"

# cache kinds the sanitizer can shadow (serving/core.py adapters)
SHADOWABLE_KINDS = ("kv", "state")


class ShadowCoverageChecker(BaseChecker):
    id = "shadow-coverage"
    description = ("every cache_kind kv/state arch appears in "
                   f"{SANITIZED_LIST} and the sanitizer test consumes it")

    def __init__(self, archs=None, build=None,
                 matrix_path: str = DEFAULT_MATRIX,
                 test_path: str = DEFAULT_TEST):
        """``archs``/``build``: injectable registry view (default: the live
        ``ARCH_IDS`` / ``build_arch``) so fixtures can test the rules."""
        self._archs = archs
        self._build = build
        self.matrix_path = matrix_path
        self.test_path = test_path

    def check_project(self, root: str) -> Iterable[Finding]:
        if self._archs is None or self._build is None:
            from repro.models import registry
            self._archs = self._archs or list(registry.ARCH_IDS)
            self._build = self._build or registry.build_arch

        mpath = os.path.join(root, self.matrix_path)
        if not os.path.isfile(mpath):
            yield Finding(self.id, self.matrix_path, 1,
                          "test matrix module missing: sanitizer coverage "
                          "has no ledger")
            return
        lists = _matrix_lists(mpath)

        kinds = {arch: getattr(self._build(arch), "cache_kind", "none")
                 for arch in self._archs}
        shadowable = {a for a, k in kinds.items() if k in SHADOWABLE_KINDS}

        if SANITIZED_LIST not in lists:
            if shadowable:
                yield Finding(
                    self.id, self.matrix_path, 1,
                    f"matrix list {SANITIZED_LIST} missing: "
                    f"{len(shadowable)} cache-bearing arch(s) have no "
                    "sanitizer coverage ledger")
            return
        lineno, ids = lists[SANITIZED_LIST]

        for arch in sorted(shadowable):
            if arch not in ids:
                yield Finding(
                    self.id, self.matrix_path, lineno,
                    f"{arch} has cache_kind={kinds[arch]!r} but no "
                    f"{SANITIZED_LIST} entry: its adapter path never runs "
                    "under REPRO_SAN — cache corruption there ships silently")
        for aid in ids:
            if aid not in kinds:
                yield Finding(
                    self.id, self.matrix_path, lineno,
                    f"{SANITIZED_LIST} names unknown arch {aid!r}")
            elif aid not in shadowable:
                yield Finding(
                    self.id, self.matrix_path, lineno,
                    f"{SANITIZED_LIST} lists {aid} but its cache_kind is "
                    f"{kinds[aid]!r} — nothing to shadow; the ledger "
                    "overstates coverage")

        tpath = os.path.join(root, self.test_path)
        if not os.path.isfile(tpath):
            yield Finding(
                self.id, self.test_path, 1,
                f"sanitizer test module missing: {SANITIZED_LIST} is a "
                "ledger nobody reads")
            return
        with open(tpath, encoding="utf-8") as fh:
            if SANITIZED_LIST not in fh.read():
                yield Finding(
                    self.id, self.test_path, 1,
                    f"{self.test_path} never references {SANITIZED_LIST}: "
                    "the sweep does not consume the ledger, so list entries "
                    "assert nothing")
