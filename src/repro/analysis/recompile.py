"""recompile-guard: jit cache misses counted at runtime, retrace traps
caught statically.

The scheduler decode loops are built to compile ONCE per shape bucket
(``SlotScheduler.decode_chunk``, ``PagedScheduler.decode_until``; prefill
retraces only per padded bucket length). A shape-dependent retrace —
a Python int that becomes a weak type, a donated buffer rebound to a new
shape, an argument that should be static but varies — silently multiplies
decode latency by compile time. Two halves:

- :class:`JitTraceCounter` — a context manager that patches ``jax.jit`` so
  every function jitted UNDER the context counts its traces (a trace == a
  cache miss; XLA only re-invokes the Python callable when the signature
  is new). Schedulers constructed inside the context are fully counted
  because they build their jitted programs in ``__init__``. Used by the
  ``jit_trace_counter`` pytest fixture (tests/test_analysis.py).

- :class:`RecompileChecker` — static detection of the two retrace traps a
  counter only finds after the fact: ``jax.jit`` called inside a loop body
  (a fresh compile cache per iteration) and call sites that pass an
  unhashable literal (list/dict/set display) in a ``static_argnums`` /
  ``static_argnames`` position of a same-module jitted function.
"""

from __future__ import annotations

import ast
import contextlib
import functools
from collections import Counter
from typing import Iterable

import jax

from repro.analysis.engine import BaseChecker, Finding, dotted_name, is_jit_expr


class JitTraceCounter:
    """Counts traces per jitted-function name for jits created while active.

    >>> with JitTraceCounter() as jc:
    ...     sched = SlotScheduler(engine, ...)   # builds its jitted programs
    ...     sched.serve(trace_a, 8)
    ...     sched.serve(trace_b, 8)
    >>> jc.counts["decode_chunk"]
    1
    """

    def __init__(self):
        self.counts: Counter[str] = Counter()
        self._orig = None

    def __enter__(self):
        self._orig = jax.jit
        counts = self.counts

        def counting_jit(fun=None, **kw):
            if fun is None:          # @jax.jit(static_argnames=...) form
                return lambda f: counting_jit(f, **kw)
            name = getattr(fun, "__name__", repr(fun))

            @functools.wraps(fun)
            def traced(*a, **k):
                counts[name] += 1    # invoked only on a cache miss
                return fun(*a, **k)

            return self._orig(traced, **kw)

        jax.jit = counting_jit
        return self

    def __exit__(self, *exc):
        jax.jit = self._orig
        return False

    def total(self) -> int:
        return sum(self.counts.values())

    def assert_traces(self, name: str, expected: int) -> None:
        got = self.counts.get(name, 0)
        if got != expected:
            raise AssertionError(
                f"`{name}` traced {got}x, expected exactly {expected}: a "
                "retrace means a shape/static-arg varied per call "
                f"(all counts: {dict(self.counts)})")


@contextlib.contextmanager
def count_jit_traces():
    """Function-style alias: ``with count_jit_traces() as jc: ...``"""
    with JitTraceCounter() as jc:
        yield jc


# ---------------------------------------------------------------------------
# static half
# ---------------------------------------------------------------------------

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)


def _static_spec(call: ast.Call):
    """(static_positions, static_names) literals of a jax.jit call."""
    nums: list[int] = []
    names: list[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.append(n.value)
        elif kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.append(n.value)
    return nums, names


class RecompileChecker(BaseChecker):
    id = "recompile-guard"
    description = ("no jax.jit inside loop bodies; no unhashable literals "
                   "in static-arg positions of jitted call sites")

    def check_file(self, path, tree, source) -> Iterable[Finding]:
        yield from self._jit_in_loops(path, tree)
        yield from self._unhashable_statics(path, tree)

    def _jit_in_loops(self, path, tree) -> Iterable[Finding]:
        class V(ast.NodeVisitor):
            def __init__(self):
                self.hits: list[ast.AST] = []
                self._loop = 0

            def visit_For(self, node):
                self._loop += 1
                self.generic_visit(node)
                self._loop -= 1

            visit_While = visit_For

            def visit_FunctionDef(self, node):
                # decorators run at def time — in the enclosing loop context;
                # the body runs later, so its loop depth resets
                if self._loop:
                    self.hits.extend(d for d in node.decorator_list
                                     if is_jit_expr(d))
                loop, self._loop = self._loop, 0
                for stmt in node.body:
                    self.visit(stmt)
                self._loop = loop

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Lambda(self, node):
                loop, self._loop = self._loop, 0
                self.generic_visit(node)
                self._loop = loop

            def visit_Call(self, node):
                if self._loop and is_jit_expr(node):
                    self.hits.append(node)
                self.generic_visit(node)

        v = V()
        v.visit(tree)
        for node in v.hits:
            yield Finding(
                self.id, path, node.lineno,
                "jax.jit called inside a loop body: every iteration builds a "
                "fresh compile cache — hoist the jit (or cache the jitted "
                "callable) outside the loop", col=node.col_offset)

    def _unhashable_statics(self, path, tree) -> Iterable[Finding]:
        # module-level best effort: name -> (static nums, static names)
        specs: dict[str, tuple[list[int], list[str]]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and is_jit_expr(dec):
                        specs[node.name] = _static_spec(dec)
            elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                call = node.value
                if dotted_name(call.func) in ("jax.jit", "jit") and call.args:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            specs[t.id] = _static_spec(call)
        if not specs:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or not isinstance(node.func, ast.Name):
                continue
            spec = specs.get(node.func.id)
            if spec is None:
                continue
            nums, names = spec
            bad: list[ast.AST] = []
            bad += [a for i, a in enumerate(node.args)
                    if i in nums and isinstance(a, _UNHASHABLE)]
            bad += [kw.value for kw in node.keywords
                    if kw.arg in names and isinstance(kw.value, _UNHASHABLE)]
            for b in bad:
                yield Finding(
                    self.id, path, b.lineno,
                    f"unhashable literal passed in a static position of "
                    f"jitted `{node.func.id}`: static args must hash stably "
                    "or every call recompiles (TypeError at best)",
                    col=b.col_offset)
