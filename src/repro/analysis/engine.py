"""repro-lint engine: repo-specific static analysis as CI-gated checks.

The serving/kernel stack rests on invariants the hardware never forgives —
one host sync per scheduler chunk, Pallas grids that tile their dims
exactly, pack groups that never straddle a shard — and until now they were
enforced only by runtime asserts and whichever test happened to trip them.
This package promotes them to a static-analysis pass, the way
``launch/hlo_analysis.py`` does for post-SPMD cost accounting: a small
AST-walking engine, a :class:`Checker` protocol, and seven repo-specific
checkers (see ``repro.analysis.__init__``).

Two checker shapes exist:

- **file checkers** implement ``check_file(path, tree, source)`` and run on
  every scanned ``*.py`` (AST only, no imports);
- **project checkers** implement ``check_project(root)`` and run once per
  invocation — these may import repo modules (the quant registry, the model
  registry) to validate live objects against the declared contracts.

Deliberate exceptions live in an allowlist file (default
``.repro-lint-allow`` at the repo root): one finding pattern per line,

    <checker-id>  <relpath-glob[:line]>  <justification...>

Every suppression must carry a justification; unused allowlist entries are
themselves reported (severity ``warning``) so the file cannot rot.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
from typing import Iterable, Protocol, runtime_checkable

SEVERITIES = ("error", "warning")

# directories never scanned for file checks
SKIP_DIRS = {".git", "__pycache__", ".github", "analysis_fixtures",
             ".pytest_cache", "node_modules", ".venv"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect: checker id, anchor (file:line:col), severity, message."""

    checker: str
    path: str            # repo-relative posix path
    line: int
    message: str
    severity: str = "error"
    col: int = 0

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    @property
    def anchor(self) -> str:
        return f"{self.path}:{self.line}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity}[{self.checker}] {self.message}")


@runtime_checkable
class Checker(Protocol):
    """A lint pass. ``id`` is the allowlist/selection key; implement
    ``check_file`` for per-file AST checks, ``check_project`` for one-shot
    repo-level checks, or both."""

    id: str
    description: str

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> Iterable[Finding]:
        ...

    def check_project(self, root: str) -> Iterable[Finding]:
        ...


class BaseChecker:
    """No-op defaults so concrete checkers implement only one hook."""

    id = "base"
    description = ""

    def check_file(self, path: str, tree: ast.AST,
                   source: str) -> Iterable[Finding]:
        return ()

    def check_project(self, root: str) -> Iterable[Finding]:
        return ()


# ---------------------------------------------------------------------------
# allowlist
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AllowRule:
    checker: str
    pattern: str         # fnmatch over "relpath" or "relpath:line"
    reason: str
    lineno: int
    hits: int = 0

    def matches(self, f: Finding) -> bool:
        if self.checker not in ("*", f.checker):
            return False
        return (fnmatch.fnmatch(f.path, self.pattern)
                or fnmatch.fnmatch(f.anchor, self.pattern))


class Allowlist:
    """Parsed allowlist file. Lines: ``checker glob justification...``;
    ``#`` comments and blank lines ignored. A justification is mandatory —
    an exception nobody can explain is a bug with paperwork."""

    def __init__(self, rules: list[AllowRule], path: str | None = None):
        self.rules = rules
        self.path = path

    @classmethod
    def load(cls, path: str) -> "Allowlist":
        rules = []
        with open(path, encoding="utf-8") as fh:
            for i, raw in enumerate(fh, 1):
                line = raw.split("#", 1)[0].strip()
                if not line:
                    continue
                parts = line.split(None, 2)
                if len(parts) < 3:
                    raise ValueError(
                        f"{path}:{i}: allowlist entries are "
                        "'<checker> <glob> <justification>'; a justification "
                        "is required")
                rules.append(AllowRule(parts[0], parts[1], parts[2], i))
        return cls(rules, path)

    @classmethod
    def empty(cls) -> "Allowlist":
        return cls([])

    def filter(self, findings: list[Finding]):
        """-> (kept, suppressed); increments rule hit counters."""
        kept, suppressed = [], []
        for f in findings:
            rule = next((r for r in self.rules if r.matches(f)), None)
            if rule is None:
                kept.append(f)
            else:
                rule.hits += 1
                suppressed.append(f)
        return kept, suppressed

    def unused(self) -> list[AllowRule]:
        return [r for r in self.rules if r.hits == 0]


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def iter_python_files(paths: list[str], root: str) -> list[str]:
    """Expand files/directories into a sorted list of .py paths, skipping
    SKIP_DIRS (fixtures are analyzed only when named explicitly)."""
    out: list[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap):
            out.append(ap)
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = [d for d in sorted(dirnames) if d not in SKIP_DIRS]
            out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                       if f.endswith(".py"))
    return out


def run_analysis(checkers: list, paths: list[str], root: str,
                 allowlist: Allowlist | None = None):
    """Run every checker over ``paths``; -> (findings, suppressed).

    Findings are allowlist-filtered and sorted by (path, line, checker).
    A file that fails to parse is itself a finding (checker id ``parse``).
    """
    allowlist = allowlist or Allowlist.empty()
    findings: list[Finding] = []
    file_checkers = [c for c in checkers
                     if type(c).check_file is not BaseChecker.check_file]
    for fp in iter_python_files(paths, root):
        rel = os.path.relpath(fp, root).replace(os.sep, "/")
        try:
            with open(fp, encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source, filename=rel)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding("parse", rel,
                                    getattr(e, "lineno", 0) or 0, str(e)))
            continue
        for c in file_checkers:
            findings.extend(c.check_file(rel, tree, source))
    for c in checkers:
        if type(c).check_project is not BaseChecker.check_project:
            findings.extend(c.check_project(root))
    findings.sort(key=lambda f: (f.path, f.line, f.checker))
    return allowlist.filter(findings)


# ---------------------------------------------------------------------------
# shared AST helpers (used by several checkers)
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str:
    """'jax.device_get' for Attribute/Name chains; '' when not a plain
    dotted path (calls, subscripts)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def is_jit_expr(node: ast.AST) -> bool:
    """True for ``jax.jit``, ``jit``, ``partial(jax.jit, ...)``,
    ``functools.partial(jax.jit, ...)`` and ``jax.jit(...)`` call forms —
    the decorator/callable spellings that produce a traced scope."""
    name = dotted_name(node)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        if fname in ("jax.jit", "jit"):
            return True
        if fname in ("partial", "functools.partial") and node.args:
            return is_jit_expr(node.args[0])
    return False


def assigned_names(target: ast.AST) -> list[str]:
    """Flatten assignment targets (incl. tuple unpacks) into plain names."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: list[str] = []
        for elt in target.elts:
            out.extend(assigned_names(elt))
        return out
    return []
