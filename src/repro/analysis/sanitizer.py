"""repro-san: the opt-in cache-memory and numerics sanitizer (DESIGN.md §13).

``BlockPool`` recycles KV blocks without zeroing and three adapters
allocate/scatter/free slot state behind one loop — a use-after-free or a
leaked block returns stale-but-plausible KV and corrupts generations
WITHOUT crashing. repro-san is the debug mode that turns those silent
corruptions into immediate, attributed errors:

- **Shadow state** (analysis/shadow.py): every ``BlockPool`` alloc/free and
  every adapter admit/finish/snapshot is mirrored on the host. Double
  reserve, double free, leaks at request-finish / serve-finalize, writes to
  frozen slots, pad rows entering a recurrence, and snapshots of dead slots
  all raise :class:`~repro.analysis.shadow.SanitizerError` at the violating
  call, with block/slot/request attribution.
- **Poison-on-free**: freed blocks are filled with
  :data:`~repro.analysis.shadow.POISON` (finite — see shadow.py for why
  parity survives) and the paged gather oracle mirror
  (``kernels/ref.paged_poison_counts``) detects any committed position of a
  live slot that can still REACH a freed block — the use-after-free the
  block-table indirection makes possible.
- **Numerics tripwires**: ``core/quant.py`` boundary checks are switched on
  (bad scales raise with param + layer-class via core/policy.py), the
  per-round device check counts NaN/Inf/overflow per cache leaf per layer,
  and the engine checks final logits.

Cost discipline: all per-round device tripwires run in ONE jitted program
whose result is fetched with ONE extra ``jax.device_get`` per round — the
lexical host-sync budget (analysis/host_sync.py) holds under sanitize.
Enable with ``sanitize=True`` on ``InferenceEngine``/``SchedulerCore``,
``REPRO_SAN=1`` in the environment, or ``--sanitize`` on the serve CLI.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.shadow import (
    OVERFLOW_LIMIT,
    POISON,
    SanitizerError,
    ShadowBlockTracker,
    SlotShadow,
)
from repro.core.quant import set_numerics_checks

__all__ = [
    "ENV_VAR",
    "Sanitizer",
    "check_array",
    "sanitize_enabled",
]

ENV_VAR = "REPRO_SAN"


def sanitize_enabled(default: bool = False) -> bool:
    """True when the environment opts into repro-san (``REPRO_SAN=1``)."""
    v = os.environ.get(ENV_VAR)
    if v is None:
        return default
    return v not in ("", "0")


def check_array(tag: str, x) -> None:
    """Host-side NaN/Inf/overflow check on a concrete array (engine logits).

    One deliberate device fetch per *generate call* — not per round; the
    per-round cache tripwires live in :meth:`Sanitizer.check_round`.
    """
    if isinstance(x, jax.core.Tracer):
        return
    a = np.asarray(jax.device_get(x))
    if not np.issubdtype(a.dtype, np.inexact):
        return
    bad = ~np.isfinite(a) | (np.abs(a) > OVERFLOW_LIMIT)
    n = int(bad.sum())
    if n:
        idx = tuple(int(i) for i in np.argwhere(bad)[0])
        raise SanitizerError(
            f"repro-san[numerics]: {tag}: {n} non-finite/overflow value(s) "
            f"of {a.size}, first at index {idx} = {a[idx]!r}")


class Sanitizer:
    """Per-core sanitizer: one instance per ``SchedulerCore``, re-armed by
    ``begin_serve`` for every serve. The core calls the hooks below at the
    lexical points DESIGN.md §13 pins down; adapters never talk to the
    sanitizer directly except through ``san_state()`` (their pool/table
    registration) and the snapshot hook.
    """

    def __init__(self, core):
        self.core = core
        self.adapter = None
        self.slots_shadow: SlotShadow | None = None
        self.tracker: ShadowBlockTracker | None = None
        self.table = None               # adapter's block table (shared ref)
        self._check = None              # jitted per-round tripwire program
        self._leaf_names: list[str] = []
        self._poison_fill = None
        set_numerics_checks(True)       # quantize/dequantize boundary guards

    # -- serve lifecycle -----------------------------------------------------

    def begin_serve(self, adapter, cache):
        self.adapter = adapter
        self.slots_shadow = SlotShadow(self.core.slots, adapter.kind)
        st = adapter.san_state()
        pool, self.table = st.get("pool"), st.get("table")
        self.tracker = None
        if pool is not None:
            self.tracker = ShadowBlockTracker(pool.num_blocks)
            pool.shadow = self.tracker
        self._check = None              # cache pytree may differ per serve
        return cache

    def on_admit(self, s: int, r) -> None:
        self.slots_shadow.on_admit(s, r.id)
        if self.tracker is not None:
            self.tracker.set_context(s)   # the admission prompt-block alloc

    def on_prefill_group(self, group, length: int) -> None:
        self.slots_shadow.check_prefill_group(
            [s for s, _ in group], [len(r.tokens) for _, r in group], length)

    def on_request_finish(self, cache, s: int, req_id, pos_s):
        """After ``adapter.on_finish(s)``: freeze the slot, audit that every
        block it owned came back, and poison the frees SYNCHRONOUSLY — a
        deferred fill would race a re-allocation of the same block and
        clobber its fresh prefill writes."""
        self.slots_shadow.on_finish(s, pos_s)
        if self.tracker is not None:
            self.tracker.audit_request(s, req_id)
            cache = self._apply_poison(cache)
        return cache

    def pre_round(self, cache):
        """Drain poison pending from out-of-band frees (anything that called
        ``pool.free`` outside the finish path, e.g. a buggy adapter's
        ``before_round``) before this round's decode reads the pool."""
        if self.tracker is not None and self.tracker.pending_poison:
            cache = self._apply_poison(cache)
        return cache

    def check_round(self, cache, pos, live) -> None:
        """The per-round tripwires: frozen-slot drift on the host, then ONE
        jitted device program + ONE ``device_get`` for every numeric check
        (per-leaf per-layer non-finite counts, paged poison reach)."""
        del live
        self.slots_shadow.check_frozen(pos)
        paged = self.tracker is not None
        if self._check is None:
            self._check = self._build_check(cache, paged)
        if paged:
            flags = self._check(cache, jnp.asarray(self.table),
                                jnp.asarray(pos, jnp.int32))
        else:
            flags = self._check(cache)
        self._interpret(jax.device_get(flags))

    def on_snapshot(self, slots) -> None:
        """Adapter snapshot hook: snapshotting a dead slot is a UAF on the
        snapshot path; a table row disagreeing with shadow ownership means
        the snapshot would carry phantom or aliased blocks."""
        if self.slots_shadow is None:
            return
        slot_ids = [int(s) for s in np.asarray(slots).reshape(-1)]
        self.slots_shadow.check_snapshot(slot_ids)
        if self.tracker is not None:
            for s in slot_ids:
                shadow = self.tracker.slot_blocks(s)
                mapped = sorted(int(b) for b in self.table[s] if b != 0)
                if mapped != shadow:
                    raise SanitizerError(
                        f"repro-san[paged]: snapshot of slot {s} carries "
                        f"phantom/aliased blocks: table maps {mapped} but "
                        f"shadow ownership is {shadow}")

    def finalize(self) -> None:
        """End-of-serve audit: nothing owned, nothing live, shadow and pool
        agree the pool drained back to empty."""
        if self.tracker is not None:
            self.tracker.audit_final()
            pool = self.adapter.san_state().get("pool")
            if pool is not None and pool.live_blocks != 0:
                raise SanitizerError(
                    f"repro-san[paged]: pool reports {pool.live_blocks} live "
                    "block(s) at end of serve but the shadow saw every block "
                    "freed — an allocation bypassed the shadowed pool")
        leftover = self.slots_shadow.live_slots()
        if leftover:
            raise SanitizerError(
                f"repro-san[{self.slots_shadow.kind}]: slot(s) {leftover} "
                "still live at end of serve — requests finished without "
                "on_finish")

    # -- device programs -----------------------------------------------------

    def _apply_poison(self, cache):
        blocks = self.tracker.drain_poison()
        if not blocks:
            return cache
        idx = jnp.asarray(sorted(set(blocks)), jnp.int32)
        if self._poison_fill is None:
            @partial(jax.jit, donate_argnums=(0,))
            def fill(pages, blocks_d):
                return pages.at[:, blocks_d].set(
                    jnp.asarray(POISON, pages.dtype))

            self._poison_fill = fill
        return {k: (self._poison_fill(v, idx)
                    if k in ("k_pages", "v_pages") else v)
                for k, v in cache.items()}

    def _build_check(self, cache, paged: bool):
        self._leaf_names = [
            jax.tree_util.keystr(kp)
            for kp, _ in jax.tree_util.tree_flatten_with_path(cache)[0]]

        def leaf_counts(leaf):
            # per-axis-0 (layer) count of NaN/Inf/overflow values; integer
            # leaves can't hold them and report a zero so the output pytree
            # stays congruent with the cache
            if not jnp.issubdtype(leaf.dtype, jnp.inexact):
                return jnp.zeros((1,), jnp.int32)
            x = leaf.astype(jnp.float32)
            bad = ~jnp.isfinite(x) | (jnp.abs(x) > OVERFLOW_LIMIT)
            if bad.ndim < 2:
                return jnp.atleast_1d(bad.sum().astype(jnp.int32))
            return bad.reshape(bad.shape[0], -1).sum(-1).astype(jnp.int32)

        if paged:
            # lazy: kernels.ref pulls in the quant/kernels stack, which the
            # analysis package must not require for pure static linting
            from repro.kernels.ref import paged_poison_counts

            @jax.jit
            def check(cache, table, pos):
                counts = [leaf_counts(x) for x in jax.tree.leaves(cache)]
                pc = paged_poison_counts(cache["k_pages"], cache["v_pages"],
                                         table, pos, POISON)
                return counts, pc

            return check

        @jax.jit
        def check(cache):
            return [leaf_counts(x) for x in jax.tree.leaves(cache)], None

        return check

    def _interpret(self, flags) -> None:
        counts_all, pc = flags
        for name, counts in zip(self._leaf_names, counts_all):
            counts = np.atleast_1d(np.asarray(counts))
            total = int(counts.sum())
            if total:
                layers = np.flatnonzero(counts).tolist()
                raise SanitizerError(
                    "repro-san[numerics]: non-finite/overflow values in "
                    f"cache leaf {name}: {total} value(s) at axis-0 (layer) "
                    f"indices {layers} (per-layer counts "
                    f"{counts[layers].tolist()})")
        if pc is not None:
            pc = np.asarray(pc)
            if pc.sum():
                ell, s, j = (int(i) for i in np.argwhere(pc)[0])
                phys = int(self.table[s, j])
                gen = self.tracker.generation[phys]
                raise SanitizerError(
                    "repro-san[paged]: poison read — use-after-free: layer "
                    f"{ell}, slot {s} (request {self.slots_shadow.req[s]}) "
                    f"still maps freed physical block {phys} (generation "
                    f"{gen}) at virtual block {j}; "
                    f"{int(pc[ell, s, j])} committed position(s) reach it")
