"""registry-coverage checker: capability flags vs callables vs test matrix.

A new model family gets its fast paths (ragged prefill, paged KV, spec
decode) only through three ``Model`` flags — and a flag nobody tests is a
fast path that silently rots. Three layers of coverage:

1. **Declaration** (file check on ``models/registry.py``): every
   ``Model(...)`` construction spells out the full capability surface —
   ``supports_lengths`` / ``supports_paged`` / ``supports_spec`` plus the
   scheduling-core ``cache_kind`` — even when False/"none". Dataclass
   defaults would make omission legal; omission is exactly how a family
   misses a fast path without anyone deciding that.

2. **Consistency** (project check): for each arch, a True flag must come
   with its callables (``supports_paged`` => ``init_paged_cache`` +
   ``decode_paged``; ``supports_spec`` => ``verify``/``commit_verify``)
   and a False flag must NOT ship them (dead capability). ``cache_kind``
   must be one of ``kv``/``state``/``none``; kv and state families must
   ship the slot hooks (``insert_slots`` + ``gather_slots`` — the
   scheduling core's continuous-batching contract, serving/core.py) and
   ``none`` families must not.

3. **Test matrix** (project check): each True flag appears in the matching
   list in ``tests/arch_matrix.py`` (``RAGGED_ARCHS`` / ``PAGED_ARCHS`` /
   ``SPEC_ARCHS``) — parsed as literals, no test import — and the matrix
   holds no unknown ids or capability-less entries. When any audited arch
   has ``cache_kind="state"``, a ``SLOT_STATE_ARCHS`` list must cover the
   slot-state continuous-batching families the same way.
"""

from __future__ import annotations

import ast
import fnmatch
import os
from typing import Iterable

from repro.analysis.engine import BaseChecker, Finding

CAP_FLAGS = ("supports_lengths", "supports_paged", "supports_spec")

# declaration surface: the bool flags plus the scheduling-core cache kind
DECLARED = CAP_FLAGS + ("cache_kind",)

# flag -> (matrix list name, [required Model attributes when True])
CAPS = {
    "supports_lengths": ("RAGGED_ARCHS", []),
    "supports_paged": ("PAGED_ARCHS", ["init_paged_cache", "decode_paged"]),
    "supports_spec": ("SPEC_ARCHS", ["verify", "commit_verify"]),
}

CACHE_KINDS = ("kv", "state", "none")
SLOT_HOOKS = ("insert_slots", "gather_slots")
SLOT_STATE_LIST = "SLOT_STATE_ARCHS"

DEFAULT_MATRIX = "tests/arch_matrix.py"
REGISTRY_GLOB = "*models/registry.py"
REGISTRY_ANCHOR = "src/repro/models/registry.py"


def _matrix_lists(path: str) -> dict[str, tuple[int, list[str]]]:
    """{LIST_NAME: (lineno, [arch ids])} for top-level list-of-str assigns."""
    with open(path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    out: dict[str, tuple[int, list[str]]] = {}
    for node in tree.body:
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, (ast.List, ast.Tuple))):
            continue
        elts = node.value.elts
        if not all(isinstance(e, ast.Constant) and isinstance(e.value, str)
                   for e in elts):
            continue
        for t in node.targets:
            if isinstance(t, ast.Name):
                out[t.id] = (node.lineno, [e.value for e in elts])
    return out


class RegistryCoverageChecker(BaseChecker):
    id = "registry-coverage"
    description = ("every Model declares supports_lengths/paged/spec and "
                   "cache_kind explicitly; capabilities have callables, "
                   "slot hooks, and a test-matrix entry")

    def __init__(self, archs=None, matrix_path: str = DEFAULT_MATRIX,
                 build=None, registry_glob: str = REGISTRY_GLOB):
        """``archs``: arch ids to audit (default: the live ARCH_IDS);
        ``build``: arch_id -> Model (default: registry ``build_arch``);
        ``matrix_path``: repo-relative test-matrix module."""
        self._archs = archs
        self._build = build
        self.matrix_path = matrix_path
        self.registry_glob = registry_glob

    # -- 1. explicit declaration (static) ------------------------------------
    def check_file(self, path, tree, source) -> Iterable[Finding]:
        if not fnmatch.fnmatch(path, self.registry_glob):
            return
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "Model"):
                continue
            given = {kw.arg for kw in node.keywords if kw.arg}
            missing = [f for f in DECLARED if f not in given]
            if missing:
                yield Finding(
                    self.id, path, node.lineno,
                    f"Model(...) omits capability flags {missing}: declare "
                    "the full surface explicitly (False included) so a new "
                    "family never misses a fast path by default",
                    col=node.col_offset)

    # -- 2 + 3. live consistency and matrix coverage -------------------------
    def check_project(self, root: str) -> Iterable[Finding]:
        if self._archs is None or self._build is None:
            from repro.models import registry
            self._archs = self._archs or list(registry.ARCH_IDS)
            self._build = self._build or registry.build_arch

        mpath = os.path.join(root, self.matrix_path)
        if not os.path.isfile(mpath):
            yield Finding(self.id, self.matrix_path, 1,
                          "test matrix module missing: capability flags have "
                          "no test coverage ledger")
            return
        lists = _matrix_lists(mpath)

        caps: dict[str, dict[str, bool]] = {}
        slot_state: dict[str, bool] = {}
        for arch in self._archs:
            model = self._build(arch)
            caps[arch] = {f: bool(getattr(model, f)) for f in CAP_FLAGS}
            for flag, (_, attrs) in CAPS.items():
                have = [a for a in attrs if getattr(model, a) is not None]
                if caps[arch][flag] and len(have) != len(attrs):
                    yield Finding(
                        self.id, REGISTRY_ANCHOR, 1,
                        f"{arch}: {flag}=True but missing callables "
                        f"{sorted(set(attrs) - set(have))}")
                elif not caps[arch][flag] and have:
                    yield Finding(
                        self.id, REGISTRY_ANCHOR, 1,
                        f"{arch}: {flag}=False yet ships {have} — dead "
                        "capability; either set the flag or drop the hooks")
            kind = getattr(model, "cache_kind", "none")
            slot_state[arch] = kind == "state"
            if kind not in CACHE_KINDS:
                yield Finding(
                    self.id, REGISTRY_ANCHOR, 1,
                    f"{arch}: cache_kind={kind!r} is not one of "
                    f"{'/'.join(CACHE_KINDS)}")
                continue
            hooks = [a for a in SLOT_HOOKS
                     if getattr(model, a, None) is not None]
            if kind in ("kv", "state") and len(hooks) != len(SLOT_HOOKS):
                yield Finding(
                    self.id, REGISTRY_ANCHOR, 1,
                    f"{arch}: cache_kind={kind!r} but missing slot hooks "
                    f"{sorted(set(SLOT_HOOKS) - set(hooks))} — the "
                    "scheduling core cannot serve this family continuously")
            elif kind == "none" and hooks:
                yield Finding(
                    self.id, REGISTRY_ANCHOR, 1,
                    f"{arch}: cache_kind='none' yet ships {hooks} — dead "
                    "capability; either declare the kind or drop the hooks")

        for flag, (list_name, _) in CAPS.items():
            if list_name not in lists:
                yield Finding(
                    self.id, self.matrix_path, 1,
                    f"matrix list {list_name} missing (needed to cover "
                    f"{flag})")
                continue
            lineno, ids = lists[list_name]
            for arch in self._archs:
                if caps[arch][flag] and arch not in ids:
                    yield Finding(
                        self.id, self.matrix_path, lineno,
                        f"{arch} has {flag}=True but no {list_name} entry: "
                        "the fast path is untested")
            for aid in ids:
                if aid not in caps:
                    yield Finding(
                        self.id, self.matrix_path, lineno,
                        f"{list_name} names unknown arch {aid!r}")
                elif not caps[aid][flag]:
                    yield Finding(
                        self.id, self.matrix_path, lineno,
                        f"{list_name} lists {aid} but its {flag} is False — "
                        "the matrix overstates coverage")

        # slot-state continuous batching: only audited when a state family
        # exists, so fixture registries without recurrent archs stay clean
        if any(slot_state.values()):
            if SLOT_STATE_LIST not in lists:
                yield Finding(
                    self.id, self.matrix_path, 1,
                    f"matrix list {SLOT_STATE_LIST} missing (needed to "
                    "cover cache_kind='state' slot-state serving)")
            else:
                lineno, ids = lists[SLOT_STATE_LIST]
                for arch, is_state in slot_state.items():
                    if is_state and arch not in ids:
                        yield Finding(
                            self.id, self.matrix_path, lineno,
                            f"{arch} has cache_kind='state' but no "
                            f"{SLOT_STATE_LIST} entry: the slot-state "
                            "continuous path is untested")
                for aid in ids:
                    if aid not in slot_state:
                        yield Finding(
                            self.id, self.matrix_path, lineno,
                            f"{SLOT_STATE_LIST} names unknown arch {aid!r}")
                    elif not slot_state[aid]:
                        yield Finding(
                            self.id, self.matrix_path, lineno,
                            f"{SLOT_STATE_LIST} lists {aid} but its "
                            "cache_kind is not 'state' — the matrix "
                            "overstates coverage")
