"""xray bytes-contract sweep: model-vs-HLO HBM bytes per decode step.

For each tinyllama quant preset (int8 / packed int4 / mixed) this compiles
the full-size single-request decode step on CPU from eval_shape-sized
inputs (no weights materialized — the same rows the ``xray-bytes`` checker
audits, shared via the ``repro.analysis.xray`` catalog), walks the
optimized HLO with ``repro.analysis.hlo``, and prints both sides:

  name                          us_per_call   derived
  xray_bytes_int8               -             hlo_mb=...;model_mb=...;delta=+6.1%

The suite FAILS (returns False -> ``run.py`` exit 1) when any preset's
compiled traffic disagrees with the registry nbytes/bits_per_weight model
by more than ``BYTES_RTOL`` (15%) — the CI gate that "int4" actually
streams packed nibbles, not dequantized f32 (DESIGN.md §14).
"""

from __future__ import annotations


def run() -> bool:
    from repro.analysis.hlo import analyze
    from repro.analysis.xray import BYTES_RTOL, catalog

    ok = True
    rows = [p for p in catalog() if p.expected_bytes is not None]
    if not rows:
        print("xray_bytes,-,error=no bytes rows in catalog")
        return False
    for prog in rows:
        rep = analyze(prog.hlo_text)
        delta = rep.hbm_bytes / prog.expected_bytes - 1.0
        bad = abs(delta) > BYTES_RTOL
        ok = ok and not bad
        print(f"xray_bytes_{prog.fmt},-,"
              f"hlo_mb={rep.hbm_bytes / 1e6:.1f};"
              f"model_mb={prog.expected_bytes / 1e6:.1f};"
              f"delta={delta:+.1%};tol={BYTES_RTOL:.0%}"
              + (";FAIL" if bad else ""))
    return ok
