"""Paper Table II: forward-pass runtime distribution (TinyLlama).

The paper profiles the TinyLlama decode forward pass on the ZCU102 ARM PS at
positions 63/127/255 and finds matrix computation >97% of runtime. We time
each component at the paper's exact dimensions (dim=2048, hidden=5632,
kv_dim=256, 22 layers, batch 1) on this host and report the same breakdown.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.models.common import apply_rope, rmsnorm, swiglu

DIM, HIDDEN, VOCAB, LAYERS = 2048, 5632, 32000, 22
HEADS, KV_HEADS, HEAD_DIM = 32, 4, 64


def run():
    rng = np.random.default_rng(0)
    f32 = np.float32
    x = jnp.asarray(rng.normal(size=(DIM,)).astype(f32))

    # per-layer weights at TinyLlama shapes
    wqkv = jnp.asarray(rng.normal(size=(DIM + 2 * KV_HEADS * HEAD_DIM, DIM)).astype(f32) * 0.02)
    wo = jnp.asarray(rng.normal(size=(DIM, DIM)).astype(f32) * 0.02)
    w13 = jnp.asarray(rng.normal(size=(2 * HIDDEN, DIM)).astype(f32) * 0.02)
    w2 = jnp.asarray(rng.normal(size=(DIM, HIDDEN)).astype(f32) * 0.02)
    wcls = jnp.asarray(rng.normal(size=(VOCAB, DIM)).astype(f32) * 0.02)
    norm_w = jnp.ones((DIM,))

    matmuls = jax.jit(lambda v: wcls @ (w2 @ swiglu(*jnp.split(w13 @ (wo @ (wqkv @ v)[:DIM]), 2))))

    def components(pos):
        k = jnp.asarray(rng.normal(size=(1, pos + 1, KV_HEADS, HEAD_DIM)).astype(f32))
        v = jnp.asarray(rng.normal(size=(1, pos + 1, KV_HEADS, HEAD_DIM)).astype(f32))
        q = jnp.asarray(rng.normal(size=(1, 1, HEADS, HEAD_DIM)).astype(f32))

        def mha(q, k, v):
            qg = q.reshape(1, 1, KV_HEADS, HEADS // KV_HEADS, HEAD_DIM)
            s = jnp.einsum("bskgh,btkh->bkgst", qg, k) / HEAD_DIM**0.5
            a = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bkgst,btkh->bskgh", a, v)

        gate = jnp.asarray(rng.normal(size=(HIDDEN,)).astype(f32))
        up = jnp.asarray(rng.normal(size=(HIDDEN,)).astype(f32))
        comps = {
            # one token's worth of matrix computation (all layers + classifier)
            "matrix_computation": (jax.jit(lambda a: matmuls(a)), (x,), LAYERS),
            "multi_head_attention": (jax.jit(mha), (q, k, v), LAYERS),
            "swiglu": (jax.jit(swiglu), (gate, up), LAYERS),
            "rope": (jax.jit(lambda t: apply_rope(t, jnp.asarray([[pos]]), 1e4)), (q,), LAYERS),
            "rmsnorm": (jax.jit(lambda a: rmsnorm(a, norm_w)), (x,), 3 * LAYERS),
        }
        return comps

    for pos in (63, 127, 255):
        rows = []
        for name, (fn, args, mult) in components(pos).items():
            us = time_fn(fn, *args) * mult
            rows.append((name, us))
        total = sum(us for _, us in rows)
        for name, us in rows:
            emit(f"table2/pos{pos}/{name}", us, f"{100*us/total:.2f}%")


if __name__ == "__main__":
    run()
