"""Kernel-shape sweep for the Pallas GQMV/GQMM (interpret mode on CPU; the
BlockSpec tiling is the TPU artifact). Reports per-call time of the XLA
path (the math the kernels implement) across the shapes the assigned
architectures actually use."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.quant import quantize_activation, quantize_groupwise
from repro.kernels import ops

# (label, m, n, gs) from the assigned archs' serve-path projections
SHAPES = [
    ("tinyllama_wqkv", 2560, 2048, 256),
    ("internlm2_w13", 16384, 2048, 256),
    ("gemma2_w2", 2304, 9216, 256),
    ("dscoder_w2", 7168, 19200, 256),
    ("pixtral_wqkv", 6144, 5120, 256),
]


def run():
    rng = np.random.default_rng(2)
    for label, m, n, gs in SHAPES:
        w = quantize_groupwise(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), gs)
        x = quantize_activation(jnp.asarray(rng.normal(size=(n,)).astype(np.float32)), gs)
        fn = jax.jit(lambda wq, ws, xq, xs: ops.gqmv(wq, ws, xq, xs, group_size=gs, impl="xla"))
        us = time_fn(fn, w.qvalues, w.scales, x.qvalues, x.scales, iters=3)
        gops = 2.0 * m * n / (us * 1e-6) / 1e9
        emit(f"kernels/gqmv/{label}", us, f"{gops:.2f} GOPS")

    # batched GQMM at decode batch sizes
    for b in (8, 32, 128):
        m, n, gs = 4096, 4096, 256
        w = quantize_groupwise(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), gs)
        x = quantize_activation(jnp.asarray(rng.normal(size=(b, n)).astype(np.float32)), gs)
        fn = jax.jit(lambda wq, ws, xq, xs: ops.gqmm(wq, ws, xq, xs, group_size=gs, impl="xla"))
        us = time_fn(fn, w.qvalues, w.scales, x.qvalues, x.scales, iters=3)
        gops = 2.0 * b * m * n / (us * 1e-6) / 1e9
        emit(f"kernels/gqmm/b{b}", us, f"{gops:.2f} GOPS")

    # small-m GQMM: the speculative-verify shape (m activation rows = the
    # spec_k chunk, serving/spec.py). This measures the cost CURVE of
    # verifying k tokens per weight stream instead of assuming one decode
    # step scales linearly — us/row falling with m is the amortization the
    # spec suite prices in weight bytes (benchmarks/run.py spec).
    from repro.core.quant import get_format

    for fmt_name in ("int8", "int4"):
        fmt = get_format(fmt_name)
        m, n, gs = 2048, 2048, 256
        w = fmt.quantize(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), gs)
        for rows in (1, 2, 4, 8):
            x = quantize_activation(
                jnp.asarray(rng.normal(size=(rows, n)).astype(np.float32)), gs)
            fn = jax.jit(lambda wq, ws, xq, xs, k=fmt.kernel: ops.gqmm(
                wq, ws, xq, xs, group_size=gs, impl="xla", kernel=k))
            us = time_fn(fn, w.qvalues, w.scales, x.qvalues, x.scales, iters=3)
            gops = 2.0 * rows * m * n / (us * 1e-6) / 1e9
            emit(f"kernels/gqmm_small/{fmt_name}_m{rows}", us,
                 f"{us / rows:.2f} us/row, {gops:.2f} GOPS")


if __name__ == "__main__":
    run()
