"""Paper Table IV: group-wise quantization error statistics (GS=256).

Paper reports, over all TinyLlama weight groups: max 0.0115, min 0.0,
mean 2.65e-4, std 1.73e-4, plus mean relative error 3.30% (std 11.57%).
We quantize TinyLlama-shaped weight tensors (same init family) and report
the same statistics — for int8 (the paper row) and for the narrower
formats (int4, int3, fp8) on the non-embedding matrices they actually
cover under the mixed presets.

CI gates (run fails on either): int3's mean error must stay within
``INT3_VS_INT4_GATE``x int4's (halving the grid from 7 to 3 levels costs
~2.1x; a broken pack path costs far more), and fp8's within
``FP8_VS_INT8_GATE``x int8's (e4m3's 3-bit mantissa vs the 255-level int8
grid measures ~3x; a wrong scale association blows past it).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.quant import quantize, quantize_groupwise

SHAPES = [  # TinyLlama weight matrices (paper Table I)
    (32000, 2048),   # embeddings
    (32000, 2048),   # classifier
    (2048, 2048), (2048, 2048),        # Wq, Wo
    (256, 2048), (256, 2048),          # Wk, Wv
    (5632, 2048), (5632, 2048),        # W1, W3
    (2048, 5632),                      # W2
]

# attn/ffn projections only — the leaves the mixed/mixed3 presets map the
# narrow formats onto (embed/classifier stay int8 there)
NARROW_SHAPES = SHAPES[2:]
NARROW_FORMATS = ("int4", "int3", "fp8")

INT3_VS_INT4_GATE = 3.0   # int3 mean err / int4 mean err (measured ~2.1x)
FP8_VS_INT8_GATE = 4.0    # fp8 mean err / int8 mean err (measured ~3.0x)


def _stats(fmt: str, shapes) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(0)
    errs, rels = [], []
    for shape in shapes:
        w = jnp.asarray((rng.normal(size=shape) * 0.02).astype(np.float32))
        qt = quantize(w, 256, fmt) if fmt != "int8" else quantize_groupwise(w, 256)
        err = np.abs(np.asarray(qt.dequantize()) - np.asarray(w))
        errs.append(err.ravel())
        denom = np.abs(np.asarray(w))
        rels.append((err / np.where(denom > 0, denom, 1.0)).ravel())
    return np.concatenate(errs), np.concatenate(rels)


def run() -> bool:
    t0 = time.perf_counter()
    e, r = _stats("int8", SHAPES)
    us = (time.perf_counter() - t0) * 1e6 / len(SHAPES)
    emit("table4/int8_gs256_max", us, f"{e.max():.4g}")
    emit("table4/int8_gs256_min", us, f"{e.min():.4g}")
    emit("table4/int8_gs256_mean", us, f"{e.mean():.4g}")
    emit("table4/int8_gs256_std", us, f"{e.std():.4g}")
    emit("table4/rel_err_mean_pct", us, f"{100*r.mean():.2f}%")
    emit("table4/rel_err_std_pct", us, f"{100*r.std():.2f}%")

    means = {"int8": float(e.mean())}
    for fmt in NARROW_FORMATS:
        ef, rf = _stats(fmt, NARROW_SHAPES)
        means[fmt] = float(ef.mean())
        emit(f"table4/{fmt}_gs256_mean", 0.0, f"{ef.mean():.4g}")
        emit(f"table4/{fmt}_gs256_max", 0.0, f"{ef.max():.4g}")
        emit(f"table4/{fmt}_rel_err_mean_pct", 0.0, f"{100*rf.mean():.2f}%")

    # int8's mean over ALL shapes vs narrow formats over attn/ffn shapes is
    # comparable: the per-group error depends on the group's absmax, which
    # this init family draws identically for every matrix
    ok = True
    r34 = means["int3"] / means["int4"]
    emit("table4/int3_vs_int4_mean_err", 0.0,
         f"{r34:.2f}x (gate: <= {INT3_VS_INT4_GATE}x)")
    if r34 > INT3_VS_INT4_GATE:
        print(f"FAIL: quant_error: int3 mean error is {r34:.2f}x int4's, "
              f"gate is <= {INT3_VS_INT4_GATE}x", flush=True)
        ok = False
    rf8 = means["fp8"] / means["int8"]
    emit("table4/fp8_vs_int8_mean_err", 0.0,
         f"{rf8:.2f}x (gate: <= {FP8_VS_INT8_GATE}x)")
    if rf8 > FP8_VS_INT8_GATE:
        print(f"FAIL: quant_error: fp8 mean error is {rf8:.2f}x int8's, "
              f"gate is <= {FP8_VS_INT8_GATE}x", flush=True)
        ok = False
    return ok


if __name__ == "__main__":
    run()
