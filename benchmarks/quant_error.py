"""Paper Table IV: group-wise quantization error statistics (GS=256).

Paper reports, over all TinyLlama weight groups: max 0.0115, min 0.0,
mean 2.65e-4, std 1.73e-4, plus mean relative error 3.30% (std 11.57%).
We quantize TinyLlama-shaped weight tensors (same init family) and report
the same statistics.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.quant import quantize_groupwise

SHAPES = [  # TinyLlama weight matrices (paper Table I)
    (32000, 2048),   # embeddings
    (32000, 2048),   # classifier
    (2048, 2048), (2048, 2048),        # Wq, Wo
    (256, 2048), (256, 2048),          # Wk, Wv
    (5632, 2048), (5632, 2048),        # W1, W3
    (2048, 5632),                      # W2
]


def run():
    rng = np.random.default_rng(0)
    errs, rels = [], []
    t0 = time.perf_counter()
    for i, shape in enumerate(SHAPES):
        w = jnp.asarray((rng.normal(size=shape) * 0.02).astype(np.float32))
        qt = quantize_groupwise(w, 256)
        err = np.abs(np.asarray(qt.dequantize()) - np.asarray(w))
        errs.append(err.ravel())
        denom = np.abs(np.asarray(w))
        rels.append((err / np.where(denom > 0, denom, 1.0)).ravel())
    us = (time.perf_counter() - t0) * 1e6 / len(SHAPES)
    e = np.concatenate(errs)
    r = np.concatenate(rels)
    emit("table4/int8_gs256_max", us, f"{e.max():.4g}")
    emit("table4/int8_gs256_min", us, f"{e.min():.4g}")
    emit("table4/int8_gs256_mean", us, f"{e.mean():.4g}")
    emit("table4/int8_gs256_std", us, f"{e.std():.4g}")
    emit("table4/rel_err_mean_pct", us, f"{100*r.mean():.2f}%")
    emit("table4/rel_err_std_pct", us, f"{100*r.std():.2f}%")


if __name__ == "__main__":
    run()
