"""`quant` suite: per-format PTQ comparison on TinyLlama decode shapes.

For every registered weight format (int8 = paper W8A8, int4 = packed
sub-byte, int3 = packed sub-4-bit, fp8 = e4m3 value grid) reports:

  bits-per-weight       stored bits per logical weight incl. fp32 scales
  weight MB per step    bytes DMA'd from HBM for one decode step's matmuls
                        (the paper's §II-B bandwidth axis; int4 must move
                        >= 1.8x fewer bytes than int8)
  decode us/call        measured batch-1 GQMV wall time per projection
                        (XLA path — the portable backend; Pallas-interpret
                        is a correctness harness, not a timing one)
  Table-IV error stats  round-trip |r_hat - r| statistics (Eq. 3), plus a
                        NAIVE per-tensor int4 row showing what group-wise
                        scales buy at 4 bits

plus the "mixed3" policy preset (attn/ffn int3, embed/classifier/other
int8) priced per shape class. CI gate: mixed3 weight bytes/step must be
<= 0.8x int4's on these shapes, or the run fails. Headline numbers land
in BENCH_quant.json.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.policy import resolve_format_map
from repro.core.quant import available_formats, quantization_error_stats, quantize
from repro.kernels import ops

# The three distinct decode-step matmul shapes of TinyLlama (paper Table I);
# kernel1 (d, d), kernel2-style (4d-ish, d) and its transpose cover the
# attention + FFN traffic without re-timing duplicate shapes.
SHAPES = [(2048, 2048), (5632, 2048), (2048, 5632)]
# policy leaf class each shape stands in for when pricing format MAPS:
# (d, d) is an attention projection, the (4d-ish, d) pair is the FFN
SHAPE_CLASSES = ("attn", "ffn", "ffn")
GS = 256
MIXED3_VS_INT4_GATE = 0.8


def _naive_int4_per_tensor(r: np.ndarray) -> np.ndarray:
    """One symmetric scale for the WHOLE tensor (the baseline group-wise
    scales beat): S = 2*max|r|/15, round-clip to [-7, 7]."""
    s = 2.0 * np.abs(r).max() / 15.0
    q = np.clip(np.round(r / s), -7, 7)
    return (q * s).astype(np.float32)


def run():
    rng = np.random.default_rng(0)
    weights_f = [
        jnp.asarray((rng.normal(size=shape) * 0.02).astype(np.float32))
        for shape in SHAPES
    ]
    xs = [
        jnp.asarray(rng.normal(size=(shape[1],)).astype(np.float32))
        for shape in SHAPES
    ]

    step_bytes = {}
    for fmt in available_formats():
        qws = [quantize(w, GS, fmt) for w in weights_f]
        bpw = qws[0].bits_per_weight()
        step_bytes[fmt] = sum(q.nbytes() for q in qws)

        mm = jax.jit(lambda x, w: ops.quantized_matmul(x, w, impl="xla"))
        us = sum(time_fn(mm, x, q) for x, q in zip(xs, qws)) / len(SHAPES)
        emit(f"quant/{fmt}/bits_per_weight", 0.0, f"{bpw:.3f}")
        emit(f"quant/{fmt}/weight_mb_per_step", 0.0,
             f"{step_bytes[fmt] / 1e6:.2f}MB")
        emit(f"quant/{fmt}/decode_gqmv", us, "us/call mean over shapes")

        stats = quantization_error_stats(weights_f[0], GS, fmt)
        for k in ("max", "mean", "std"):
            emit(f"quant/{fmt}/err_{k}", 0.0, f"{stats[k]:.4g}")
        emit(f"quant/{fmt}/rel_err_mean_pct", 0.0,
             f"{stats['rel_mean_pct']:.2f}%")

    if {"int8", "int4"} <= set(step_bytes):
        ratio = step_bytes["int8"] / step_bytes["int4"]
        emit("quant/int4_vs_int8_weight_bytes", 0.0, f"{ratio:.2f}x fewer")

    # the "mixed3" policy preset, priced per shape class (attn/ffn -> int3
    # on these shapes; embed/classifier keep int8 but have no shape here)
    fmap = resolve_format_map("mixed3")
    qws3 = [quantize(w, GS, fmap[c]) for w, c in zip(weights_f, SHAPE_CLASSES)]
    step_bytes["mixed3"] = sum(q.nbytes() for q in qws3)
    emit("quant/mixed3/weight_mb_per_step", 0.0,
         f"{step_bytes['mixed3'] / 1e6:.2f}MB")
    ok = True
    if {"int4", "mixed3"} <= set(step_bytes):
        r34 = step_bytes["mixed3"] / step_bytes["int4"]
        emit("quant/mixed3_vs_int4_weight_bytes", 0.0,
             f"{r34:.3f}x int4 (gate: <= {MIXED3_VS_INT4_GATE}x)")
        if r34 > MIXED3_VS_INT4_GATE:
            print(f"FAIL: quant: mixed3 weight bytes/step is {r34:.3f}x int4, "
                  f"gate is <= {MIXED3_VS_INT4_GATE}x", flush=True)
            ok = False

    # group-wise int4 vs naive per-tensor int4 (what Table IV looks like
    # without per-group scales at 4 bits)
    w0 = np.asarray(weights_f[0])
    naive_err = np.abs(_naive_int4_per_tensor(w0) - w0)
    emit("quant/int4_naive_per_tensor/err_mean", 0.0, f"{naive_err.mean():.4g}")
    emit("quant/int4_naive_per_tensor/err_max", 0.0, f"{naive_err.max():.4g}")

    headline = {
        "group_size": GS,
        "weight_bytes_per_step": {k: int(v) for k, v in step_bytes.items()},
        "mixed3_vs_int4": round(step_bytes["mixed3"] / step_bytes["int4"], 4),
        "gate_mixed3_vs_int4_max": MIXED3_VS_INT4_GATE,
    }
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_quant.json")
    with open(out_path, "w") as f:
        json.dump(headline, f, indent=2, sort_keys=True)
        f.write("\n")
    return ok


if __name__ == "__main__":
    run()
