"""`quant` suite: per-format PTQ comparison on TinyLlama decode shapes.

For every registered weight format (int8 = paper W8A8, int4 = packed
sub-byte) reports:

  bits-per-weight       stored bits per logical weight incl. fp32 scales
  weight MB per step    bytes DMA'd from HBM for one decode step's matmuls
                        (the paper's §II-B bandwidth axis; int4 must move
                        >= 1.8x fewer bytes than int8)
  decode us/call        measured batch-1 GQMV wall time per projection
                        (XLA path — the portable backend; Pallas-interpret
                        is a correctness harness, not a timing one)
  Table-IV error stats  round-trip |r_hat - r| statistics (Eq. 3), plus a
                        NAIVE per-tensor int4 row showing what group-wise
                        scales buy at 4 bits
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.quant import available_formats, quantization_error_stats, quantize
from repro.kernels import ops

# The three distinct decode-step matmul shapes of TinyLlama (paper Table I);
# kernel1 (d, d), kernel2-style (4d-ish, d) and its transpose cover the
# attention + FFN traffic without re-timing duplicate shapes.
SHAPES = [(2048, 2048), (5632, 2048), (2048, 5632)]
GS = 256


def _naive_int4_per_tensor(r: np.ndarray) -> np.ndarray:
    """One symmetric scale for the WHOLE tensor (the baseline group-wise
    scales beat): S = 2*max|r|/15, round-clip to [-7, 7]."""
    s = 2.0 * np.abs(r).max() / 15.0
    q = np.clip(np.round(r / s), -7, 7)
    return (q * s).astype(np.float32)


def run():
    rng = np.random.default_rng(0)
    weights_f = [
        jnp.asarray((rng.normal(size=shape) * 0.02).astype(np.float32))
        for shape in SHAPES
    ]
    xs = [
        jnp.asarray(rng.normal(size=(shape[1],)).astype(np.float32))
        for shape in SHAPES
    ]

    step_bytes = {}
    for fmt in available_formats():
        qws = [quantize(w, GS, fmt) for w in weights_f]
        bpw = qws[0].bits_per_weight()
        step_bytes[fmt] = sum(q.nbytes() for q in qws)

        mm = jax.jit(lambda x, w: ops.quantized_matmul(x, w, impl="xla"))
        us = sum(time_fn(mm, x, q) for x, q in zip(xs, qws)) / len(SHAPES)
        emit(f"quant/{fmt}/bits_per_weight", 0.0, f"{bpw:.3f}")
        emit(f"quant/{fmt}/weight_mb_per_step", 0.0,
             f"{step_bytes[fmt] / 1e6:.2f}MB")
        emit(f"quant/{fmt}/decode_gqmv", us, "us/call mean over shapes")

        stats = quantization_error_stats(weights_f[0], GS, fmt)
        for k in ("max", "mean", "std"):
            emit(f"quant/{fmt}/err_{k}", 0.0, f"{stats[k]:.4g}")
        emit(f"quant/{fmt}/rel_err_mean_pct", 0.0,
             f"{stats['rel_mean_pct']:.2f}%")

    if {"int8", "int4"} <= set(step_bytes):
        ratio = step_bytes["int8"] / step_bytes["int4"]
        emit("quant/int4_vs_int8_weight_bytes", 0.0, f"{ratio:.2f}x fewer")

    # group-wise int4 vs naive per-tensor int4 (what Table IV looks like
    # without per-group scales at 4 bits)
    w0 = np.asarray(weights_f[0])
    naive_err = np.abs(_naive_int4_per_tensor(w0) - w0)
    emit("quant/int4_naive_per_tensor/err_mean", 0.0, f"{naive_err.mean():.4g}")
    emit("quant/int4_naive_per_tensor/err_max", 0.0, f"{naive_err.max():.4g}")


if __name__ == "__main__":
    run()
