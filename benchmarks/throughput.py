"""Paper Table VI: inference speed / GQMV throughput / scheduling ablation.

Paper (TinyLlama on ZCU102): PS baseline 0.201 GOPS / 0.093 tok/s; LlamaF
4.696 GOPS (23.4x), 1.33-1.48 tok/s (14.3-15.8x), +55.6-57.9% from async
scheduling, 6.1x tok/s/W.

This container has no FPGA/TPU, so we report three layers of evidence:
  1. measured host tok/s of the serving engine, fp32 vs W8A8 (structure);
  2. measured GQMV GOPS at the paper's two kernel shapes (kernel1: n=dim,
     kernel2: n=hidden_dim);
  3. DERIVED v5e roofline for full-size TinyLlama batch-1 decode: tok/s from
     weight-stream bytes (the paper's regime), W32 vs W8A8, plus the
     async-overlap ablation (serialized transfer+compute vs overlapped),
     which is the paper's Fig.2 scheduling experiment at the HBM level.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.quant import quantize_activation, quantize_groupwise
from repro.kernels import ops
from repro.models.registry import build, load_config
from repro.serving.batching import Request, SlotScheduler, serve_bucketed
from repro.serving.engine import InferenceEngine

HBM_BW = 819e9
PEAK = 197e12

# ragged trace: prompt lengths spread thinly across six power-of-two
# buckets, decode budgets mixed within every bucket — real traffic's shape.
# Bucket-serial decode drags each under-filled bucket to its longest
# budget (rows that finished keep burning decode steps); the slot
# scheduler frees a slot the moment its request completes and refills it.
RAGGED_LENGTHS = [2, 5, 9, 14, 17, 30, 33, 60, 65, 120, 130, 250]
RAGGED_BUDGETS = [32, 3, 28, 4, 24, 6, 32, 3, 28, 4, 24, 6]
RAGGED_SLOTS = 6
RAGGED_CHUNK = 4


def measured_engine_toks():
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), dtype=jnp.int32)}
    steps = 32
    for name, quant in (("ps_baseline_fp32", False), ("llamaf_w8a8", True)):
        eng = InferenceEngine(model, params, cache_len=16 + steps, quantize=quant)
        eng.generate(batch, steps)  # warm/compile
        t0 = time.perf_counter()
        eng.generate(batch, steps)
        dt = time.perf_counter() - t0
        emit(f"table6/measured_host/{name}", dt * 1e6 / steps, f"{steps/dt:.2f} tok/s")


def measured_gqmv_gops():
    rng = np.random.default_rng(1)
    for name, (m, n) in (("kernel1_dim", (2048, 2048)), ("kernel2_hidden", (2048, 5632))):
        w = quantize_groupwise(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), 256)
        x = quantize_activation(jnp.asarray(rng.normal(size=(n,)).astype(np.float32)), 256)
        fn = jax.jit(lambda wq, ws, xq, xs: ops.gqmv(wq, ws, xq, xs, group_size=256, impl="xla"))
        us = time_fn(fn, w.qvalues, w.scales, x.qvalues, x.scales)
        gops = 2.0 * m * n / (us * 1e-6) / 1e9
        emit(f"table6/measured_gqmv/{name}", us, f"{gops:.2f} GOPS")


def derived_v5e_roofline():
    # full-size TinyLlama: 1.1B params; batch-1 decode reads every weight once
    n_params = 1.1e9
    for name, bytes_per_w, extra in (
        ("w32a32", 4.0, 0.0),
        ("w8a8_gs256", 1.0, 4.0 / 256),   # int8 + fp32 scale per 256 group
    ):
        wbytes = n_params * (bytes_per_w + extra)
        mem_s = wbytes / HBM_BW
        comp_s = 2 * n_params / PEAK
        overlapped = max(mem_s, comp_s)
        serial = mem_s + comp_s
        emit(f"table6/derived_v5e/{name}_tok_s", overlapped * 1e6, f"{1/overlapped:.1f} tok/s")
        emit(f"table6/derived_v5e/{name}_no_overlap_tok_s", serial * 1e6,
             f"{1/serial:.1f} tok/s (+{100*(serial-overlapped)/overlapped:.1f}% from overlap)")
    speedup = 4.0 + 0 - 0  # bytes ratio w32/w8a8
    emit("table6/derived_v5e/quant_speedup", 0.0,
         f"{(4.0)/(1.0+4.0/256):.2f}x (paper: 14.3-15.8x vs scalar ARM PS)")


def ragged_throughput() -> bool:
    """Measured useful tok/s on a ragged trace: bucket-serial baseline vs
    the slot scheduler (continuous batching). Same requests, same greedy
    sampling, same per-request budgets — the delta is pure scheduling.
    Both run the deferred decode-cache commit (§Perf), so step cost is not
    dominated by the scan's full-cache copy.

    Also gates repro-san's disabled-mode cost (DESIGN.md §13): a scheduler
    built with ``sanitize=False`` must stay within 2% tok/s of the default
    continuous run. The sanitizer's per-round hooks sit on the serve hot
    loop behind ``san is not None`` checks; this pins them (and any future
    work that creeps outside that gate) to noise when the mode is off."""
    from repro.core import flags

    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(1, cfg.vocab_size, size=(n,)).astype(int).tolist(),
                max_new=m)
        for i, (n, m) in enumerate(zip(RAGGED_LENGTHS, RAGGED_BUDGETS))
    ]
    cache_len = max(RAGGED_LENGTHS) + max(RAGGED_BUDGETS) + 64
    total = sum(RAGGED_BUDGETS)                # useful tokens delivered
    with flags.overrides(deferred_decode_cache=True):
        engine = InferenceEngine(model, params, cache_len=cache_len)
        sched = SlotScheduler(engine, slots=RAGGED_SLOTS, chunk=RAGGED_CHUNK)
        engine_off = InferenceEngine(model, params, cache_len=cache_len,
                                     sanitize=False)
        sched_off = SlotScheduler(engine_off, slots=RAGGED_SLOTS,
                                  chunk=RAGGED_CHUNK)

        runs = {
            "bucket_serial": lambda: serve_bucketed(engine, reqs, max(RAGGED_BUDGETS)),
            "continuous_slots": lambda: sched.serve(reqs, max(RAGGED_BUDGETS)),
            "continuous_sanitize_off": lambda: sched_off.serve(
                reqs, max(RAGGED_BUDGETS)),
        }
        results = {}
        for name, fn in runs.items():
            fn()                               # warm/compile
            dt = float("inf")
            for _ in range(3):                 # best-of-3: host-noise robust
                t0 = time.perf_counter()
                out = fn()
                dt = min(dt, time.perf_counter() - t0)
            assert [r.tokens.shape[0] for r in out] == RAGGED_BUDGETS
            results[name] = total / dt
            emit(f"ragged/measured_host/{name}", dt * 1e6 / total,
                 f"{total/dt:.2f} tok/s")
    emit("ragged/measured_host/speedup", 0.0,
         f"{results['continuous_slots']/results['bucket_serial']:.2f}x "
         "continuous vs bucket-serial")
    ratio = results["continuous_sanitize_off"] / results["continuous_slots"]
    ok = ratio >= 0.98
    emit("ragged/measured_host/sanitize_off_overhead", 0.0,
         f"{ratio:.3f}x of baseline tok/s "
         f"({'within' if ok else 'EXCEEDS'} the 2% repro-san off gate)")
    return ok


def paged_throughput() -> bool:
    """Paged vs contiguous continuous batching on the mixed-budget ragged
    trace: same requests, same greedy sampling — tok/s plus RESIDENT KV
    BYTES. The contiguous scheduler's residency is ``slots x cache_len``
    regardless of traffic; the paged scheduler's is its block pool's
    high-water mark (on-demand allocation, blocks freed on EOS/budget at the
    exact decode step). Returns False — a CI failure — if the paged
    high-water residency does not beat the contiguous footprint."""
    from repro.core import flags
    from repro.serving.paged import PagedScheduler

    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(1, cfg.vocab_size, size=(n,)).astype(int).tolist(),
                max_new=m)
        for i, (n, m) in enumerate(zip(RAGGED_LENGTHS, RAGGED_BUDGETS))
    ]
    cache_len = max(RAGGED_LENGTHS) + max(RAGGED_BUDGETS) + 64
    block_size = 16
    # the pool the paged scheduler ACTUALLY device-allocates: half the
    # contiguous slots x cache_len token footprint (rounded to blocks, +1
    # sink). Backpressure covers any trace; the default worst-case pool
    # would match the contiguous allocation and prove nothing.
    num_blocks = (RAGGED_SLOTS * cache_len) // (2 * block_size) + 1
    total = sum(RAGGED_BUDGETS)
    with flags.overrides(deferred_decode_cache=True):
        engine = InferenceEngine(model, params, cache_len=cache_len)
        slot = SlotScheduler(engine, slots=RAGGED_SLOTS, chunk=RAGGED_CHUNK)
        paged = PagedScheduler(engine, slots=RAGGED_SLOTS, chunk=RAGGED_CHUNK,
                               block_size=block_size, num_blocks=num_blocks)

        results = {}
        outs = {}
        for name, fn in (
            ("continuous_slots", lambda: slot.serve(reqs, max(RAGGED_BUDGETS))),
            ("paged_blocks", lambda: paged.serve(reqs, max(RAGGED_BUDGETS))),
        ):
            fn()                               # warm/compile
            dt = float("inf")
            for _ in range(3):
                paged.last_peak_blocks = 0
                t0 = time.perf_counter()
                out = fn()
                dt = min(dt, time.perf_counter() - t0)
            assert [r.tokens.shape[0] for r in out] == RAGGED_BUDGETS
            results[name], outs[name] = total / dt, out
            emit(f"paged/measured_host/{name}", dt * 1e6 / total,
                 f"{total/dt:.2f} tok/s")
    for a, b in zip(outs["continuous_slots"], outs["paged_blocks"]):
        assert np.array_equal(a.tokens, b.tokens), (
            f"paged/contiguous greedy divergence on request {a.id}")

    cont = jax.eval_shape(
        lambda: model.init_cache(RAGGED_SLOTS, cache_len, cfg.cdtype()))
    cont_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                     for l in jax.tree.leaves(cont))
    pool_tree = jax.eval_shape(
        lambda: model.init_paged_cache(paged.num_blocks, block_size, cfg.cdtype()))
    pool_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                     for l in jax.tree.leaves(pool_tree))
    block_bytes = pool_bytes // paged.num_blocks
    peak_bytes = paged.last_peak_blocks * block_bytes
    emit("paged/resident_kv/contiguous_bytes", 0.0,
         f"{cont_bytes} B ({RAGGED_SLOTS} slots x {cache_len})")
    emit("paged/resident_kv/pool_alloc_bytes", 0.0,
         f"{pool_bytes} B ({paged.num_blocks} blocks x {block_size} tok "
         f"device-allocated, {cont_bytes / pool_bytes:.2f}x smaller)")
    emit("paged/resident_kv/peak_live_bytes", 0.0,
         f"{peak_bytes} B ({paged.last_peak_blocks} blocks high-water: what "
         f"live tokens actually pinned, {cont_bytes / max(peak_bytes, 1):.2f}x "
         "under contiguous)")
    emit("paged/measured_host/speedup", 0.0,
         f"{results['paged_blocks']/results['continuous_slots']:.2f}x "
         "paged vs contiguous slots")
    import json
    import os

    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_paged.json")
    with open(out_path, "w") as f:
        json.dump({
            "contiguous_bytes": int(cont_bytes),
            "pool_alloc_bytes": int(pool_bytes),
            "peak_live_bytes": int(peak_bytes),
            "peak_live_blocks": int(paged.last_peak_blocks),
            "block_size": int(block_size),
            "num_blocks": int(paged.num_blocks),
            "tok_s": {k: round(v, 2) for k, v in results.items()},
            "paged_vs_contiguous_speedup": round(
                results["paged_blocks"] / results["continuous_slots"], 4),
        }, f, indent=2, sort_keys=True)
        f.write("\n")
    # gate on the REAL device allocation, not the bookkeeping count — and
    # sanity-check the bookkeeping fits inside it
    if pool_bytes >= cont_bytes or peak_bytes > pool_bytes:
        print(f"FAIL: paged pool {pool_bytes} B (peak live {peak_bytes} B) "
              f"vs contiguous {cont_bytes} B", flush=True)
        return False
    return True


def recurrent_throughput() -> bool:
    """Slot-state continuous batching (serving/core.py RecurrentAdapter) vs
    exact-length bucket-serial serving on a mixed-budget rwkv6 trace. Every
    prompt length is distinct, so the bucketed path degenerates to one
    batch-1 generate per request — exactly what it did for recurrent
    families before the scheduling core — while the slot scheduler gathers
    and scatters O(1) recurrent state through shared decode rounds. Same
    requests, same greedy sampling, same budgets: the delta is pure
    scheduling. Returns False — a CI failure — below the 1.3x gate."""
    cfg = load_config("rwkv6-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lengths, budgets = RAGGED_LENGTHS, RAGGED_BUDGETS
    reqs = [
        Request(i, rng.integers(1, cfg.vocab_size, size=(n,)).astype(int).tolist(),
                max_new=m)
        for i, (n, m) in enumerate(zip(lengths, budgets))
    ]
    total = sum(budgets)
    # rwkv6's state is O(1) per slot (engine.unbounded_state): cache_len is
    # a formality for this family, not a capacity
    engine = InferenceEngine(model, params, cache_len=max(lengths) + 8)
    assert engine.unbounded_state
    sched = SlotScheduler(engine, slots=RAGGED_SLOTS, chunk=RAGGED_CHUNK)

    results = {}
    outs = {}
    for name, fn in (
        ("bucket_serial", lambda: serve_bucketed(engine, reqs, max(budgets))),
        ("slot_state_continuous", lambda: sched.serve(reqs, max(budgets))),
    ):
        fn()                                   # warm/compile
        dt = float("inf")
        for _ in range(3):                     # best-of-3: host-noise robust
            t0 = time.perf_counter()
            out = fn()
            dt = min(dt, time.perf_counter() - t0)
        assert [r.tokens.shape[0] for r in out] == budgets
        results[name], outs[name] = total / dt, out
        emit(f"recurrent/measured_host/{name}", dt * 1e6 / total,
             f"{total/dt:.2f} tok/s")
    for a, b in zip(outs["bucket_serial"], outs["slot_state_continuous"]):
        assert np.array_equal(a.tokens, b.tokens), (
            f"slot-state/bucketed greedy divergence on request {a.id}")
    speedup = results["slot_state_continuous"] / results["bucket_serial"]
    emit("recurrent/measured_host/speedup", 0.0,
         f"{speedup:.2f}x slot-state continuous vs exact-length bucket-serial "
         "(gate: >= 1.3x)")
    if speedup < 1.3:
        print(f"FAIL: recurrent: slot-state continuous speedup {speedup:.2f}x "
              "did not clear the 1.3x gate", flush=True)
        return False
    return True


def spec_decode() -> bool:
    """Speculative decoding (serving/spec.py + lm_verify): decode forward
    passes per generated token, weight bytes streamed per accepted token,
    and acceptance rate, on a repetitive trace (where the zero-weight
    n-gram prompt-lookup drafter shines) vs a random one. The engine runs
    the paper's W8A8 weights so bytes-per-token prices the registry's
    actual storage (``bits_per_weight``). CI gates:

    - greedy speculative output must be TOKEN-IDENTICAL to vanilla decode
      on both traces (exactness is the whole point — the chunk only
      amortizes the weight stream);
    - the repetitive trace must need >= 1.5x fewer decode forward passes
      per generated token than vanilla's 1.0.
    """
    import json
    import os

    from repro.core.quant import QuantizedTensor
    from repro.serving.spec import NgramDrafter

    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    steps, spec_k = 96, 4
    engine = InferenceEngine(model, params, cache_len=32 + steps + spec_k,
                             quantize="int8")
    # bytes one decode forward pass streams: every weight leaf read once
    # (LlamaF §II-B's regime) — quantized leaves at their format's storage
    # footprint (qvalues + scales), exempt leaves (norms etc.) at float width
    weight_bytes = sum(
        leaf.nbytes() if isinstance(leaf, QuantizedTensor) else leaf.nbytes
        for leaf in jax.tree.leaves(
            engine.params,
            is_leaf=lambda x: isinstance(x, QuantizedTensor))
    )
    rng = np.random.default_rng(0)
    traces = {
        "repetitive": ([11, 23, 7, 5] * 6),
        "random": rng.integers(1, cfg.vocab_size, (24,)).astype(int).tolist(),
    }
    ok = True
    headline: dict[str, dict] = {"spec_k": spec_k, "steps": steps,
                                 "weight_bytes_per_pass": int(weight_bytes)}
    for name, prompt in traces.items():
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        van = engine.generate(batch, steps)
        res = engine.generate(batch, steps, spec_k=spec_k,
                              drafter=NgramDrafter())
        if not np.array_equal(np.asarray(van.tokens), np.asarray(res.tokens)):
            print(f"FAIL: spec/{name}: greedy speculative output diverged "
                  "from vanilla decode", flush=True)
            ok = False
        st = res.spec_stats
        fwd_per_tok = st["verify_steps"] / st["generated"]
        acc = st["accepted"] / max(st["drafted"], 1)
        bytes_per_tok = weight_bytes * fwd_per_tok
        emit(f"spec/{name}/fwd_per_token", 0.0,
             f"{fwd_per_tok:.3f} (vanilla 1.0 -> {1 / fwd_per_tok:.2f}x fewer "
             "weight streams)")
        emit(f"spec/{name}/acceptance_rate", 0.0,
             f"{acc:.3f} ({st['accepted']}/{st['drafted']} drafts)")
        emit(f"spec/{name}/weight_MB_per_token", 0.0,
             f"{bytes_per_tok / 1e6:.2f} MB (vanilla {weight_bytes / 1e6:.2f})")
        headline[name] = {
            "fwd_per_token": round(fwd_per_tok, 4),
            "acceptance_rate": round(acc, 4),
            "weight_bytes_per_token": int(bytes_per_tok),
            "verify_steps": st["verify_steps"],
            "generated": st["generated"],
            "token_identical_to_vanilla": bool(
                np.array_equal(np.asarray(van.tokens), np.asarray(res.tokens))),
        }
    rep = headline["repetitive"]["fwd_per_token"]
    emit("spec/repetitive/speedup_gate", 0.0,
         f"{1 / rep:.2f}x fewer forward passes (gate: >= 1.5x)")
    if 1.0 / rep < 1.5:
        print(f"FAIL: spec: repetitive-trace forward passes per token {rep:.3f} "
              "did not clear the 1.5x amortization gate", flush=True)
        ok = False
    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_spec.json")
    with open(out_path, "w") as f:
        json.dump(headline, f, indent=2, sort_keys=True)
        f.write("\n")
    return ok


def run():
    measured_engine_toks()
    measured_gqmv_gops()
    derived_v5e_roofline()


def run_ragged():
    return ragged_throughput()


def run_paged():
    return paged_throughput()


def run_recurrent():
    return recurrent_throughput()


def run_spec():
    return spec_decode()


if __name__ == "__main__":
    run()
