"""Paper Table V: perplexity of W32A32 vs quantized presets (GS from cfg).

Paper: TinyLlama on WikiText-2, 7.05 -> 7.09 (+0.57%) at W8A8. WikiText-2
is not available offline, so we preserve the comparison STRUCTURE: train a
small TinyLlama-family model on a deterministic synthetic corpus, then
evaluate the SAME held-out data under fp32 weights and each quantized
preset — int8 (the paper row), fp8 (e4m3 value grid), and mixed3 (attn/ffn
int3, embed/classifier int8) — reporting PPL, relative degradation, and
mean logit KL per preset.

CI gate: the sub-4-bit mixed3 preset must stay within ``MIXED3_PPL_GATE``
relative PPL degradation of the fp32 baseline (int8 runs well under 1%;
mixed3's coarser grid costs more, and the gate pins how much more this
repo accepts before a format regression fails the run).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.policy import quantize_params
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build, load_config
from repro.optim import adamw
from repro.train.loop import lm_loss, make_train_step


# max relative PPL degradation the sub-4-bit preset may cost on the
# held-out synthetic eval before the run fails. Measured on the current
# tree: int8 0.18%, fp8 0.44%, mixed3 10.1% (the reduced synthetic model
# at GS=32 punishes a 7-level grid much harder than the paper's 1.1B at
# GS=256 would). The gate separates "coarse but working" from "broken
# pack/unpack or scale association", which lands at hundreds of percent.
MIXED3_PPL_GATE = 15.0

PRESETS = ("int8", "fp8", "mixed3")


def run() -> bool:
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))

    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt = adamw.init(params)
    for i in range(60):
        params, opt, m = step(params, opt, jax.tree.map(jnp.asarray, data.batch_at(i)))

    # held-out evaluation (steps the model never trained on)
    eval_batches = [jax.tree.map(jnp.asarray, data.batch_at(1000 + i)) for i in range(4)]

    @jax.jit
    def eval_nll(p, batch):
        logits = model.forward(p, batch, remat=False)
        return lm_loss(logits, batch["labels"]), logits

    t0 = time.perf_counter()
    nll_f, logfs = [], []
    for b in eval_batches:
        lf, logf = eval_nll(params, b)
        nll_f.append(float(lf))
        logfs.append(jax.nn.log_softmax(logf.astype(jnp.float32), -1))
    ppl_f = float(np.exp(np.mean(nll_f)))

    degradation = {}
    for preset in PRESETS:
        qparams = quantize_params(params, cfg.group_size, formats=preset)
        nll_q, kls = [], []
        for b, pf in zip(eval_batches, logfs):
            lq, logq = eval_nll(qparams, b)
            nll_q.append(float(lq))
            pq = jax.nn.log_softmax(logq.astype(jnp.float32), -1)
            kls.append(float(jnp.mean(jnp.sum(jnp.exp(pf) * (pf - pq), axis=-1))))
        ppl_q = float(np.exp(np.mean(nll_q)))
        degradation[preset] = 100 * (ppl_q - ppl_f) / ppl_f
        tag = "w8a8" if preset == "int8" else preset   # the paper's row name
        emit(f"table5/ppl_{tag}_gs{cfg.group_size}", 0.0, f"{ppl_q:.4f}")
        emit(f"table5/{tag}_degradation_pct", 0.0,
             f"{degradation[preset]:.3f}%")
        emit(f"table5/{tag}_mean_logit_kl", 0.0, f"{np.mean(kls):.3e}")
    us = (time.perf_counter() - t0) * 1e6 / ((1 + len(PRESETS)) * len(eval_batches))
    emit("table5/ppl_w32a32", us, f"{ppl_f:.4f}")

    emit("table5/mixed3_ppl_gate", 0.0,
         f"{degradation['mixed3']:.3f}% (gate: <= {MIXED3_PPL_GATE}%)")
    if degradation["mixed3"] > MIXED3_PPL_GATE:
        print(f"FAIL: quality: mixed3 PPL degradation "
              f"{degradation['mixed3']:.3f}% exceeds the "
              f"{MIXED3_PPL_GATE}% gate", flush=True)
        return False
    return True


if __name__ == "__main__":
    run()
