"""Paper Table V: perplexity of W32A32 vs W8A8 (GS=256).

Paper: TinyLlama on WikiText-2, 7.05 -> 7.09 (+0.57%). WikiText-2 is not
available offline, so we preserve the comparison STRUCTURE: train a small
TinyLlama-family model on a deterministic synthetic corpus, then evaluate
the SAME held-out data under fp32 weights and W8A8-quantized weights, and
report both PPLs, the relative degradation, and the mean logit KL.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.policy import quantize_params
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build, load_config
from repro.optim import adamw
from repro.train.loop import lm_loss, make_train_step


def run():
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))

    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt = adamw.init(params)
    for i in range(60):
        params, opt, m = step(params, opt, jax.tree.map(jnp.asarray, data.batch_at(i)))

    # held-out evaluation (steps the model never trained on)
    eval_batches = [jax.tree.map(jnp.asarray, data.batch_at(1000 + i)) for i in range(4)]
    qparams = quantize_params(params, cfg.group_size)

    @jax.jit
    def eval_nll(p, batch):
        logits = model.forward(p, batch, remat=False)
        return lm_loss(logits, batch["labels"]), logits

    t0 = time.perf_counter()
    nll_f, nll_q, kls = [], [], []
    for b in eval_batches:
        lf, logf = eval_nll(params, b)
        lq, logq = eval_nll(qparams, b)
        nll_f.append(float(lf))
        nll_q.append(float(lq))
        pf = jax.nn.log_softmax(logf.astype(jnp.float32), -1)
        pq = jax.nn.log_softmax(logq.astype(jnp.float32), -1)
        kls.append(float(jnp.mean(jnp.sum(jnp.exp(pf) * (pf - pq), axis=-1))))
    us = (time.perf_counter() - t0) * 1e6 / (2 * len(eval_batches))

    ppl_f = float(np.exp(np.mean(nll_f)))
    ppl_q = float(np.exp(np.mean(nll_q)))
    emit("table5/ppl_w32a32", us, f"{ppl_f:.4f}")
    emit("table5/ppl_w8a8_gs%d" % cfg.group_size, us, f"{ppl_q:.4f}")
    emit("table5/ppl_degradation_pct", us, f"{100*(ppl_q-ppl_f)/ppl_f:.3f}%")
    emit("table5/mean_logit_kl", us, f"{np.mean(kls):.3e}")


if __name__ == "__main__":
    run()
