"""`kvquant` suite: quantized KV-cache pool — bytes per decode step + parity.

The decode-side twin of the weight-format suite (quant_bench.py): once
weights stream at 3-4 bits, the KV cache is the next HBM term (§II-B
applied to the cache axis). ``kv_quant`` stores the paged block pool and
the contiguous kvt cache at int8/fp8 width with per-row f32 scales; the
paged attention kernel dequantizes in VMEM, so per-step cache traffic
drops to storage width + the scale rows.

Measured here, on the full-size head geometry (head_dim 64 — the scale
overhead is 4/head_dim per element, so narrow reduced heads would flatter
nothing and distort the fp16 gate):

  pool bytes/token     device bytes per cached token position, per format
  paged/contiguous/direct greedy parity of every kv_quant engine
  agreement vs float   token agreement of quantized vs float decode

CI gates (either failing exits non-zero):
  - quantized pool bytes/token >= 1.8x lower than the float paged
    baseline (the PR 4 pool at the config compute dtype);
  - quantized pool bytes/token <= 0.55x a HYPOTHETICAL fp16 pool —
    the stricter bound that prices the scale overhead honestly.

Headline numbers land in BENCH_kvquant.json.
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.models.registry import build, load_config
from repro.serving.core import Request
from repro.serving.engine import InferenceEngine
from repro.serving.paged import serve_paged

KV_FORMATS = ("int8", "fp8")
GATE_VS_FLOAT = 1.8
GATE_VS_FP16 = 0.55

PROMPTS = [[5, 3], [7, 1, 4], list(range(1, 11)), list(range(2, 16))]
BUDGETS = [8, 6, 8, 6]
STEPS = max(BUDGETS)


def _pool_bytes(model, num_blocks: int, block_size: int, dtype) -> int:
    tree = jax.eval_shape(
        lambda: model.init_paged_cache(num_blocks, block_size, dtype))
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def run() -> bool:
    # reduced depth/width but FULL head_dim: the per-row scale overhead is
    # 4 bytes per head_dim elements, and the gates price exactly that
    cfg = dataclasses.replace(load_config("tinyllama-1.1b").reduced(),
                              head_dim=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 48
    block_size, slots = 8, 4
    num_blocks = slots * (cache_len // block_size) + 1

    reqs = [Request(id=i, tokens=p, max_new=b)
            for i, (p, b) in enumerate(zip(PROMPTS, BUDGETS))]

    float_engine = InferenceEngine(model, params, cache_len=cache_len)
    float_out = serve_paged(float_engine, reqs, STEPS, slots=slots,
                            block_size=block_size)
    float_bytes = _pool_bytes(model, num_blocks, block_size, cfg.cdtype())
    fp16_bytes = _pool_bytes(model, num_blocks, block_size, jnp.float16)
    tokens_pooled = num_blocks * block_size
    emit("kvquant/float/pool_bytes_per_token", 0.0,
         f"{float_bytes / tokens_pooled:.1f} B ({cfg.cdtype().name} pool)")

    ok = True
    headline: dict = {
        "cache_len": cache_len, "block_size": block_size,
        "float_pool_bytes": int(float_bytes),
        "fp16_pool_bytes": int(fp16_bytes),
        "gate_vs_float_min": GATE_VS_FLOAT, "gate_vs_fp16_max": GATE_VS_FP16,
        "formats": {},
    }
    for kvq in KV_FORMATS:
        eng = InferenceEngine(model, params, cache_len=cache_len,
                              kv_quant=kvq)
        q_out = serve_paged(eng, reqs, STEPS, slots=slots,
                            block_size=block_size)
        # parity: the paged quantized path must equal the contiguous
        # quantized decode token-for-token (same association, same rows)
        direct_ok = True
        for r, q in zip(reqs, q_out):
            d = eng.generate({"tokens": jnp.asarray([r.tokens], jnp.int32)},
                             r.max_new)
            if not np.array_equal(np.asarray(d.tokens[0]),
                                  np.asarray(q.tokens)):
                direct_ok = False
        if not direct_ok:
            print(f"FAIL: kvquant/{kvq}: paged serve diverged from the "
                  "contiguous quantized decode", flush=True)
            ok = False
        agree = np.mean([
            np.mean(np.asarray(a.tokens) == np.asarray(b.tokens))
            for a, b in zip(float_out, q_out)])

        q_bytes = _pool_bytes(eng.model, num_blocks, block_size,
                              eng.cfg.cdtype())
        vs_float = float_bytes / q_bytes
        vs_fp16 = q_bytes / fp16_bytes
        emit(f"kvquant/{kvq}/pool_bytes_per_token", 0.0,
             f"{q_bytes / tokens_pooled:.1f} B (storage + f32 scale rows)")
        emit(f"kvquant/{kvq}/bytes_vs_float", 0.0,
             f"{vs_float:.2f}x fewer (gate: >= {GATE_VS_FLOAT}x)")
        emit(f"kvquant/{kvq}/bytes_vs_fp16", 0.0,
             f"{vs_fp16:.3f}x fp16 (gate: <= {GATE_VS_FP16}x)")
        emit(f"kvquant/{kvq}/paged_eq_contiguous", 0.0, str(direct_ok))
        emit(f"kvquant/{kvq}/token_agreement_vs_float", 0.0, f"{agree:.3f}")
        if vs_float < GATE_VS_FLOAT:
            print(f"FAIL: kvquant/{kvq}: pool bytes only {vs_float:.2f}x "
                  f"under the float baseline, gate is >= {GATE_VS_FLOAT}x",
                  flush=True)
            ok = False
        if vs_fp16 > GATE_VS_FP16:
            print(f"FAIL: kvquant/{kvq}: pool bytes {vs_fp16:.3f}x fp16, "
                  f"gate is <= {GATE_VS_FP16}x", flush=True)
            ok = False
        headline["formats"][kvq] = {
            "pool_bytes": int(q_bytes),
            "bytes_vs_float": round(vs_float, 4),
            "bytes_vs_fp16": round(vs_fp16, 4),
            "paged_eq_contiguous": bool(direct_ok),
            "token_agreement_vs_float": round(float(agree), 4),
        }

    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_kvquant.json")
    with open(out_path, "w") as f:
        json.dump(headline, f, indent=2, sort_keys=True)
        f.write("\n")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
