"""Benchmark harness: one module per paper table. CSV: name,us_per_call,derived.

  table2 -> profile_forward  (paper Table II: runtime distribution)
  table4 -> quant_error      (paper Table IV: quantization error stats)
  table5 -> quality          (paper Table V: PPL fp32 vs W8A8)
  table6 -> throughput       (paper Table VI: tok/s, GOPS, scheduling)
  kernels -> kernel_bench    (GQMV/GQMM kernel-shape sweep, interpret mode)
"""

import sys


def main() -> None:
    from benchmarks import kernel_bench, profile_forward, quant_error, quality, throughput

    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = {
        "table2": profile_forward.run,
        "table4": quant_error.run,
        "table5": quality.run,
        "table6": throughput.run,
        "kernels": kernel_bench.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and only != name:
            continue
        fn()


if __name__ == "__main__":
    main()
