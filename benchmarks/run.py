"""Benchmark harness: one module per paper table. CSV: name,us_per_call,derived.

  table2 -> profile_forward  (paper Table II: runtime distribution)
  table4 -> quant_error      (paper Table IV: quantization error stats)
  table5 -> quality          (paper Table V: PPL fp32 vs W8A8)
  table6 -> throughput       (paper Table VI: tok/s, GOPS, scheduling)
  kernels -> kernel_bench    (GQMV/GQMM kernel-shape sweep, interpret mode)
  ragged -> throughput       (ragged trace: bucket-serial vs continuous slots;
                              exits non-zero if a sanitize=False scheduler
                              loses more than 2% tok/s vs the default run —
                              repro-san's disabled-mode overhead gate)
  quant -> quant_bench       (per-format bytes/weight, decode us/call, errors;
                              writes BENCH_quant.json; exits non-zero if the
                              mixed3 preset's weight bytes/step exceed 0.8x
                              int4's)
  kvquant -> kvquant_bench   (quantized KV pool: bytes/token per kv_quant
                              format + paged/contiguous parity; writes
                              BENCH_kvquant.json; exits non-zero below the
                              1.8x-vs-float or above the 0.55x-vs-fp16
                              pool-bytes gates)
  paged -> throughput        (paged vs contiguous slots: tok/s + resident KV
                              bytes; exits non-zero if paged residency does
                              not beat the contiguous footprint)
  spec -> throughput         (speculative decode: forward passes + weight
                              bytes per token, acceptance rate; writes
                              BENCH_spec.json; exits non-zero if greedy
                              speculative output diverges from vanilla or
                              the repetitive trace misses the 1.5x gate)
  recurrent -> throughput    (rwkv6 slot-state continuous batching vs
                              exact-length bucket-serial; exits non-zero
                              below the 1.3x tok/s gate)
  xray -> xray_bench         (bytes-per-decode-step contract: compiled-HLO
                              HBM traffic vs the registry nbytes model for
                              tinyllama int8/int4/mixed; exits non-zero on
                              >15% discrepancy — DESIGN.md §14)

A suite returning False marks the run failed (exit 1).
"""

import os
import sys

# Allow both `python benchmarks/run.py` and `python -m benchmarks.run`.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> int:
    from benchmarks import (
        kernel_bench,
        kvquant_bench,
        profile_forward,
        quant_bench,
        quant_error,
        quality,
        throughput,
        xray_bench,
    )

    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = {
        "table2": profile_forward.run,
        "table4": quant_error.run,
        "table5": quality.run,
        "table6": throughput.run,
        "kernels": kernel_bench.run,
        "ragged": throughput.run_ragged,
        "quant": quant_bench.run,
        "kvquant": kvquant_bench.run,
        "paged": throughput.run_paged,
        "spec": throughput.run_spec,
        "recurrent": throughput.run_recurrent,
        "xray": xray_bench.run,
    }
    if only is not None and only not in suites:
        print(f"unknown suite {only!r}; valid: {', '.join(suites)}", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if only and only != name:
            continue
        if fn() is False:
            failed.append(name)
    if failed:
        print(f"failed suites: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
