"""Pallas GQMV/GQMM kernels vs the pure-jnp oracle (paper Alg. 1).

Kernels execute in interpret mode (CPU container); shapes/dtypes/GS swept.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.quant import (
    quantize_activation,
    quantize_fp8,
    quantize_groupwise,
    quantize_int3,
    quantize_int4,
)
from repro.kernels import ops
from repro.kernels.gqmv import (
    gqmm_fp8_pallas,
    gqmm_int3_pallas,
    gqmm_int4_pallas,
    gqmm_pallas,
    gqmv_fp8_pallas,
    gqmv_int3_pallas,
    gqmv_int4_pallas,
    gqmv_pallas,
)
from repro.kernels.ref import (
    gqmm_fp8_ref,
    gqmm_int3_ref,
    gqmm_int4_ref,
    gqmm_ref,
    gqmv_fp8_ref,
    gqmv_int3_ref,
    gqmv_int4_ref,
    gqmv_ref,
)


def _mk(m, n, gs, seed=0, b=None):
    rng = np.random.default_rng(seed)
    w = quantize_groupwise(
        jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), gs
    )
    shape = (n,) if b is None else (b, n)
    x = quantize_activation(
        jnp.asarray(rng.normal(size=shape).astype(np.float32)), gs
    )
    return w, x


GQMV_SHAPES = [
    # (m, n, GS) - includes paper-exact TinyLlama dims (2048, 5632, GS=256)
    (8, 64, 32),
    (128, 256, 256),
    (256, 2048, 256),     # kernel1 column size = dim (paper §III-B)
    (2048, 5632, 256),    # kernel2 column size = hidden_dim (paper §III-B)
    (96, 384, 128),
    (512, 512, 64),
]


@pytest.mark.parametrize("m,n,gs", GQMV_SHAPES)
def test_gqmv_matches_ref(m, n, gs):
    w, x = _mk(m, n, gs, seed=m + n)
    got = gqmv_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                      group_size=gs, interpret=True)
    want = gqmv_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,gs,b", [
    (64, 128, 32, 4),
    (128, 512, 256, 16),
    (256, 2048, 256, 8),
    (32, 256, 64, 1),
    (2048, 5632, 256, 2),
])
def test_gqmm_matches_ref(m, n, gs, b):
    w, x = _mk(m, n, gs, seed=m + n + b, b=b)
    got = gqmm_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                      group_size=gs, interpret=True)
    want = gqmm_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


@pytest.mark.parametrize("block_m,block_n", [(8, 64), (16, 128), (32, 256)])
def test_gqmv_block_shape_sweep(block_m, block_n):
    """Block shape is a tuning knob; result must be invariant to it."""
    w, x = _mk(64, 512, 64, seed=7)
    want = gqmv_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=64)
    got = gqmv_pallas(w.qvalues, w.scales, x.qvalues, x.scales, group_size=64,
                      block_m=block_m, block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


def test_gqmv_against_fp32_matmul():
    """GQMV approximates the fp32 matmul within dequantization error."""
    rng = np.random.default_rng(11)
    wf = rng.normal(scale=0.05, size=(256, 1024)).astype(np.float32)
    xf = rng.normal(size=(1024,)).astype(np.float32)
    w = quantize_groupwise(jnp.asarray(wf), 256)
    x = quantize_activation(jnp.asarray(xf), 256)
    got = gqmv_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                      group_size=256, interpret=True)
    exact = wf @ xf
    # relative Frobenius error small (paper Table IV: mean element error 2.65e-4)
    rel = np.linalg.norm(np.asarray(got) - exact) / np.linalg.norm(exact)
    assert rel < 0.02, rel


def test_ops_dispatch_xla_equals_interpret():
    w, x = _mk(128, 512, 128, seed=5)
    a = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="xla")
    b = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-4)


def test_quantized_matmul_shapes():
    rng = np.random.default_rng(9)
    w = quantize_groupwise(jnp.asarray(rng.normal(size=(96, 256)).astype(np.float32)), 64)
    y1 = ops.quantized_matmul(jnp.ones((256,)), w, impl="xla")
    y2 = ops.quantized_matmul(jnp.ones((4, 256)), w, impl="xla")
    y3 = ops.quantized_matmul(jnp.ones((2, 3, 256)), w, impl="xla")
    assert y1.shape == (96,)
    assert y2.shape == (4, 96)
    assert y3.shape == (2, 3, 96)
    np.testing.assert_allclose(np.asarray(y3[0, 0]), np.asarray(y1), rtol=1e-5)


@settings(deadline=None, max_examples=15)
@given(
    mi=st.integers(1, 4),
    gi=st.integers(1, 4),
    gs=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_gqmv_pallas_vs_ref(mi, gi, gs, seed):
    m, n = 8 * mi, gs * gi
    w, x = _mk(m, n, gs, seed=seed)
    got = gqmv_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                      group_size=gs, interpret=True)
    want = gqmv_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# packed int4 (unpack-in-VMEM kernels vs XLA oracle)
# ---------------------------------------------------------------------------

def _mk4(m, n, gs, seed=0, b=None):
    rng = np.random.default_rng(seed)
    w = quantize_int4(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), gs)
    shape = (n,) if b is None else (b, n)
    x = quantize_activation(
        jnp.asarray(rng.normal(size=shape).astype(np.float32)), gs
    )
    return w, x


@pytest.mark.parametrize("m,n,gs", [
    (8, 64, 32),
    (128, 256, 256),
    (256, 1024, 256),     # single n-block (bn=1024): bit-exact regime
    (96, 384, 128),
])
def test_gqmv_int4_interpret_exact_vs_ref(m, n, gs):
    """Single-n-block shapes: the interpret-mode kernel and the XLA oracle
    share the combined-scale association -> bitwise-equal outputs."""
    w, x = _mk4(m, n, gs, seed=m + n)
    got = gqmv_int4_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                           group_size=gs, interpret=True)
    want = gqmv_int4_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,gs", [
    (2048, 5632, 256),    # paper kernel2 dims; multi-n-block accumulation
    (256, 2048, 256),
])
def test_gqmv_int4_multiblock_matches_ref(m, n, gs):
    w, x = _mk4(m, n, gs, seed=m + n)
    got = gqmv_int4_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                           group_size=gs, interpret=True)
    want = gqmv_int4_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n,gs,b", [
    (64, 128, 32, 4),
    (128, 512, 256, 16),
    (2048, 5632, 256, 2),
    (32, 256, 64, 1),
])
def test_gqmm_int4_matches_ref(m, n, gs, b):
    w, x = _mk4(m, n, gs, seed=m + n + b, b=b)
    got = gqmm_int4_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                           group_size=gs, interpret=True)
    want = gqmm_int4_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


def test_int4_dispatch_xla_equals_interpret():
    w, x = _mk4(128, 512, 128, seed=5)
    a = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="xla", kernel="gqmv_int4")
    b = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="interpret", kernel="gqmv_int4")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_int4_quantized_matmul_approximates_fp32():
    """End-to-end dispatch through the registry's kernel hook: int4 GQMV
    approximates the fp32 matmul within dequantization error."""
    rng = np.random.default_rng(13)
    wf = rng.normal(scale=0.05, size=(256, 1024)).astype(np.float32)
    xf = rng.normal(size=(1024,)).astype(np.float32)
    w = quantize_int4(jnp.asarray(wf), 256)
    got = ops.quantized_matmul(jnp.asarray(xf), w, impl="interpret")
    exact = wf @ xf
    rel = np.linalg.norm(np.asarray(got) - exact) / np.linalg.norm(exact)
    assert rel < 0.2, rel   # ~17x the int8 error budget (4 bits vs 8)


# ---------------------------------------------------------------------------
# packed int3 (8 values per 3 bytes; unpack-in-VMEM kernels vs XLA oracle)
# ---------------------------------------------------------------------------

def _mkq(fmt_fn, m, n, gs, seed=0, b=None):
    rng = np.random.default_rng(seed)
    w = fmt_fn(jnp.asarray(rng.normal(size=(m, n)).astype(np.float32)), gs)
    shape = (n,) if b is None else (b, n)
    x = quantize_activation(
        jnp.asarray(rng.normal(size=shape).astype(np.float32)), gs
    )
    return w, x


@pytest.mark.parametrize("m,n,gs", [
    (8, 64, 32),
    (128, 256, 256),
    (256, 1024, 256),     # single n-block: bit-exact regime
    (96, 384, 128),
])
def test_gqmv_int3_interpret_exact_vs_ref(m, n, gs):
    """Integer datapath: the interpret-mode kernel and the XLA oracle share
    the combined-scale association -> bitwise-equal outputs (like int4)."""
    w, x = _mkq(quantize_int3, m, n, gs, seed=m + n)
    got = gqmv_int3_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                           group_size=gs, interpret=True)
    want = gqmv_int3_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,gs", [
    (2048, 5632, 256),    # paper kernel2 dims; multi-n-block accumulation
    (256, 2048, 256),
])
def test_gqmv_int3_multiblock_matches_ref(m, n, gs):
    w, x = _mkq(quantize_int3, m, n, gs, seed=m + n)
    got = gqmv_int3_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                           group_size=gs, interpret=True)
    want = gqmv_int3_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    # cross-block f32 accumulation order differs -> tolerance, not bit-equal
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,gs,b", [
    (64, 128, 32, 4),
    (128, 512, 256, 16),
    (2048, 5632, 256, 2),
    (32, 256, 64, 1),
])
def test_gqmm_int3_matches_ref(m, n, gs, b):
    w, x = _mkq(quantize_int3, m, n, gs, seed=m + n + b, b=b)
    got = gqmm_int3_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                           group_size=gs, interpret=True)
    want = gqmm_int3_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


def test_int3_dispatch_xla_equals_interpret():
    w, x = _mkq(quantize_int3, 128, 512, 128, seed=5)
    a = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="xla", kernel="gqmv_int3")
    b = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="interpret", kernel="gqmv_int3")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_int3_quantized_matmul_approximates_fp32():
    """3-bit grid has 7 levels: error ~2x int4's but the registry dispatch
    must still land in the same ballpark as the fp32 matmul."""
    rng = np.random.default_rng(17)
    wf = rng.normal(scale=0.05, size=(256, 1024)).astype(np.float32)
    xf = rng.normal(size=(1024,)).astype(np.float32)
    w = quantize_int3(jnp.asarray(wf), 256)
    got = ops.quantized_matmul(jnp.asarray(xf), w, impl="interpret")
    exact = wf @ xf
    rel = np.linalg.norm(np.asarray(got) - exact) / np.linalg.norm(exact)
    assert rel < 0.4, rel   # measured ~0.17 on this init family


# ---------------------------------------------------------------------------
# fp8 (e4m3 weights, float datapath; tolerance-based vs oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,gs", [
    (8, 64, 32),
    (128, 256, 256),
    (256, 2048, 256),
    (2048, 5632, 256),
])
def test_gqmv_fp8_matches_ref(m, n, gs):
    """Float datapath: no exact integer stage, so the comparison is
    tolerance-based (f32 dot reassociation across lanes may differ)."""
    w, x = _mkq(quantize_fp8, m, n, gs, seed=m + n)
    got = gqmv_fp8_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                          group_size=gs, interpret=True)
    want = gqmv_fp8_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,gs,b", [
    (64, 128, 32, 4),
    (128, 512, 256, 16),
    (2048, 5632, 256, 2),
    (32, 256, 64, 1),
])
def test_gqmm_fp8_matches_ref(m, n, gs, b):
    w, x = _mkq(quantize_fp8, m, n, gs, seed=m + n + b, b=b)
    got = gqmm_fp8_pallas(w.qvalues, w.scales, x.qvalues, x.scales,
                          group_size=gs, interpret=True)
    want = gqmm_fp8_ref(w.qvalues, w.scales, x.qvalues, x.scales, group_size=gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=1e-4)


def test_fp8_dispatch_xla_equals_interpret():
    w, x = _mkq(quantize_fp8, 128, 512, 128, seed=5)
    a = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="xla", kernel="gqmv_fp8")
    b = ops.gqmv(w.qvalues, w.scales, x.qvalues, x.scales,
                 group_size=128, impl="interpret", kernel="gqmv_fp8")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-4)


def test_fp8_quantized_matmul_approximates_fp32():
    rng = np.random.default_rng(19)
    wf = rng.normal(scale=0.05, size=(256, 1024)).astype(np.float32)
    xf = rng.normal(size=(1024,)).astype(np.float32)
    w = quantize_fp8(jnp.asarray(wf), 256)
    got = ops.quantized_matmul(jnp.asarray(xf), w, impl="interpret")
    exact = wf @ xf
    rel = np.linalg.norm(np.asarray(got) - exact) / np.linalg.norm(exact)
    assert rel < 0.05, rel   # e4m3 weights: near the int8 error budget


def test_int4_quantized_matmul_batched_shapes():
    rng = np.random.default_rng(14)
    w = quantize_int4(jnp.asarray(rng.normal(size=(96, 256)).astype(np.float32)), 64)
    y1 = ops.quantized_matmul(jnp.ones((256,)), w, impl="xla")
    y3 = ops.quantized_matmul(jnp.ones((2, 3, 256)), w, impl="xla")
    assert y1.shape == (96,)
    assert y3.shape == (2, 3, 96)
    # GQMV and GQMM oracles associate the fp32 scale product differently
    np.testing.assert_allclose(np.asarray(y3[0, 0]), np.asarray(y1), rtol=5e-4, atol=1e-4)
