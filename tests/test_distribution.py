"""Distribution-layer tests: partition rules, HLO analyzer, mesh planning,
plus one real (tiny-mesh) sharded train step for end-to-end validity."""

import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import batch_specs, cache_spec, param_spec, param_specs
from repro.launch import hlo_analysis

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MESH16 = SimpleNamespace(shape={"data": 16, "model": 16},
                         axis_names=("data", "model"))


def _spec(path, shape, mode="train"):
    return param_spec(path, shape, mesh=MESH16, mode=mode)


def test_column_parallel_rules():
    # (L, out, in): out -> model, in -> data (FSDP, train only)
    assert _spec("layers/attn/wqkv", (24, 4096, 2048)) == P(None, "model", "data")
    assert _spec("layers/attn/wqkv", (24, 4096, 2048), "serve") == P(None, "model", None)
    assert _spec("layers/mlp/w13", (24, 16384, 2048)) == P(None, "model", "data")


def test_row_parallel_rules():
    assert _spec("layers/attn/wo", (24, 2048, 2048)) == P(None, "data", "model")
    assert _spec("layers/mlp/w2", (24, 2048, 8192), "serve") == P(None, None, "model")


def test_quantized_leaf_rules():
    # scales of a row-parallel int8 weight: groups axis follows the model axis
    assert _spec("layers/mlp/w2/qvalues", (24, 2048, 8192), "serve") == P(None, None, "model")
    assert _spec("layers/mlp/w2/scales", (24, 2048, 64), "serve") == P(None, None, "model")
    # col-parallel scales shard the out dim, never get FSDP on the group axis
    assert _spec("layers/attn/wqkv/scales", (24, 4096, 8), "serve") == P(None, "model", None)


def test_moe_expert_parallel():
    assert _spec("layers/mlp/experts/w13", (40, 16, 21504, 6144)) == \
        P(None, "model", None, "data")
    # within-expert contraction never sharded (groups stay whole)
    assert _spec("layers/mlp/experts/w2", (40, 16, 6144, 10752), "serve") == \
        P(None, "model", None, None)


def test_embed_and_small_leaves():
    assert _spec("embed", (92544, 2048)) == P("model", "data")
    assert _spec("layers/att_norm", (24, 2048)) == P(None, None)
    assert _spec("layers/mlp/router_w", (40, 16, 6144)) == P(None, None, None)
    # indivisible dims stay unsharded rather than erroring
    assert _spec("layers/attn/wo", (24, 2048, 2047)) == P(None, "data", None)


def test_cache_rules():
    # (L,B,T,KV,hd): batch -> data, seq -> model
    assert cache_spec("k", (24, 128, 32768, 8, 128), mesh=MESH16, batch=128) == \
        P(None, "data", "model", None, None)
    # batch=1 long context: T over both axes
    assert cache_spec("shared_k", (13, 1, 524288, 32, 112), mesh=MESH16, batch=1) == \
        P(None, None, ("data", "model"), None, None)
    # rwkv state: heads -> model
    assert cache_spec("wkv", (32, 128, 64, 64, 64), mesh=MESH16, batch=128) == \
        P(None, "data", "model", None, None)


def test_batch_specs_divisibility():
    mesh = SimpleNamespace(shape={"data": 16, "model": 16}, axis_names=("data", "model"))
    specs = batch_specs({"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
                         "odd": jax.ShapeDtypeStruct((3, 5), jnp.int32)}, mesh)
    assert specs["tokens"] == P(("data",), None)
    assert specs["odd"] == P(None, None)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%g), channel_id=1
  %d = f32[8,8]{1,0} dot(%ar, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%p, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_analyzer_trip_count_expansion():
    rep = hlo_analysis.analyze(HLO_SAMPLE)
    # dot: 2*8*8*8 flops, x10 trips
    assert rep.flops == 10 * 2 * 8 * 8 * 8
    assert rep.bytes_by_kind["all-reduce"] == 10 * 8 * 8 * 4
    assert rep.num_collectives["all-reduce"] == 10


def test_analyzer_on_real_compiled_module():
    def f(w, x):
        return jnp.tanh(x @ w)

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((16, 64), jnp.float32)).compile()
    rep = hlo_analysis.analyze(compiled.as_text())
    assert rep.flops == 2 * 16 * 64 * 32
    assert rep.collective_bytes == 0


def test_roofline_terms():
    rl = hlo_analysis.Roofline(flops=197e12, hbm_bytes=819e9 * 2,
                               collective_bytes=50e9 * 3, chips=256,
                               model_flops=197e12 * 256 * 0.5)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 2.0) < 1e-9
    assert abs(rl.collective_s - 3.0) < 1e-9
    assert rl.dominant == "collective"
    assert abs(rl.mfu - 0.5 / 3.0) < 1e-9


# ---------------------------------------------------------------------------
# end-to-end sharded step on the host devices (1-device mesh)
# ---------------------------------------------------------------------------

def test_sharded_train_step_runs():
    from repro.ft.elastic import elastic_mesh
    from repro.models.registry import build, load_config, smoke_batch
    from repro.optim import adamw
    from repro.train.loop import make_train_step
    from repro.dist.sharding import shardings

    cfg = load_config("internlm2-1.8b").reduced()
    model = build(cfg)
    mesh = elastic_mesh()
    params = model.init(jax.random.PRNGKey(0))
    specs = param_specs(params, mesh, "train")
    params = jax.device_put(params, shardings(specs, mesh))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(model, adamw.AdamWConfig(total_steps=10)))
    batch = smoke_batch(cfg, batch=2, seq=8)
    with mesh:
        params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_dryrun_cli_single_cell(tmp_path):
    """Full dry-run path in a subprocess (needs its own XLA_FLAGS=512)."""
    out = tmp_path / "res.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "internlm2-1.8b",
         "--shape", "prefill_32k", "--mesh", "single", "--out", str(out)],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin")},
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    res = json.loads(out.read_text())
    rec = res["internlm2-1.8b|prefill_32k|single"]
    assert rec["status"] == "ok"
    assert rec["roofline"]["chips"] == 256
    assert rec["roofline"]["step_s"] > 0
