"""Fault-tolerance integration: train on an 8-device mesh, checkpoint, lose
half the fleet, resume on a 4-device mesh — the checkpoint reshards onto the
surviving devices and the loss curve continues (subprocess because device
count is fixed at first jax init)."""

import os
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.dist import logical
    from repro.dist.sharding import param_specs, shardings
    from repro.ft.elastic import elastic_mesh
    from repro.models.registry import build, load_config
    from repro.optim import adamw
    from repro.train.loop import LoopConfig, make_train_step, run_loop

    steps, ckdir = int(sys.argv[2]), sys.argv[3]
    cfg = load_config("internlm2-1.8b").reduced()
    model = build(cfg)
    mesh = elastic_mesh(model_parallel=4)
    assert mesh.devices.size == int(sys.argv[1]), mesh.devices.shape
    params = model.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, shardings(param_specs(params, mesh, "train"), mesh))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4))
    opt_cfg = adamw.AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=12)
    with mesh, logical.use_mesh_rules(mesh):
        step = jax.jit(make_train_step(model, opt_cfg))
        _, _, hist = run_loop(model, params, data, opt_cfg,
                              LoopConfig(total_steps=steps, ckpt_every=4,
                                         ckpt_dir=ckdir, log_every=100),
                              train_step=step, log=lambda s: None)
        # fresh-init loss on the first batch this run trained on: the
        # reset-detection baseline (params above were never updated here)
        from repro.train.loop import make_loss_fn
        first_batch = jax.tree.map(jnp.asarray, data.batch_at(hist[0]["step"] - 1))
        fresh = float(make_loss_fn(model)(params, first_batch)[0])
    print(json.dumps({"hist": [(h["step"], h["loss"]) for h in hist],
                      "fresh_first_loss": fresh}))
""")


def _run(devices: int, steps: int, ckdir: str):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(devices), str(steps), ckdir],
        capture_output=True, text=True, timeout=900, cwd=REPO_ROOT,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", "/usr/bin:/bin")},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_elastic_restart_reshards(tmp_path):
    ck = str(tmp_path / "elastic")
    res1 = _run(8, 8, ck)             # 2x4 mesh, checkpoints at steps 4, 8
    hist1 = res1["hist"]
    assert hist1[-1][0] == 8
    res2 = _run(4, 12, ck)            # "pod loss": resume on 1x4 mesh
    hist2 = res2["hist"]
    assert hist2[0][0] == 9           # resumed, not restarted
    # restored params beat a fresh re-init ON THE SAME BATCH: the checkpoint
    # trajectory continued rather than resetting to ~ln(V) (same-batch
    # comparison — per-batch difficulty varies more than 8 steps of progress,
    # so any cross-batch loss comparison here would be unreliable)
    assert hist2[0][1] < res2["fresh_first_loss"], (res2["fresh_first_loss"], hist2[0])
