"""Speculative decoding (lm_verify + serving/spec.py), DESIGN.md §10.

Tentpole regressions:
- greedy speculative decoding is TOKEN-IDENTICAL to vanilla decode across
  three GQA architectures (tinyllama, gemma2 window+softcap, internlm2) in
  both the contiguous and paged cache layouts, for any drafter (the chunk
  only amortizes the weight stream — it must never change the output);
- a self-draft oracle is fully accepted (acceptance rate 1, exactly
  ceil((n-1)/k) verify steps);
- rejection rollback: rejected rows are NEVER written — the cache/pool
  after a partial accept is bit-identical to a trajectory that never saw
  the drafts (and commit must not clobber block 0, which under the
  engine's identity tables is a live block, not the scheduler sink);
- top-p speculative sampling preserves the target distribution exactly
  (leftover-distribution residual sampling for the deterministic drafters).
"""

import math
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import verify_logits_spec
from repro.models.registry import build, load_config
from repro.serving.batching import Request, serve_ragged
from repro.serving.engine import InferenceEngine
from repro.serving.spec import (
    ModelDrafter,
    NgramDrafter,
    resolve_drafter,
    spec_accept,
)

ARCHS = ["tinyllama-1.1b", "gemma2-2b", "internlm2-1.8b"]


@pytest.fixture(scope="module")
def engines():
    out = {}
    for arch in ARCHS:
        cfg = load_config(arch).reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[arch] = InferenceEngine(model, params, cache_len=64)
    return out


@pytest.fixture(scope="module")
def tiny(engines):
    return engines["tinyllama-1.1b"]


def _batch(cfg, b=2, s=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)}


class AdversarialDrafter:
    """Drafts argmax+1 of nothing in particular — every draft should be
    rejected, exercising the pure-rollback path."""

    name = "adversarial"

    def draft(self, tokens, k):
        return [(tokens[-1] + 1 + i) % 97 + 1 for i in range(k)]


class SelfDrafter:
    """Oracle drafter: proposes the target's own greedy continuation
    (precomputed), so every draft must be accepted."""

    name = "self"

    def __init__(self, continuation, prompt_len):
        self.continuation = [int(t) for t in continuation]
        self.prompt_len = prompt_len

    def draft(self, tokens, k):
        g = len(tokens) - self.prompt_len    # tokens generated so far
        out = self.continuation[g:g + k]
        return out + [0] * (k - len(out))


# ---------------------------------------------------------------------------
# tentpole: greedy speculative == vanilla, contiguous and paged, >= 3 archs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
def test_greedy_spec_token_identical(engines, arch, paged):
    eng = engines[arch]
    batch = _batch(eng.cfg)
    van = eng.generate(batch, 12, paged=paged)
    for drafter in (NgramDrafter(), AdversarialDrafter()):
        res = eng.generate(batch, 12, paged=paged, spec_k=4, drafter=drafter)
        np.testing.assert_array_equal(
            np.asarray(van.tokens), np.asarray(res.tokens),
            err_msg=f"{arch} paged={paged} drafter={drafter.name}")
    assert res.spec_stats["accepted"] == 0      # adversarial: pure rollback


def test_engine_spec_eos_parity(tiny):
    """EOS semantics match vanilla exactly: generation freezes at the first
    EOS and the tail is EOS-padded, even when the EOS lands mid-chunk."""
    batch = _batch(tiny.cfg, seed=11)
    probe = np.asarray(tiny.generate(batch, 12).tokens)
    eos = int(probe[0, 4])                     # appears mid-generation
    eng = InferenceEngine(tiny.model, tiny.params, cache_len=64, eos_id=eos)
    van = eng.generate(batch, 12)
    res = eng.generate(batch, 12, spec_k=4)
    np.testing.assert_array_equal(np.asarray(van.tokens), np.asarray(res.tokens))


def test_spec_logits_last_seeded_from_prefill(tiny):
    """A generation that never reaches a verify step (max_new=1) must still
    return real logits — the prefill distribution that produced its only
    token — not the zeros initialization."""
    batch = _batch(tiny.cfg, seed=13)
    res = tiny.generate(batch, 1, spec_k=4)
    lg = np.asarray(res.logits_last)
    assert np.abs(lg).max() > 0
    np.testing.assert_array_equal(lg.argmax(-1), np.asarray(res.tokens)[:, 0])


def test_spec_stats_count_only_kept_tokens(tiny):
    """spec_stats must price USEFUL work: tokens discarded past an EOS (or
    the budget clamp) may not inflate generated/accepted — those feed the
    benchmark's amortization headline."""
    batch = _batch(tiny.cfg, seed=11)
    probe = np.asarray(tiny.generate(batch, 12).tokens)
    eos = int(probe[0, 4])
    eng = InferenceEngine(tiny.model, tiny.params, cache_len=64, eos_id=eos)
    res = eng.generate(batch, 12, spec_k=4)
    toks = np.asarray(res.tokens)
    kept = sum(
        int(np.argmax(toks[i] == eos)) + 1 if eos in toks[i] else toks.shape[1]
        for i in range(toks.shape[0]))
    st = res.spec_stats
    assert st["generated"] == kept, (st, toks)
    assert st["accepted"] <= st["drafted"]


def test_greedy_spec_ragged_lengths(tiny):
    batch = _batch(tiny.cfg, b=3, s=10, seed=3)
    lens = [4, 10, 7]
    van = tiny.generate(batch, 10, lengths=lens)
    res = tiny.generate(batch, 10, lengths=lens, spec_k=3)
    np.testing.assert_array_equal(np.asarray(van.tokens), np.asarray(res.tokens))


def test_model_drafter_token_identical(tiny):
    """A small-model drafter (fresh registry weights — a worst-case draft
    model) must still yield exact outputs; only efficiency may change."""
    cfg = load_config("tinyllama-1.1b").reduced()
    dmodel = build(cfg)
    drafter = ModelDrafter(dmodel, dmodel.init(jax.random.PRNGKey(9)))
    batch = _batch(tiny.cfg)
    van = tiny.generate(batch, 10)
    res = tiny.generate(batch, 10, spec_k=3, drafter=drafter)
    np.testing.assert_array_equal(np.asarray(van.tokens), np.asarray(res.tokens))


def test_self_draft_full_acceptance(tiny):
    spec_k, max_new = 4, 13                    # (max_new - 1) % spec_k == 0
    batch = _batch(tiny.cfg, b=1, seed=5)
    van = tiny.generate(batch, max_new + spec_k)   # oracle continuation
    cont = np.asarray(van.tokens)[0, 1:]       # tokens after the prefill token
    drafter = SelfDrafter(cont, prompt_len=batch["tokens"].shape[1] + 1)
    res = tiny.generate(batch, max_new, spec_k=spec_k, drafter=drafter)
    np.testing.assert_array_equal(
        np.asarray(van.tokens)[:, :max_new], np.asarray(res.tokens))
    st = res.spec_stats
    assert st["accepted"] == st["drafted"], st     # acceptance rate == 1
    assert st["verify_steps"] == math.ceil((max_new - 1) / spec_k), st


# ---------------------------------------------------------------------------
# rollback: rejected rows leave no trace
# ---------------------------------------------------------------------------

def _prefilled(eng, seed=0):
    batch = _batch(eng.cfg, b=2, seed=seed)
    logits, cache = eng.model.prefill(eng.params, batch, eng.cache_len)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), batch["tokens"].shape[1], jnp.int32)
    return batch, cache, tok0, pos


def test_rollback_contiguous(tiny):
    batch, cache, tok0, pos = _prefilled(tiny)
    chunk = jnp.concatenate(
        [tok0[:, None], jnp.asarray([[3, 5, 7], [2, 4, 6]], jnp.int32)], axis=1)
    _, rows = tiny.model.verify(tiny.params, chunk, cache, pos)
    # full rejection: nothing committed -> cache bit-identical to pre-draft
    c0 = tiny.model.commit_verify(cache, rows, pos, jnp.zeros((2,), jnp.int32))
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(c0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # partial accept: ONLY slots pos..pos+n-1 may differ from pre-draft
    c1 = tiny.model.commit_verify(cache, rows, pos, jnp.asarray([2, 1], jnp.int32))
    p = int(pos[0])
    for name in ("k", "v"):
        before, after = np.asarray(cache[name]), np.asarray(c1[name])
        touched = np.zeros(before.shape, bool)
        touched[:, 0, p:p + 2] = True
        touched[:, 1, p:p + 1] = True
        np.testing.assert_array_equal(before[~touched], after[~touched])
        assert not np.array_equal(before[touched], after[touched])


def test_rollback_paged_and_block0_not_clobbered(tiny):
    from repro.models.transformer import contiguous_to_paged

    batch, cache, tok0, pos = _prefilled(tiny)
    pool, table = contiguous_to_paged(cache, 8)
    chunk = jnp.concatenate(
        [tok0[:, None], jnp.asarray([[3, 5, 7], [2, 4, 6]], jnp.int32)], axis=1)
    _, rows = tiny.model.verify_paged(tiny.params, chunk, pool, table, pos)
    p0 = tiny.model.commit_verify_paged(pool, rows, table, pos,
                                        jnp.zeros((2,), jnp.int32))
    for a, b in zip(jax.tree.leaves(pool), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a rejected suffix must not be routed to block 0 — under identity
    # tables that is row 0's first prompt block, not a sink (regression:
    # the first paged-commit draft did exactly that)
    p1 = tiny.model.commit_verify_paged(pool, rows, table, pos,
                                        jnp.asarray([1, 1], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(pool["k_pages"])[:, 0], np.asarray(p1["k_pages"])[:, 0])
    # paged partial commit == contiguous partial commit, pooled
    n = jnp.asarray([2, 1], jnp.int32)
    _, rows_c = tiny.model.verify(tiny.params, chunk, cache, pos)
    cc_pool, _ = contiguous_to_paged(
        tiny.model.commit_verify(cache, rows_c, pos, n), 8)
    cp = tiny.model.commit_verify_paged(pool, rows, table, pos, n)
    for name in ("k_pages", "v_pages"):
        np.testing.assert_array_equal(np.asarray(cc_pool[name]),
                                      np.asarray(cp[name]))


# ---------------------------------------------------------------------------
# top-p residual sampling: distribution preservation on a toy vocab
# ---------------------------------------------------------------------------

def test_residual_sampling_preserves_distribution():
    """One accept/reject position with a deterministic draft: the output
    token's distribution must equal the top-p target distribution exactly
    (accept d w.p. p(d); else sample p with d removed, renormalized).
    Exact-count check against a 5-sigma binomial envelope."""
    logits = jnp.asarray([[2.0, 1.0, 0.5, -1.0, -3.0, -3.5]])
    p, temp = 0.85, 1.0
    from repro.models.common import NEG_INF
    from repro.serving.sampling import nucleus_mask

    filt = np.where(np.asarray(nucleus_mask(logits, p)), np.asarray(logits), NEG_INF)
    target = np.exp(filt[0] - filt[0].max())
    target /= target.sum()
    draft_tok = 1                                  # inside the nucleus

    n = 4000
    chunk = jnp.asarray([[0, draft_tok]], jnp.int32)
    lg = jnp.stack([logits[0], logits[0]])[None]    # (1, 2, V): row 0 judged

    def one(key):
        out, n_out = spec_accept(lg, chunk, key, sampler="top_p",
                                 sampler_kw={"p": p, "temperature": temp})
        return out[0, 0]

    toks = np.asarray(jax.vmap(one)(jax.random.split(jax.random.PRNGKey(0), n)))
    counts = np.bincount(toks, minlength=6)
    assert counts[np.asarray(target) == 0].sum() == 0   # never leaves nucleus
    for v in range(6):
        sigma = math.sqrt(max(target[v] * (1 - target[v]) / n, 1e-12))
        assert abs(counts[v] / n - target[v]) < 5 * sigma + 1e-9, (
            v, counts[v] / n, target[v])


def test_top_p_tiny_p_equals_greedy_spec(tiny):
    """p -> 0 collapses the nucleus to the argmax: the speculative top-p
    path (accept + residual) must reproduce greedy output exactly."""
    batch = _batch(tiny.cfg)
    van = tiny.generate(batch, 10)
    res = tiny.generate(batch, 10, spec_k=3, sampler="top_p",
                        sampler_kw={"p": 1e-9, "temperature": 1.0})
    np.testing.assert_array_equal(np.asarray(van.tokens), np.asarray(res.tokens))


# ---------------------------------------------------------------------------
# schedulers + plumbing
# ---------------------------------------------------------------------------

def test_schedulers_spec_token_identical(tiny):
    rng = np.random.default_rng(2)
    lens = [2, 5, 9, 14, 3, 7]
    buds = [12, 3, 10, 4, 8, 6]
    reqs = [Request(i, rng.integers(1, tiny.cfg.vocab_size, size=(n,))
                    .astype(int).tolist(), max_new=m)
            for i, (n, m) in enumerate(zip(lens, buds))]
    for mode in ("continuous", "paged"):
        base = serve_ragged(tiny, reqs, 12, mode=mode)
        spec = serve_ragged(tiny, reqs, 12, mode=mode, spec_k=4)
        for a, b in zip(base, spec):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            assert a.length == b.length
    stats = [s.last_spec_stats for s in tiny._paged_schedulers.values()
             if s.last_spec_stats]
    assert stats and stats[0]["verify_steps"] > 0
    # 'generated' prices delivered work: every request's full budget,
    # including the prefill-sampled token (engine-stats-comparable)
    assert stats[0]["generated"] == sum(buds)


def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(max_n=3)
    # trailing [7, 8] occurred earlier, followed by 9, 10, 11
    assert d.draft([1, 7, 8, 9, 10, 11, 7, 8], 3) == [9, 10, 11]
    # no match: repeat last token
    assert d.draft([1, 2, 3], 2) == [3, 3]
    assert d.draft([], 2) == [0, 0]


def test_resolve_drafter():
    assert isinstance(resolve_drafter(None), NgramDrafter)
    assert isinstance(resolve_drafter("ngram"), NgramDrafter)
    md = resolve_drafter("model:tinyllama-1.1b", reduced=True)
    assert md.name == "model:tinyllama-1.1b"
    with pytest.raises(ValueError, match="unknown drafter"):
        resolve_drafter("medusa")


def test_spec_validation_errors(tiny):
    batch = _batch(tiny.cfg)
    with pytest.raises(ValueError, match="spec_k must be >= 2"):
        tiny.generate(batch, 4, spec_k=1)
    with pytest.raises(ValueError, match="spec_k=4"):
        # vanilla fit (8 + 56 = 64) but no spec slack left
        tiny.generate(batch, 56, spec_k=4)
    rwkv = build(load_config("rwkv6-7b").reduced())
    reng = InferenceEngine(rwkv, rwkv.init(jax.random.PRNGKey(0)), cache_len=32)
    with pytest.raises(ValueError, match="no speculative verify"):
        reng.generate(_batch(rwkv.cfg), 4, spec_k=2)
    # rwkv now resolves to the slot-state continuous scheduler, whose core
    # rejects spec for non-verify families; the bucketed fallback keeps its
    # own refusal for explicitly-requested bucket-serial serving
    with pytest.raises(ValueError, match="no speculative verify"):
        serve_ragged(reng, [Request(0, [1, 2, 3])], 4, spec_k=2)
    with pytest.raises(ValueError, match="bucketed"):
        serve_ragged(tiny, [Request(0, [1, 2, 3])], 4, spec_k=2,
                     mode="bucketed")


def test_verify_logits_spec_dist():
    mesh = SimpleNamespace(shape={"data": 16, "model": 16},
                           axis_names=("data", "model"))
    from jax.sharding import PartitionSpec as P

    assert verify_logits_spec(mesh, 256) == P(("data",), None, "model")
    assert verify_logits_spec(mesh, 3) == P(None, None, "model")
