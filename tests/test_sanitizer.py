"""repro-san: the cache-memory and numerics sanitizer (DESIGN.md §13).

Three layers of coverage:

- **Planted bugs**: adapter subclasses that deliberately use-after-free a
  KV block, leak blocks at finish, or write NaN into the cache — each must
  raise ``SanitizerError``/``QuantNumericsError`` WITH attribution (block +
  generation, request id, leaf + layer).
- **Shadow unit tests**: the host-side mirrors in isolation (double-reserve,
  unowned free, frozen-slot drift, pad rows, dead-slot snapshots) plus the
  paged poison oracle's committed-position semantics.
- **The parity sweep**: every arch in ``SANITIZED_ARCHS`` (the ledger the
  shadow-coverage checker audits) serves bit-identically with the sanitizer
  on vs off, and finalizes with a clean audit. This is the load-bearing
  property: repro-san must observe, never perturb.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from arch_matrix import SANITIZED_ARCHS
from repro.analysis.sanitizer import (
    ENV_VAR,
    Sanitizer,
    check_array,
    sanitize_enabled,
)
from repro.analysis.shadow import (
    OVERFLOW_LIMIT,
    POISON,
    SanitizerError,
    ShadowBlockTracker,
    SlotShadow,
)
from repro.core.policy import quantize_params
from repro.core.quant import (
    QuantNumericsError,
    QuantizedTensor,
    get_format,
    numerics_checks,
    numerics_checks_enabled,
    set_numerics_checks,
)
from repro.kernels.ref import paged_poison_counts
from repro.models.registry import build, load_config
from repro.serving.batching import serve_ragged
from repro.serving.core import Request, RecurrentAdapter, SchedulerCore
from repro.serving.engine import InferenceEngine
from repro.serving.paged import BlockPool, PagedAdapter, PagedScheduler

STEPS = 3
PROMPTS = [[5, 3], [7, 1, 4, 2, 6], [9, 2, 8]]


@pytest.fixture(autouse=True)
def _numerics_isolation():
    """Sanitized engines flip the process-global numerics switch; keep each
    test hermetic."""
    prev = numerics_checks_enabled()
    yield
    set_numerics_checks(prev)


def _setup(arch):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def tiny():
    return _setup("tinyllama-1.1b")


@pytest.fixture(scope="module")
def rwkv():
    return _setup("rwkv6-7b")


def _requests(prompts=PROMPTS, max_new=None):
    return [Request(i, list(p), max_new=max_new) for i, p in enumerate(prompts)]


# ---------------------------------------------------------------------------
# shadow state: host-side mirrors in isolation
# ---------------------------------------------------------------------------

def test_poison_is_finite_and_below_overflow_limit():
    # NaN poison would infect masked softmax columns (0 * NaN) and break the
    # parity sweep below; the whole scheme rests on these two properties
    assert np.isfinite(POISON)
    assert abs(POISON) < OVERFLOW_LIMIT


def test_tracker_double_reserve_and_unowned_free():
    t = ShadowBlockTracker(8)
    t.set_context(0)
    t.on_alloc([3, 4])
    with pytest.raises(SanitizerError, match="double-reserve of block 3"):
        t.on_alloc([3])
    with pytest.raises(SanitizerError, match="unowned block 5"):
        t.on_free([5])


def test_tracker_generations_and_poison_queue():
    t = ShadowBlockTracker(8)
    t.set_context(1)
    t.on_alloc([2])
    t.on_free([2])
    assert t.generation[2] == 1
    assert t.drain_poison() == [2]
    assert t.drain_poison() == []       # drained exactly once
    t.on_alloc([2])                      # recycled: new generation, same id
    t.on_free([2])
    assert t.generation[2] == 2


def test_tracker_audits_catch_leaks():
    t = ShadowBlockTracker(8)
    t.set_context(1)
    t.on_alloc([6])
    with pytest.raises(SanitizerError, match="leak — request r9"):
        t.audit_request(1, "r9")
    with pytest.raises(SanitizerError, match="leak at finalize"):
        t.audit_final()


def test_slot_shadow_lifecycle_violations():
    sh = SlotShadow(2, "paged")
    sh.on_admit(0, 11)
    with pytest.raises(SanitizerError, match="double-admit"):
        sh.on_admit(0, 12)
    with pytest.raises(SanitizerError, match="non-live slot 1"):
        sh.on_finish(1, 0)
    sh.on_finish(0, 7)
    sh.check_frozen([7, 0])              # frozen at 7: no drift, clean
    with pytest.raises(SanitizerError, match="frozen slot 0.*7 -> 9"):
        sh.check_frozen([9, 0])
    assert sh.live_slots() == []
    with pytest.raises(SanitizerError, match="snapshot of non-live slot 0"):
        sh.check_snapshot([0])


def test_slot_shadow_pad_rows_recurrent_only():
    # a padded admission group corrupts a recurrence but is the NORM for the
    # masked kv prefill — the check must be kind-gated
    SlotShadow(2, "paged").check_prefill_group([0], [3], 4)
    with pytest.raises(SanitizerError, match="pad rows entering"):
        SlotShadow(2, "recurrent").check_prefill_group([0], [3], 4)


def test_paged_poison_oracle_counts_committed_positions_only():
    L, NB, BS, KV, hd = 1, 4, 2, 1, 2
    k = np.zeros((L, NB, BS, KV, hd), np.float32)
    v = np.zeros_like(k)
    k[0, 2, 0] = POISON                  # physical block 2, in-block pos 0
    table = jnp.asarray([[2, 0]], jnp.int32)   # slot 0: virtual block 0 -> 2

    def counts(pos):
        return np.asarray(paged_poison_counts(
            jnp.asarray(k), jnp.asarray(v), table,
            jnp.asarray([pos], jnp.int32), POISON))

    assert counts(1).tolist() == [[[1, 0]]]    # t=0 committed: reachable
    assert counts(0).sum() == 0          # lookahead block: masked, clean
    v[0, 2, 0] = POISON                  # K and V hits count independently
    assert counts(1).tolist() == [[[2, 0]]]


def test_sanitizer_snapshot_hooks_dead_slot_and_phantom_blocks():
    class _Core:
        slots = 2

    class _Adapter:
        kind = "paged"

        def __init__(self, pool, table):
            self.pool, self.table = pool, table

        def san_state(self):
            return {"pool": self.pool, "table": self.table}

    pool = BlockPool(5, 4)
    table = np.zeros((2, 2), np.int32)
    san = Sanitizer(_Core())
    san.begin_serve(_Adapter(pool, table), cache=None)
    san.on_admit(0, Request(0, [1, 2]))
    table[0, 0] = pool.alloc(1)[0]
    san.on_snapshot([0])                 # live slot, table == shadow: clean
    table[0, 1] = 3                      # mapping the shadow never saw
    with pytest.raises(SanitizerError, match="phantom"):
        san.on_snapshot([0])
    table[0, 1] = 0
    with pytest.raises(SanitizerError, match="non-live slot 1"):
        san.on_snapshot([1])


# ---------------------------------------------------------------------------
# numerics tripwires: quantize/dequantize boundaries, logits, cache leaves
# ---------------------------------------------------------------------------

def test_check_array_attributes_first_bad_index():
    check_array("ok", jnp.ones((2, 3)))
    check_array("ints", jnp.ones((4,), jnp.int32))   # integer: no-op
    x = jnp.ones((2, 3)).at[1, 2].set(jnp.nan)
    with pytest.raises(SanitizerError, match=r"logits.*index \(1, 2\)"):
        check_array("logits", x)


def test_quantize_guard_flags_nan_input_only_when_armed():
    fmt = get_format("int8")
    x = jnp.ones((2, 32)).at[0, 0].set(jnp.nan)
    with numerics_checks(True):
        with pytest.raises(QuantNumericsError, match=r"quantize\[int8\].input"):
            fmt.quantize(x, 32)
    fmt.quantize(x, 32)                  # unarmed: legacy silent behavior


def test_dequantize_guard_flags_corrupt_scales():
    fmt = get_format("int8")
    qt = fmt.quantize(jnp.ones((2, 32)), 32)
    bad = dataclasses.replace(
        qt, scales=jnp.asarray(qt.scales).at[0, 0].set(jnp.inf))
    with numerics_checks(True):
        with pytest.raises(QuantNumericsError, match=r"dequantize\[int8\].scales"):
            fmt.dequantize(bad)
    fmt.dequantize(bad)


def _corrupt_first_quantized_leaf(cfg, params):
    """NaN-poison the first param leaf the quant policy actually quantizes."""
    qp = quantize_params(params, cfg.group_size)
    qleaves = jax.tree_util.tree_flatten_with_path(
        qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]
    target = next(jax.tree_util.keystr(kp) for kp, leaf in qleaves
                  if isinstance(leaf, QuantizedTensor))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    bad = [leaf.at[(0,) * leaf.ndim].set(jnp.nan)
           if jax.tree_util.keystr(kp) == target else leaf
           for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, bad)


def test_corrupt_checkpoint_attributed_param_and_layer_class(tiny):
    cfg, model, params = tiny
    bad = _corrupt_first_quantized_leaf(cfg, params)
    with numerics_checks(True):
        with pytest.raises(QuantNumericsError) as ei:
            quantize_params(bad, cfg.group_size)
    msg = str(ei.value)
    assert "param" in msg and "layer-class" in msg


def test_sanitized_engine_rejects_corrupt_checkpoint_at_init(tiny):
    # the end-to-end path: sanitize=True arms the guards BEFORE PTQ runs,
    # so a corrupted checkpoint fails at load, not as garbage generations
    cfg, model, params = tiny
    bad = _corrupt_first_quantized_leaf(cfg, params)
    with pytest.raises(QuantNumericsError, match="layer-class"):
        InferenceEngine(model, bad, cache_len=16, quantize=True, sanitize=True)


# ---------------------------------------------------------------------------
# planted bugs: each classic corruption raises with attribution
# ---------------------------------------------------------------------------

class UafAdapter(PagedAdapter):
    """Frees a live slot's first block but leaves the table mapping it —
    the silent stale-KV read the poison oracle exists to catch."""

    tripped = False

    def before_round(self, pos, live):
        super().before_round(pos, live)
        if not self.tripped:
            s = int(np.flatnonzero(live)[0])
            blk = self._slot_blocks[s][0]
            self.pool.free([blk])        # out-of-band free: pre_round poisons
            self.tripped = True


def test_planted_use_after_free_caught_with_block_attribution(tiny):
    cfg, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=16, sanitize=True)
    core = SchedulerCore(eng, UafAdapter(eng), slots=1, chunk=2)
    with pytest.raises(SanitizerError) as ei:
        core.serve([Request(0, [5, 3, 1, 7], max_new=6)], 6)
    msg = str(ei.value)
    assert "use-after-free" in msg
    assert "freed physical block" in msg and "generation" in msg


class LeakOnFinishAdapter(PagedAdapter):
    """Drops the bookkeeping at finish but never returns the blocks."""

    def on_finish(self, s):
        self._slot_blocks[s], self._slot_need[s] = [], 0
        self.table[s, :] = 0
        self._slot_live[s] = False       # everything but pool.free


def test_planted_leak_caught_at_request_finish(tiny):
    cfg, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=16, sanitize=True)
    core = SchedulerCore(eng, LeakOnFinishAdapter(eng), slots=1, chunk=2)
    with pytest.raises(SanitizerError, match="leak — request 0.*still owns"):
        core.serve([Request(0, [5, 3, 1], max_new=2)], 2)


class NanCacheAdapter(PagedAdapter):
    """Writes one NaN into the KV pool after a decode round."""

    tripped = False

    def decode_round(self, params, tok, cache, pos, live, remaining, keys):
        toks, steps, cache, pos = super().decode_round(
            params, tok, cache, pos, live, remaining, keys)
        if not self.tripped:
            cache = dict(cache)
            cache["k_pages"] = cache["k_pages"].at[0, 2].set(jnp.nan)
            self.tripped = True
        return toks, steps, cache, pos


def test_planted_nan_cache_caught_with_leaf_and_layer(tiny):
    cfg, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=16, sanitize=True)
    core = SchedulerCore(eng, NanCacheAdapter(eng), slots=1, chunk=2)
    with pytest.raises(SanitizerError) as ei:
        core.serve([Request(0, [5, 3, 1, 7], max_new=6)], 6)
    msg = str(ei.value)
    assert "k_pages" in msg and "layer" in msg and "[0]" in msg


# ---------------------------------------------------------------------------
# enablement: engine flag, REPRO_SAN env, core inheritance
# ---------------------------------------------------------------------------

def test_env_var_arms_engines(tiny, monkeypatch):
    cfg, model, params = tiny
    monkeypatch.setenv(ENV_VAR, "1")
    assert sanitize_enabled()
    assert InferenceEngine(model, params, cache_len=16).sanitize
    monkeypatch.setenv(ENV_VAR, "0")
    assert not sanitize_enabled()
    assert not InferenceEngine(model, params, cache_len=16).sanitize
    monkeypatch.setenv(ENV_VAR, "1")
    # explicit construction beats the environment
    assert not InferenceEngine(
        model, params, cache_len=16, sanitize=False).sanitize


def test_core_inherits_engine_sanitize(tiny):
    cfg, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=16, sanitize=True)
    assert SchedulerCore(eng, PagedAdapter(eng), slots=2).sanitizer is not None
    assert SchedulerCore(eng, PagedAdapter(eng), slots=2,
                         sanitize=False).sanitizer is None
    plain = InferenceEngine(model, params, cache_len=16, sanitize=False)
    assert SchedulerCore(plain, PagedAdapter(plain), slots=2).sanitizer is None


# ---------------------------------------------------------------------------
# the parity sweep: sanitize must observe, never perturb
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", SANITIZED_ARCHS)
def test_sanitized_serve_bit_identical_and_audit_clean(arch):
    """Every cache-bearing family (the SANITIZED_ARCHS ledger audited by the
    shadow-coverage checker) serves its preferred mode under REPRO_SAN with
    bit-identical tokens and a clean end-of-serve audit — poison fills and
    per-round tripwires included."""
    cfg, model, params = _setup(arch)
    plain = InferenceEngine(model, params, cache_len=16, sanitize=False)
    san = InferenceEngine(model, params, cache_len=16, sanitize=True)
    want = serve_ragged(plain, _requests(), STEPS, slots=2, chunk=2)
    got = serve_ragged(san, _requests(), STEPS, slots=2, chunk=2)
    for g, w in zip(got, want):
        assert g.id == w.id
        np.testing.assert_array_equal(g.tokens, w.tokens)


def test_mixed_budgets_exercise_poison_path_cleanly(tiny):
    # early finishes free + poison blocks mid-serve while others decode on:
    # the strongest "poison never reaches live data" case on the paged path
    cfg, model, params = tiny
    plain = InferenceEngine(model, params, cache_len=16, sanitize=False)
    san = InferenceEngine(model, params, cache_len=16, sanitize=True)
    def reqs():
        return [Request(0, [5, 3], max_new=1),
                Request(1, [7, 1, 4, 2, 6], max_new=6),
                Request(2, [9, 2, 8], max_new=3)]
    want = serve_ragged(plain, reqs(), 6, mode="paged", slots=2, chunk=2)
    got = serve_ragged(san, reqs(), 6, mode="paged", slots=2, chunk=2)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.tokens, w.tokens)


# ---------------------------------------------------------------------------
# snapshots under the sanitizer: mid-flight, restore, run to completion
# ---------------------------------------------------------------------------

class MidServeSnapPaged(PagedAdapter):
    """Snapshots every live slot once, at the first decode round."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.snaps = []

    def decode_round(self, params, tok, cache, pos, live, remaining, keys):
        if not self.snaps:
            slots = np.flatnonzero(np.asarray(live)).tolist()
            self.snaps.append((self.snapshot(cache, slots),
                               np.asarray(pos)[slots].copy(),
                               np.asarray(tok)[slots].copy()))
        return super().decode_round(
            params, tok, cache, pos, live, remaining, keys)


def test_paged_snapshot_midflight_restore_and_resume(tiny):
    cfg, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=16, sanitize=True)
    adapter = MidServeSnapPaged(eng)
    core = SchedulerCore(eng, adapter, slots=2, chunk=2)
    got = core.serve(_requests(), 4)     # clean finalize despite the snapshot
    (snap, pos_s, tok_s), = adapter.snaps
    for leaf in jax.tree.leaves(snap["cache"]):
        if np.issubdtype(np.asarray(leaf).dtype, np.floating):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # restore the pool + tables and take a decode step on the snapped slots
    logits, _ = model.decode_paged(
        eng.params, jnp.asarray(tok_s), jax.device_put(snap["cache"]),
        jnp.asarray(snap["table"]), jnp.asarray(pos_s))
    check_array("restored.decode.logits", logits)
    # ...and the snapshotting, sanitized serve matched the vanilla scheduler
    plain = InferenceEngine(model, params, cache_len=16, sanitize=False)
    want = PagedScheduler(plain, slots=2, chunk=2).serve(_requests(), 4)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.tokens, w.tokens)


class MidServeSnapRecurrent(RecurrentAdapter):
    def __init__(self, engine):
        super().__init__(engine)
        self.snaps = []

    def decode_round(self, params, tok, cache, pos, live, remaining, keys):
        if not self.snaps:
            slots = np.flatnonzero(np.asarray(live)).tolist()
            self.snaps.append(self.snapshot(cache, slots))
        return super().decode_round(
            params, tok, cache, pos, live, remaining, keys)


def test_recurrent_snapshot_midflight_clean_and_parity(rwkv):
    cfg, model, params = rwkv
    eng = InferenceEngine(model, params, cache_len=16, sanitize=True)
    adapter = MidServeSnapRecurrent(eng)
    core = SchedulerCore(eng, adapter, slots=2, chunk=2)
    got = core.serve(_requests(), 4)
    rows, = adapter.snaps
    for leaf in jax.tree.leaves(rows):
        if np.issubdtype(np.asarray(leaf).dtype, np.floating):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
    plain = InferenceEngine(model, params, cache_len=16, sanitize=False)
    want = serve_ragged(plain, _requests(), 4, mode="continuous",
                        slots=2, chunk=2)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.tokens, w.tokens)


def test_engine_snapshot_restore_roundtrip_with_block_table():
    cache = {"k": jnp.ones((2, 3)), "v": jnp.zeros((2, 3))}
    snap = InferenceEngine.snapshot(
        cache, jnp.asarray([4, 1]), jnp.asarray([7, 2]),
        block_table=np.asarray([[1, 0], [2, 0]]))
    c2, pos, toks, table = InferenceEngine.restore(None, snap)
    np.testing.assert_array_equal(np.asarray(c2["k"]), np.asarray(cache["k"]))
    np.testing.assert_array_equal(np.asarray(pos), [4, 1])
    np.testing.assert_array_equal(np.asarray(toks), [7, 2])
    assert table.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(table), [[1, 0], [2, 0]])
