"""PTQ policy tests: per-leaf group sizes, TP shard alignment, exclusions,
layer-class format maps (mixed precision)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import (
    format_breakdown,
    leaf_class,
    leaf_group_size,
    quantize_params,
    quantized_fraction,
    resolve_format_map,
    should_quantize,
)
from repro.core.quant import QuantizedTensor
from repro.core.treepath import path_str


def test_leaf_group_size_plain():
    w = jnp.zeros((128, 2048))
    assert leaf_group_size("layers/attn/wqkv", w, 256) == 256
    assert leaf_group_size("layers/attn/wqkv", jnp.zeros((128, 1408)), 256) == 128


def test_leaf_group_size_row_parallel_tp():
    # deepseek-coder wo: contraction 7168 sharded 16 ways -> 448/shard -> GS 64
    w = jnp.zeros((7168, 7168))
    assert leaf_group_size("layers/attn/wo", w, 256, tp=16) == 64
    # w2 contraction 19200/16=1200 -> largest pow2 dividing is 16
    assert leaf_group_size("layers/mlp/w2", jnp.zeros((7168, 19200)), 256, tp=16) == 16
    # expert weights are EP-sharded, contraction whole
    assert leaf_group_size("layers/mlp/experts/w2", jnp.zeros((6144, 10752)), 256, tp=16) == 256


def test_exclusions():
    assert not should_quantize("layers/att_norm", jnp.zeros((24, 2048)), 256)
    assert not should_quantize("layers/mlp/router_w", jnp.zeros((16, 6144)), 256)
    assert not should_quantize("layers/mamba/conv_w", jnp.zeros((4, 7296)), 256)
    assert not should_quantize("layers/decay_lora_a", jnp.zeros((64, 4096)), 256)
    assert should_quantize("layers/attn/wqkv", jnp.zeros((4096, 2048)), 256)


def test_quantize_params_tp_alignment():
    params = {
        "wo": jnp.asarray(np.random.default_rng(0).normal(size=(64, 448 * 16)).astype(np.float32)),
        "wqkv": jnp.asarray(np.random.default_rng(1).normal(size=(64, 2048)).astype(np.float32)),
    }
    qp = quantize_params(params, 256, tp=16)
    # wo: per-shard contraction 448 -> GS 64; scales count divisible by 16
    assert qp["wo"].group_size == 64
    assert qp["wo"].scales.shape[-1] % 16 == 0
    assert qp["wqkv"].group_size == 256


def test_quantized_fraction_counts_scales():
    params = {"w": jnp.ones((64, 256)), "norm": jnp.ones((256,))}
    qp = quantize_params(params, 256)
    frac = quantized_fraction(qp)
    w_bytes = 64 * 256 + 4 * 64  # int8 + scales
    total = w_bytes + 256 * 4
    assert abs(frac - w_bytes / total) < 1e-6


def test_quantize_params_under_eval_shape():
    """The dry-run quantizes ShapeDtypeStructs via eval_shape — must work."""
    params = {"w13": jax.ShapeDtypeStruct((512, 256), jnp.float32),
              "norm": jax.ShapeDtypeStruct((256,), jnp.float32)}
    q = jax.eval_shape(lambda p: quantize_params(p, 128, tp=4), params)
    assert isinstance(q["w13"], QuantizedTensor)
    assert q["w13"].qvalues.dtype == jnp.int8
    assert q["w13"].scales.shape == (512, 2)
    assert q["norm"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# layer classes and format maps
# ---------------------------------------------------------------------------

def test_leaf_class():
    assert leaf_class("embed") == "embed"
    assert leaf_class("classifier") == "classifier"
    assert leaf_class("layers/attn/wqkv") == "attn"
    assert leaf_class("dec_layers/cross/wkv") == "attn"
    assert leaf_class("mamba_layers/mamba/win") == "attn"
    assert leaf_class("layers/wr") == "attn"                  # rwkv mixer
    assert leaf_class("layers/mlp/w2") == "ffn"
    assert leaf_class("layers/mlp/experts/w13") == "ffn"
    assert leaf_class("layers/wff2") == "ffn"                 # rwkv channel-mix
    # qvalues/scales suffixes classify like their parent weight
    assert leaf_class("layers/attn/wqkv/qvalues") == "attn"
    assert leaf_class("layers/mlp/w2/scales") == "ffn"


def test_resolve_format_map():
    uni = resolve_format_map("int4")
    assert set(uni.values()) == {"int4"}
    mixed = resolve_format_map("mixed")
    assert mixed["embed"] == "int8" and mixed["attn"] == "int4"
    partial = resolve_format_map({"attn": "int4", "classifier": None})
    assert partial["attn"] == "int4"
    assert partial["classifier"] is None
    assert partial["ffn"] == "int8"   # unspecified -> paper baseline
    uni3 = resolve_format_map("int3")
    assert set(uni3.values()) == {"int3"}
    m3 = resolve_format_map("mixed3")
    assert m3["attn"] == m3["ffn"] == "int3"
    assert m3["embed"] == m3["classifier"] == "int8"
    with pytest.raises(ValueError, match="unknown quant format"):
        resolve_format_map("int2")
    with pytest.raises(ValueError, match="unknown layer classes"):
        resolve_format_map({"attnn": "int4"})
    with pytest.raises(TypeError):
        resolve_format_map(4)


def _leaf_formats(qp) -> dict[str, str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(
        qp, is_leaf=lambda x: isinstance(x, QuantizedTensor)
    )
    return {path_str(p): l.fmt for p, l in flat if isinstance(l, QuantizedTensor)}


def test_mixed_policy_golden_tinyllama():
    """Golden: the mixed map on the FULL tinyllama-1.1b tree assigns int8 to
    embeddings/classifier and packed int4 to every attention/FFN projection;
    norms stay float (eval_shape — no 1.1B-param materialization)."""
    from repro.models.registry import build, load_config

    cfg = load_config("tinyllama-1.1b")
    model = build(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    qp = jax.eval_shape(
        lambda p: quantize_params(p, cfg.group_size, formats="mixed"), params
    )
    fmts = _leaf_formats(qp)
    assert fmts == {
        "embed": "int8",
        "classifier": "int8",
        "layers/attn/wqkv": "int4",
        "layers/attn/wo": "int4",
        "layers/mlp/w13": "int4",
        "layers/mlp/w2": "int4",
    }
    # packed storage: attn/ffn qvalues halve their trailing dim
    assert qp["layers"]["attn"]["wqkv"].qvalues.shape[-1] == cfg.d_model // 2
    assert qp["embed"].qvalues.shape[-1] == cfg.d_model
    # norms survive untouched
    assert not isinstance(qp["final_norm"], QuantizedTensor)


def test_quantized_fraction_format_aware():
    """Packed int4 must report its true (halved) storage, not int8 bytes."""
    params = {"attn": {"wo": jnp.ones((64, 256))}, "norm": jnp.ones((256,))}
    q8 = quantize_params(params, 256, formats="int8")
    q4 = quantize_params(params, 256, formats="int4")
    w8 = 64 * 256 + 4 * 64
    w4 = 64 * 128 + 4 * 64
    f32 = 256 * 4
    assert abs(quantized_fraction(q8) - w8 / (w8 + f32)) < 1e-6
    assert abs(quantized_fraction(q4) - w4 / (w4 + f32)) < 1e-6
    assert format_breakdown(q4) == {"int4": w4, "float": f32}


def test_int4_respects_tp_alignment():
    """Row-parallel leaves keep whole groups per shard in packed storage."""
    params = {"wo": jnp.ones((64, 448 * 16))}
    qp = quantize_params(params, 256, tp=16, formats="int4")
    assert qp["wo"].fmt == "int4"
    assert qp["wo"].group_size == 64          # per-shard contraction 448 -> 64
    # per-shard packed chunk (448/2 = 224 bytes) holds exactly 7 groups of 32
    assert (qp["wo"].qvalues.shape[-1] // 16) % (64 // 2) == 0
