"""PTQ policy tests: per-leaf group sizes, TP shard alignment, exclusions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import (
    leaf_group_size,
    quantize_params,
    quantized_fraction,
    should_quantize,
)
from repro.core.quant import QuantizedTensor


def test_leaf_group_size_plain():
    w = jnp.zeros((128, 2048))
    assert leaf_group_size("layers/attn/wqkv", w, 256) == 256
    assert leaf_group_size("layers/attn/wqkv", jnp.zeros((128, 1408)), 256) == 128


def test_leaf_group_size_row_parallel_tp():
    # deepseek-coder wo: contraction 7168 sharded 16 ways -> 448/shard -> GS 64
    w = jnp.zeros((7168, 7168))
    assert leaf_group_size("layers/attn/wo", w, 256, tp=16) == 64
    # w2 contraction 19200/16=1200 -> largest pow2 dividing is 16
    assert leaf_group_size("layers/mlp/w2", jnp.zeros((7168, 19200)), 256, tp=16) == 16
    # expert weights are EP-sharded, contraction whole
    assert leaf_group_size("layers/mlp/experts/w2", jnp.zeros((6144, 10752)), 256, tp=16) == 256


def test_exclusions():
    assert not should_quantize("layers/att_norm", jnp.zeros((24, 2048)), 256)
    assert not should_quantize("layers/mlp/router_w", jnp.zeros((16, 6144)), 256)
    assert not should_quantize("layers/mamba/conv_w", jnp.zeros((4, 7296)), 256)
    assert not should_quantize("layers/decay_lora_a", jnp.zeros((64, 4096)), 256)
    assert should_quantize("layers/attn/wqkv", jnp.zeros((4096, 2048)), 256)


def test_quantize_params_tp_alignment():
    params = {
        "wo": jnp.asarray(np.random.default_rng(0).normal(size=(64, 448 * 16)).astype(np.float32)),
        "wqkv": jnp.asarray(np.random.default_rng(1).normal(size=(64, 2048)).astype(np.float32)),
    }
    qp = quantize_params(params, 256, tp=16)
    # wo: per-shard contraction 448 -> GS 64; scales count divisible by 16
    assert qp["wo"].group_size == 64
    assert qp["wo"].scales.shape[-1] % 16 == 0
    assert qp["wqkv"].group_size == 256


def test_quantized_fraction_counts_scales():
    params = {"w": jnp.ones((64, 256)), "norm": jnp.ones((256,))}
    qp = quantize_params(params, 256)
    frac = quantized_fraction(qp)
    w_bytes = 64 * 256 + 4 * 64  # int8 + scales
    total = w_bytes + 256 * 4
    assert abs(frac - w_bytes / total) < 1e-6


def test_quantize_params_under_eval_shape():
    """The dry-run quantizes ShapeDtypeStructs via eval_shape — must work."""
    params = {"w13": jax.ShapeDtypeStruct((512, 256), jnp.float32),
              "norm": jax.ShapeDtypeStruct((256,), jnp.float32)}
    q = jax.eval_shape(lambda p: quantize_params(p, 128, tp=4), params)
    assert isinstance(q["w13"], QuantizedTensor)
    assert q["w13"].qvalues.dtype == jnp.int8
    assert q["w13"].scales.shape == (512, 2)
    assert q["norm"].dtype == jnp.float32
