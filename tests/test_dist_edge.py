"""Edge cases for repro.dist beyond the seed rule table: no-mesh/CPU
fallback, indivisible-dim degradation, quantized leaves on MoE expert
weights, pod meshes — plus kernels/gqmv._pick_block block-size selection."""

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.quant import quantize_groupwise
from repro.dist import logical
from repro.dist.sharding import (
    batch_specs,
    cache_spec,
    dp_axes,
    logits_spec,
    param_spec,
    param_specs,
)
from repro.kernels.gqmv import _pick_block

MESH16 = SimpleNamespace(shape={"data": 16, "model": 16},
                         axis_names=("data", "model"))
POD = SimpleNamespace(shape={"pod": 2, "data": 8, "model": 16},
                      axis_names=("pod", "data", "model"))


# ---------------------------------------------------------------------------
# no-mesh / CPU fallback
# ---------------------------------------------------------------------------

def test_no_mesh_sizes_are_one():
    assert logical.size("dp") == 1
    assert logical.size("tp") == 1
    assert logical.size("seq") == 1
    assert logical.active_mesh() is None


def test_no_mesh_constrain_is_identity():
    x = jnp.arange(12).reshape(3, 4)
    assert logical.constrain(x, "dp", "tp") is x


def test_mesh_rules_bind_and_restore():
    with logical.use_mesh_rules(MESH16):
        assert logical.size("dp") == 16
        assert logical.size("tp") == 16
        assert logical.size("seq") == 256
        assert logical.active_mesh() is MESH16
    assert logical.size("seq") == 1
    assert logical.active_mesh() is None


def test_constrain_runs_on_single_device_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with logical.use_mesh_rules(mesh):
        assert logical.size("tp") == 1
        y = logical.constrain(jnp.ones((4, 4)), "dp", "tp")
    np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))


def test_constrain_rejects_too_many_axes():
    mesh = jax.make_mesh((1,), ("data",))
    with logical.use_mesh_rules(mesh):
        with pytest.raises(ValueError):
            logical.constrain(jnp.ones((4,)), "dp", "tp")


# ---------------------------------------------------------------------------
# indivisible-dim degradation
# ---------------------------------------------------------------------------

def test_logical_spec_drops_indivisible_and_reused_axes():
    with logical.use_mesh_rules(MESH16):
        # 7 % 16 != 0 -> dropped; second "tp" would reuse the model axis
        assert logical.spec((32, 7, 64), "dp", "tp", "tp") == P("data", None, "model")
        assert logical.spec((1, 512), None, "seq") == P(None, ("data", "model"))
        # 8 % 256 != 0 -> seq dropped
        assert logical.spec((8,), "seq") == P(None)


def test_param_spec_fully_indivisible_degrades_to_replicated():
    assert param_spec("layers/attn/wqkv", (24, 4095, 2047),
                      mesh=MESH16, mode="train") == P(None, None, None)


def test_cache_spec_layer_count_equal_to_batch():
    # 16 layers, batch 16: the leading stack axis must NOT be taken for the
    # batch — batch -> data at axis 1, sequence -> model at axis 2.
    assert cache_spec("k", (16, 16, 32768, 8, 128), mesh=MESH16, batch=16) == \
        P(None, "data", "model", None, None)
    # zamba-style (groups, per, batch, ...) still finds batch at axis 2
    assert cache_spec("conv", (4, 6, 32, 3, 288), mesh=MESH16, batch=32) == \
        P(None, None, "data", None, None)


def test_cache_spec_indivisible_dims():
    assert cache_spec("k", (2, 6, 10, 2, 8), mesh=MESH16, batch=6) == \
        P(None, None, None, None, None)
    # batch=1 but T only divides the model axis -> model, not the full mesh
    assert cache_spec("k", (2, 1, 32, 2, 8), mesh=MESH16, batch=1) == \
        P(None, None, "model", None, None)


# ---------------------------------------------------------------------------
# quantized leaves on MoE expert weights
# ---------------------------------------------------------------------------

def test_moe_expert_quantized_leaves():
    # qvalues inherit the expert rule (E -> model, in -> train FSDP)
    assert param_spec("layers/mlp/experts/w13/qvalues", (40, 16, 21504, 6144),
                      mesh=MESH16, mode="train") == P(None, "model", None, "data")
    # scales: group axis NEVER takes FSDP or the (consumed) model axis
    assert param_spec("layers/mlp/experts/w13/scales", (40, 16, 21504, 24),
                      mesh=MESH16, mode="train") == P(None, "model", None, None)
    # row-parallel expert: within-expert contraction whole -> groups whole too
    assert param_spec("layers/mlp/experts/w2/scales", (40, 16, 6144, 48),
                      mesh=MESH16, mode="serve") == P(None, "model", None, None)


def test_param_specs_descends_into_quantized_tensors():
    params = {"layers": {"mlp": {"w2": quantize_groupwise(jnp.ones((4, 64)), 32)}}}
    specs = param_specs(params, MESH16, "serve")
    qt = specs["layers"]["mlp"]["w2"]
    assert qt.qvalues == P(None, "model")   # out 4 indivisible; in -> model
    assert qt.scales == P(None, None)       # 2 groups % 16 -> whole


# ---------------------------------------------------------------------------
# pod meshes / outputs
# ---------------------------------------------------------------------------

def test_pod_mesh_dp_axes_and_batch_specs():
    assert dp_axes(POD) == ("pod", "data")
    specs = batch_specs({"tokens": jax.ShapeDtypeStruct((32, 8), jnp.int32),
                         "odd": jax.ShapeDtypeStruct((10, 8), jnp.int32)}, POD)
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["odd"] == P(None, None)    # 10 % 16 != 0


def test_logits_spec():
    assert logits_spec(MESH16, 2, 256) == P(("data",), "model")
    assert logits_spec(MESH16, 3, 3) == P(None, None, "model")


# ---------------------------------------------------------------------------
# kernels/gqmv._pick_block
# ---------------------------------------------------------------------------

def test_pick_block_prime_dim_falls_to_one():
    assert _pick_block(13, 8) == 1
    assert _pick_block(997, 256) == 1


def test_pick_block_dim_below_preferred():
    assert _pick_block(7, 256) == 7
    assert _pick_block(384, 1024, multiple_of=128) == 384


def test_pick_block_respects_multiple_of():
    assert _pick_block(2048, 256, multiple_of=256) == 256
    assert _pick_block(1024, 1024, multiple_of=256) == 1024


def test_pick_block_multiple_of_exceeds_dim_raises():
    with pytest.raises(ValueError):
        _pick_block(64, 256, multiple_of=128)
