"""pallas-contract fixture: arity/divisibility/cardinality/VMEM defects.

Never imported (fixtures are AST-only); ``kernel`` is a free name.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def bad_index_map_arity(x, m):
    bm = 128
    grid = (m // bm,)  # LINT: pallas-contract
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm,), lambda i, j: (i,))],  # LINT: pallas-contract
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
    )(x)


def bad_out_cardinality(x, m):
    grid = (8,)
    return pl.pallas_call(  # LINT: pallas-contract
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((8,), lambda i: (i,)),
                   pl.BlockSpec((8,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.float32)],
    )(x)


def bad_unused_prefetch(x, tables, b, kv, mb):
    grid_spec = pltpu.PrefetchScalarGridSpec(  # LINT: pallas-contract
        num_scalar_prefetch=2,
        grid=(b, kv, mb),
        in_specs=[pl.BlockSpec((1, 8, 16), lambda i, j, k, t, p: (i, j, 0))],
        out_specs=pl.BlockSpec((1, 8, 16), lambda i, j, k, t, p: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((8, 16), jnp.float32),
    )(tables, x)


def bad_vmem_budget(x):
    big = 4096
    return pl.pallas_call(  # LINT: pallas-contract
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((big, big), lambda i: (0, i))],
        out_specs=pl.BlockSpec((big, big), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((big, big), jnp.float32),
    )(x)
