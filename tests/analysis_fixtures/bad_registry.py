"""registry-coverage fixture: Model() constructions hiding behind defaults.

Analyzed with RegistryCoverageChecker(registry_glob="*bad_registry.py").
"""


def build(cfg):
    if cfg.kind == "recurrent":
        return Model(  # LINT: registry-coverage
            cfg=cfg,
            init=None,
            decode=None,
        )
    return Model(  # LINT: registry-coverage
        cfg=cfg,
        init=None,
        decode=None,
        supports_lengths=True,
        supports_paged=True,
        cache_kind="kv",
    )
