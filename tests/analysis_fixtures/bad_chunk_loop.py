"""host-sync chunk-loop fixture: per-item syncs and a blown path budget.

Analyzed with HostSyncChecker(loop_files=("*bad_chunk_loop.py",)).
"""

import jax
import numpy as np


class Sched:
    def serve(self, requests):
        pending = list(requests)
        out = []
        while pending:
            for r in pending:
                out.append(jax.device_get(r))  # LINT: host-sync
            a = jax.device_get(pending)
            b = jax.device_get(pending)
            c = jax.device_get(pending)  # LINT: host-sync
            pending = pending[1:]
            out.extend((a, b, c))
        return out


class CastSched:
    """Implicit casts on device values inside a per-item for: each one is a
    hidden ``.item()``."""

    def serve(self, requests):
        pending = list(requests)
        out = []
        while pending:
            logits_d = self._step(pending)       # *_d naming convention
            total = self._count(pending)         # tainted: self._* call
            for r in pending:
                out.append(float(logits_d))      # LINT: host-sync
                out.append(int(total))           # LINT: host-sync
                out.append(np.asarray(logits_d))  # LINT: host-sync
            pending = pending[1:]
        return out
