"""host-sync chunk-loop fixture: per-item syncs and a blown path budget.

Analyzed with HostSyncChecker(loop_files=("*bad_chunk_loop.py",)).
"""

import jax


class Sched:
    def serve(self, requests):
        pending = list(requests)
        out = []
        while pending:
            for r in pending:
                out.append(jax.device_get(r))  # LINT: host-sync
            a = jax.device_get(pending)
            b = jax.device_get(pending)
            c = jax.device_get(pending)  # LINT: host-sync
            pending = pending[1:]
            out.extend((a, b, c))
        return out
