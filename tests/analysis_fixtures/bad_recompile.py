"""recompile-guard fixture: jit-in-loop and unhashable static args."""

from functools import partial

import jax


def jit_per_iteration(xs):
    outs = []
    for x in xs:
        f = jax.jit(lambda v: v + 1)  # LINT: recompile-guard
        outs.append(f(x))
    return outs


def jit_decorator_in_loop(xs):
    outs = []
    for x in xs:
        @jax.jit  # LINT: recompile-guard
        def g(v):
            return v * 2
        outs.append(g(x))
    return outs


@partial(jax.jit, static_argnames=("dims",))
def reshaped(x, dims):
    return x.reshape(dims)


sliced = jax.jit(lambda x, n: x[:n], static_argnums=(1,))


def callers(x):
    a = reshaped(x, dims=[2, 2])  # LINT: recompile-guard
    b = sliced(x, [1])  # LINT: recompile-guard
    return a, b
