"""pallas-contract fixture: guarded blocks, matched arities, sane VMEM."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_block(dim, preferred):
    cand = min(preferred, dim)
    while dim % cand:
        cand //= 2
    return cand


def guarded_blocks(x, m, n):
    bm = _pick_block(m, 256)          # guard: *pick_block* assignment
    bq = 256
    while n % bq:                     # guard: % descent
        bq //= 2
    grid = (m // bm, n // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bq), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, bm), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
    )(x)


def prefetch_grid(x, tables, b, kv, mb):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, mb),
        in_specs=[pl.BlockSpec((1, 8, 16), lambda i, j, k, t, p: (t[i, j], j, 0))],
        out_specs=pl.BlockSpec((1, 8, 16), lambda i, j, k, t, p: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((8, 16), jnp.float32),
    )(tables, x)
