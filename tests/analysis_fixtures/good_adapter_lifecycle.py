"""adapter-lifecycle fixture: paired alloc/free, san_state, clean serve."""


class PooledAdapter:
    kind = "pooled"

    def on_admit(self, s, r, budget):
        self.blocks[s] = self.pool.alloc(4)

    def on_finish(self, s):
        self.pool.free(self.blocks.pop(s))

    def san_state(self):
        return {"pool": self.pool, "table": None}


def serve(adapter, requests):
    cache = adapter.begin_serve()
    pending = list(requests)
    while pending:
        if not pending[0]:
            break
        pending = pending[1:]
    adapter.end_serve()
    return cache
