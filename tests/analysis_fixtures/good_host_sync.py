"""host-sync fixture: clean jitted scopes and host-side conversions."""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def pure_math(x):
    return jnp.tanh(x) * 2.0


def host_side(xs):
    # np.asarray on a host list (untainted, no *_d suffix): not a sync site
    arr = np.asarray(xs)
    y = pure_math(jnp.asarray(arr))
    return jax.device_get(y)  # outside any jitted scope / serve loop
