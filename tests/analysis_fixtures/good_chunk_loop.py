"""host-sync chunk-loop fixture: budget respected, split over continue arms.

Analyzed with HostSyncChecker(loop_files=("*good_chunk_loop.py",)).
"""

import jax


class Sched:
    def serve(self, requests):
        pending = list(requests)
        out = []
        while pending:
            admission = jax.device_get(pending)       # sync 1 (both paths)
            if not out:
                out.append(jax.device_get(admission))  # sync 2, spec arm
                continue
            chunk = jax.device_get(pending)            # sync 2, vanilla arm
            steps = int(chunk)   # already fetched: cast is host-side, clean
            pending = pending[1:]
            out.extend((admission, chunk, steps))
        return out
