"""adapter-lifecycle fixture: leaked allocs, missing san_state, early return.

Never imported (fixtures are AST-only); attribute targets are free names.
"""


class LeakyAdapter:  # LINT: adapter-lifecycle (kind without san_state)
    kind = "leaky"

    def on_admit(self, s, r, budget):
        self.blocks[s] = self.pool.alloc(4)  # LINT: adapter-lifecycle

    def on_finish(self, s):
        self.blocks.pop(s)   # drops the bookkeeping, never pool.free


def serve_forever(adapter, requests):
    cache = adapter.begin_serve()  # LINT: adapter-lifecycle (no end_serve)
    pending = list(requests)
    while pending:
        if not pending[0]:
            return cache  # LINT: adapter-lifecycle (return inside serve loop)
        pending = pending[1:]
    return cache
