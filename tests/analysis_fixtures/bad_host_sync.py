"""host-sync fixture: syncs inside jitted scopes. NOT imported — AST only."""

from functools import partial

import jax


@jax.jit
def jitted_device_get(x):
    y = jax.device_get(x)  # LINT: host-sync
    return y


@partial(jax.jit, donate_argnums=(0,))
def jitted_item(x):
    return x.item()  # LINT: host-sync


def passed_to_jit(x):
    jax.block_until_ready(x)  # LINT: host-sync
    return x


run = jax.jit(passed_to_jit)
