"""registry-coverage fixture matrix: stale, incomplete, and overstated.

Used as ``matrix_path`` with injected fake archs (test_analysis.py):
- arch-a has supports_paged=True but PAGED_ARCHS is empty (untested path)
- RAGGED_ARCHS names an arch the registry doesn't know
- SPEC_ARCHS is missing entirely
"""

RAGGED_ARCHS = [
    "arch-a",
    "unknown-arch",
]

PAGED_ARCHS = []
