"""recompile-guard fixture: hoisted jits, hashable statics."""

from functools import partial

import jax


@partial(jax.jit, static_argnames=("dims",))
def reshaped(x, dims):
    return x.reshape(dims)


step = jax.jit(lambda x, n: x[:n], static_argnums=(1,))


def run(xs):
    outs = []
    for x in xs:                      # jit built once, reused per iteration
        outs.append(reshaped(x, dims=(2, 2)))
        outs.append(step(x, 1))
    return outs
