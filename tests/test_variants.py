"""Perf-variant (flags) correctness: optimized paths must be numerically
equivalent to the baseline paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flags
from repro.models.registry import build, load_config, smoke_batch


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "internlm2-1.8b", "gemma2-2b"])
def test_deferred_decode_matches_baseline(arch):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, batch=2, seq=6)
    logits_p, cache = model.prefill(params, batch, 12)

    tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
    base_logits, base_cache = model.decode(params, tok, cache, jnp.int32(6))
    with flags.overrides(deferred_decode_cache=True):
        opt_logits, opt_cache = model.decode(params, tok, cache, jnp.int32(6))

    np.testing.assert_allclose(np.asarray(opt_logits), np.asarray(base_logits),
                               rtol=2e-3, atol=2e-3)
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(opt_cache[key]),
                                   np.asarray(base_cache[key]),
                                   rtol=2e-3, atol=2e-3)


def test_deferred_decode_multi_step(arch="tinyllama-1.1b"):
    """Three consecutive deferred steps == three baseline steps."""
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = smoke_batch(cfg, batch=1, seq=4)
    _, cache_a = model.prefill(params, batch, 10)
    cache_b = jax.tree.map(jnp.copy, cache_a)

    tok = jnp.asarray([3], jnp.int32)
    for step in range(3):
        pos = jnp.int32(4 + step)
        la, cache_a = model.decode(params, tok, cache_a, pos)
        with flags.overrides(deferred_decode_cache=True):
            lb, cache_b = model.decode(params, tok, cache_b, pos)
        np.testing.assert_allclose(np.asarray(lb), np.asarray(la), rtol=2e-3, atol=2e-3)
        tok = jnp.argmax(la, -1).astype(jnp.int32)


def test_flags_override_restores():
    assert flags.get("deferred_decode_cache") is False
    with flags.overrides(deferred_decode_cache=True):
        assert flags.get("deferred_decode_cache") is True
    assert flags.get("deferred_decode_cache") is False


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b"])
def test_blockwise_attention_matches_baseline(arch):
    """Chunked online-softmax forward == naive full-softmax forward."""
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = smoke_batch(cfg, batch=2, seq=32)
    base = model.forward(params, batch, remat=False)
    with flags.overrides(blockwise_attention=True, attention_chunk=8):
        opt = model.forward(params, batch, remat=False)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base), rtol=3e-3, atol=3e-3)


def test_blockwise_prefill_matches_baseline():
    cfg = load_config("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(4))
    batch = smoke_batch(cfg, batch=1, seq=16)
    base_logits, base_cache = model.prefill(params, batch, 24)
    with flags.overrides(blockwise_attention=True, attention_chunk=4):
        opt_logits, opt_cache = model.prefill(params, batch, 24)
    np.testing.assert_allclose(np.asarray(opt_logits), np.asarray(base_logits),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(opt_cache["k"]), np.asarray(base_cache["k"]),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "internlm2-1.8b"])
def test_kvt_cache_layout_matches_baseline(arch):
    """(B,KV,T,hd) cache layout + deferred commit == baseline decode."""
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(5))
    batch = smoke_batch(cfg, batch=2, seq=6)
    base_logits, base_cache = model.prefill(params, batch, 12)
    tok = jnp.argmax(base_logits, -1).astype(jnp.int32)
    ref_logits, _ = model.decode(params, tok, base_cache, jnp.int32(6))

    with flags.overrides(kvt_cache_layout=True):
        kvt_plogits, kvt_cache = model.prefill(params, batch, 12)
        np.testing.assert_allclose(np.asarray(kvt_plogits), np.asarray(base_logits),
                                   rtol=2e-3, atol=2e-3)
        opt_logits, opt_cache = model.decode(params, tok, kvt_cache, jnp.int32(6))
        # second step exercises the committed rows
        tok2 = jnp.argmax(opt_logits, -1).astype(jnp.int32)
        opt2, _ = model.decode(params, tok2, opt_cache, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(opt_logits), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)
    # baseline second step for comparison
    _, base_cache2 = model.decode(params, tok, base_cache, jnp.int32(6))
    ref2, _ = model.decode(params, tok2, base_cache2, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(opt2), np.asarray(ref2), rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_matches_baseline():
    """int8-quantized KV cache decode tracks the fp32-cache decode closely
    (paper Table IV error scale) and generation stays consistent."""
    cfg = load_config("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(6))
    batch = smoke_batch(cfg, batch=2, seq=6)
    ref_plogits, ref_cache = model.prefill(params, batch, 12)
    tok = jnp.argmax(ref_plogits, -1).astype(jnp.int32)
    ref1, ref_cache = model.decode(params, tok, ref_cache, jnp.int32(6))

    with flags.overrides(int8_kv_cache=True):
        q_plogits, q_cache = model.prefill(params, batch, 12)
        np.testing.assert_allclose(np.asarray(q_plogits), np.asarray(ref_plogits),
                                   rtol=0.1, atol=0.1)
        q1, q_cache = model.decode(params, tok, q_cache, jnp.int32(6))
        tok2 = jnp.argmax(q1, -1).astype(jnp.int32)
        q2, _ = model.decode(params, tok2, q_cache, jnp.int32(7))
    # quantized-cache logits track fp32-cache logits within int8 error
    rel = np.linalg.norm(np.asarray(q1) - np.asarray(ref1)) / np.linalg.norm(np.asarray(ref1))
    assert rel < 0.05, rel
    assert bool(jnp.all(jnp.isfinite(q2)))
    assert q_cache["k_q"].dtype == jnp.int8


def test_zamba_deferred_decode_matches_baseline():
    cfg = load_config("zamba2-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(7))
    batch = smoke_batch(cfg, batch=2, seq=6)
    plogits, cache = model.prefill(params, batch, 12)
    tok = jnp.argmax(plogits, -1).astype(jnp.int32)
    ref, _ = model.decode(params, tok, cache, jnp.int32(6))
    with flags.overrides(kvt_cache_layout=True):
        p2, cache2 = model.prefill(params, batch, 12)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(plogits), rtol=2e-3, atol=2e-3)
        opt, cache2 = model.decode(params, tok, cache2, jnp.int32(6))
        tok2 = jnp.argmax(opt, -1).astype(jnp.int32)
        opt2, _ = model.decode(params, tok2, cache2, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(opt), np.asarray(ref), rtol=2e-3, atol=2e-3)
    assert bool(jnp.all(jnp.isfinite(opt2)))


def test_chunked_ssd_matches_scan():
    """Mamba2 chunked-SSD (matmul duality) == per-step recurrence."""
    cfg = load_config("zamba2-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(8))
    batch = smoke_batch(cfg, batch=2, seq=32)
    base = model.forward(params, batch, remat=False)
    with flags.overrides(chunked_ssd=True, ssd_chunk=8):
        opt = model.forward(params, batch, remat=False)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base), rtol=5e-3, atol=5e-3)


def test_chunked_ssd_prefill_state_matches():
    """Chunked prefill leaves the same SSM state as the step recurrence,
    so decode continues correctly."""
    cfg = load_config("zamba2-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(9))
    batch = smoke_batch(cfg, batch=1, seq=16)
    _, cache_a = model.prefill(params, batch, 20)
    with flags.overrides(chunked_ssd=True, ssd_chunk=4):
        _, cache_b = model.prefill(params, batch, 20)
    np.testing.assert_allclose(np.asarray(cache_b["mamba"]["h"]),
                               np.asarray(cache_a["mamba"]["h"]), rtol=5e-3, atol=5e-3)
    tok = jnp.asarray([1], jnp.int32)
    la, _ = model.decode(params, tok, cache_a, jnp.int32(16))
    lb, _ = model.decode(params, tok, cache_b, jnp.int32(16))
    np.testing.assert_allclose(np.asarray(lb), np.asarray(la), rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["minicpm3-4b", "deepseek-v2-lite-16b"])
def test_mla_deferred_decode_matches_baseline(arch):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(10))
    batch = smoke_batch(cfg, batch=2, seq=6)
    plogits, cache = model.prefill(params, batch, 12)
    tok = jnp.argmax(plogits, -1).astype(jnp.int32)
    ref, ref_cache = model.decode(params, tok, cache, jnp.int32(6))
    with flags.overrides(deferred_decode_cache=True):
        opt, opt_cache = model.decode(params, tok, cache, jnp.int32(6))
        tok2 = jnp.argmax(opt, -1).astype(jnp.int32)
        opt2, _ = model.decode(params, tok2, opt_cache, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(opt), np.asarray(ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(opt_cache["ckv"]), np.asarray(ref_cache["ckv"]),
                               rtol=2e-3, atol=2e-3)
    assert bool(jnp.all(jnp.isfinite(opt2)))
