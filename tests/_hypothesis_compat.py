"""Real hypothesis when installed, else a deterministic stand-in.

The container bakes in the jax toolchain but not hypothesis; the fallback
``given`` draws ``max_examples`` pseudo-random examples from the declared
strategies with a fixed seed, so the property tests still sweep shapes and
scales (just without shrinking / example databases).
"""

from __future__ import annotations

import random

try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda r: r.choice(options))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples", 10)
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 10)
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
