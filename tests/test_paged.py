"""Paged KV cache + the three serving bugfixes of this PR.

Tentpole regressions: the paged decode path must be BIT-exact against the
contiguous deferred decode (identity block tables), the Pallas paged
kernel must match the gather oracle, and the paged scheduler must produce
token-identical greedy outputs to the contiguous slot scheduler across
GQA variants (tinyllama, gemma2 sliding-window+softcap, internlm2) while
resident blocks scale with live tokens.

Satellite regressions (each failed before its fix):
- top-p value-threshold filtering kept every token tied with the cutoff
  logit (whole vocab on tied logits) and make_sampler's p/temperature were
  unreachable from generate/serve;
- finished slots kept decoding with stale tok while pos advanced every
  chunk, drifting past cache_len;
- EOS-less engines padded responses with literal token 0, indistinguishable
  from a real vocab-0 token.
"""

import math
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flags
from repro.models.common import decode_mask
from repro.models.registry import build, load_config
from repro.serving.batching import (
    Request,
    SlotScheduler,
    serve_bucketed,
    serve_continuous,
    serve_ragged,
)
from repro.serving.engine import InferenceEngine
from repro.serving.paged import BlockPool, PagedScheduler, serve_paged
from repro.serving.sampling import make_sampler, nucleus_mask

MESH16 = SimpleNamespace(shape={"data": 16, "model": 16},
                         axis_names=("data", "model"))


@pytest.fixture(scope="module")
def tiny():
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(tiny):
    _, model, params = tiny
    return InferenceEngine(model, params, cache_len=40)


def _direct(engine, prompt, n, **kw):
    res = engine.generate({"tokens": jnp.asarray([prompt], jnp.int32)}, n, **kw)
    return np.asarray(res.tokens[0])


PROMPTS = [[5, 3], [7, 1, 4], list(range(1, 11)), list(range(2, 14))]


# ---------------------------------------------------------------------------
# satellite 1: top-p nucleus + sampler-kwarg plumbing
# ---------------------------------------------------------------------------

def test_nucleus_mask_no_overinclusion_on_ties():
    """All-tied logits, p=0.5 over 8 tokens: the minimal set is 4 tokens.
    The old `logits >= cutoff` filter kept all 8 (the cutoff VALUE ties with
    every token), inflating the nucleus to the whole vocab."""
    kept = np.asarray(nucleus_mask(jnp.zeros((1, 8)), 0.5))
    assert kept.sum() == 4, kept


def test_nucleus_mask_mass_property():
    """Minimal-mass property on random logits: kept mass reaches p, and
    dropping the smallest kept token falls below p (no over-inclusion)."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32) * 3)
    kept = np.asarray(nucleus_mask(logits, 0.7))
    probs = np.asarray(jax.nn.softmax(logits, -1))
    for i in range(16):
        mass = probs[i][kept[i]].sum()
        assert mass >= 0.7 - 1e-6
        assert mass - probs[i][kept[i]].min() < 0.7
    # top token always survives even for tiny p
    assert np.all(np.asarray(nucleus_mask(logits, 1e-9)).sum(-1) == 1)


def test_sampler_kwargs_reach_engine_and_schedulers(engine):
    """p -> 0 nucleus == greedy: if the kwargs didn't reach the sampler the
    default p=0.9 would diverge from greedy on these random-weight logits.
    (Before the fix, generate/serve had no way to pass them at all.)"""
    skw = {"p": 1e-9, "temperature": 1.0}
    greedy = [_direct(engine, p, 6) for p in PROMPTS]
    got = [_direct(engine, p, 6, sampler="top_p", sampler_kw=skw)
           for p in PROMPTS]
    for g, w in zip(got, greedy):
        np.testing.assert_array_equal(g, w)
    reqs = [Request(i, p) for i, p in enumerate(PROMPTS)]
    for mode in ("bucketed", "continuous", "paged"):
        out = serve_ragged(engine, reqs, 6, sampler="top_p", sampler_kw=skw,
                           mode=mode)
        for r, w in zip(out, greedy):
            np.testing.assert_array_equal(r.tokens, w)


def test_make_sampler_rejects_greedy_kwargs():
    with pytest.raises(ValueError, match="greedy"):
        make_sampler("greedy", p=0.9)


# ---------------------------------------------------------------------------
# satellite 2: finished-slot freeze in the contiguous scheduler
# ---------------------------------------------------------------------------

def test_slot_scheduler_freezes_finished_slots(tiny):
    """One slot finishes at budget 2 while its neighbor decodes 20 more
    tokens with nothing pending: the dead slot's position must freeze at its
    finish point instead of advancing every chunk toward (and past)
    cache_len. Outputs must still match direct generation."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40)
    sched = SlotScheduler(eng, slots=2, chunk=2)
    reqs = [Request(0, PROMPTS[0], max_new=2), Request(1, PROMPTS[1], max_new=22)]
    out = sched.serve(reqs, 22)
    for r, req in zip(out, reqs):
        np.testing.assert_array_equal(
            r.tokens, _direct(eng, req.tokens, req.max_new))
    pos = sched.last_positions
    # slot of request 0: prompt len 2 + first token + 1 committed decode
    # step before the freeze kicked in at its finish
    assert int(pos.min()) <= len(PROMPTS[0]) + 2, pos
    assert int(pos.max()) < eng.cache_len, pos


def test_slot_scheduler_long_trace_positions_stay_bounded(tiny):
    """Long mixed-budget trace through few slots with a tight cache: every
    live position must stay < cache_len (host-asserted each chunk) and every
    response must match direct generation."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40)
    budgets = [2, 26, 3, 5, 2, 4]
    reqs = [Request(i, PROMPTS[i % len(PROMPTS)], max_new=b)
            for i, b in enumerate(budgets)]
    out = serve_continuous(eng, reqs, 26, slots=2, chunk=4)
    for r, req in zip(out, reqs):
        np.testing.assert_array_equal(
            r.tokens, _direct(eng, req.tokens, req.max_new))
    assert int(np.max(np.asarray(out[1].tokens.shape))) == 26


# ---------------------------------------------------------------------------
# satellite 3: true generated length on Response
# ---------------------------------------------------------------------------

def test_response_length_without_eos(tiny):
    """eos_id=None: padding uses token 0, which is a legal vocab id — the
    true length must ride on the Response instead of being inferred."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40)   # eos None
    reqs = [Request(0, PROMPTS[0], max_new=3), Request(1, PROMPTS[2])]
    for out in (serve_bucketed(eng, reqs, 5),
                serve_continuous(eng, reqs, 5, slots=2, chunk=2),
                serve_paged(eng, reqs, 5, slots=2, chunk=2)):
        assert out[0].length == 3 and out[0].tokens.shape == (3,)
        assert out[1].length == 5 and out[1].tokens.shape == (5,)


def test_response_length_with_eos(tiny):
    """EOS mid-budget: length counts the real tokens (EOS inclusive), the
    tail is EOS padding."""
    _, model, params = tiny
    probe = InferenceEngine(model, params, cache_len=40)
    first = int(_direct(probe, PROMPTS[0], 1)[0])
    eng = InferenceEngine(model, params, cache_len=40, eos_id=first)
    reqs = [Request(0, PROMPTS[0])]
    for out in (serve_bucketed(eng, reqs, 4),
                serve_continuous(eng, reqs, 4, slots=2, chunk=2),
                serve_paged(eng, reqs, 4, slots=2, chunk=2)):
        assert out[0].length == 1
        assert np.all(np.asarray(out[0].tokens) == first)


# ---------------------------------------------------------------------------
# tentpole: block pool allocator
# ---------------------------------------------------------------------------

def test_block_pool_invariants():
    pool = BlockPool(8, 4)
    assert pool.free_blocks == 7            # block 0 is the sink
    a = pool.alloc(3)
    assert 0 not in a and len(set(a)) == 3
    assert pool.live_blocks == 3 and pool.peak_live == 3
    pool.free(a[:2])
    assert pool.free_blocks == 6 and pool.peak_live == 3
    b = pool.alloc(6)
    assert pool.peak_live == 7
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1)
    pool.free(b + a[2:])
    assert pool.live_blocks == 0


# ---------------------------------------------------------------------------
# tentpole: paged == contiguous parity
# ---------------------------------------------------------------------------

def test_paged_decode_bit_exact_vs_contiguous_deferred(tiny):
    """Identity block tables over a reshaped contiguous cache: the paged
    decode logits must be BITWISE equal to the contiguous deferred path."""
    from repro.models.transformer import contiguous_to_paged

    _, model, params = tiny
    rng = np.random.default_rng(3)
    cfg = load_config("tinyllama-1.1b").reduced()
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    lens = jnp.asarray([4, 6], jnp.int32)
    with flags.overrides(deferred_decode_cache=True):
        logits, cache = model.prefill(params, {"tokens": toks, "lengths": lens}, 16)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pool, table = contiguous_to_paged(cache, 8)
        pos = lens
        for _ in range(4):
            lc, cache = model.decode(params, tok, cache, pos)
            lp, pool = model.decode_paged(params, tok, pool, table, pos)
            np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))
            tok = jnp.argmax(lc, -1).astype(jnp.int32)
            pos = pos + 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b", "internlm2-1.8b"])
def test_paged_matches_continuous_greedy(arch):
    """Mixed-length mixed-budget trace: the paged scheduler must be
    token-identical to the contiguous slot scheduler AND direct generation
    across GQA variants (gemma2 exercises sliding window + softcap through
    the paged kernel path)."""
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, cache_len=40)
    budgets = [2, 6, 3, 5, 4]
    reqs = [Request(i, PROMPTS[i % len(PROMPTS)], max_new=b)
            for i, b in enumerate(budgets)]
    cont = serve_continuous(eng, reqs, 6, slots=2, chunk=2)
    paged = serve_paged(eng, reqs, 6, slots=2, chunk=2, block_size=8)
    for rc, rp, req in zip(cont, paged, reqs):
        want = _direct(eng, req.tokens, req.max_new)
        np.testing.assert_array_equal(rc.tokens, want)
        np.testing.assert_array_equal(rp.tokens, want)
        assert rc.length == rp.length


def test_paged_engine_generate_parity(tiny, engine):
    """engine.generate(paged=True): block-table decode over the identity
    pool must reproduce the contiguous tokens (uniform and ragged)."""
    rng = np.random.default_rng(1)
    cfg = tiny[0]
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (3, 7)), jnp.int32)}
    want = np.asarray(engine.generate(batch, 5).tokens)
    got = np.asarray(engine.generate(batch, 5, paged=True).tokens)
    np.testing.assert_array_equal(got, want)
    lens = np.asarray([3, 7, 5], np.int32)
    want = np.asarray(engine.generate(batch, 5, lengths=lens).tokens)
    got = np.asarray(engine.generate(batch, 5, lengths=lens, paged=True).tokens)
    np.testing.assert_array_equal(got, want)


def test_paged_backpressure_small_pool(tiny):
    """A pool far smaller than slots x cache_len: admission waits for block
    reclaim, outputs stay exact, and the allocator never exhausts."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40)
    budgets = [2, 6, 3, 5, 2, 4]
    reqs = [Request(i, PROMPTS[i % len(PROMPTS)], max_new=b)
            for i, b in enumerate(budgets)]
    # 9 usable blocks of 4 = 36 token slots, vs 3 slots x 40 = 120 contiguous
    sched = PagedScheduler(eng, slots=3, chunk=2, block_size=4, num_blocks=10)
    out = sched.serve(reqs, 6)
    for r, req in zip(out, reqs):
        np.testing.assert_array_equal(
            r.tokens, _direct(eng, req.tokens, req.max_new))
    assert sched.last_peak_blocks <= 9


def test_paged_resident_blocks_scale_with_live_tokens(tiny):
    """Short requests must not hold cache_len-sized regions: the pool's
    high-water mark stays well under the contiguous slots x cache_len
    equivalent."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40)
    reqs = [Request(i, PROMPTS[i % len(PROMPTS)], max_new=2) for i in range(6)]
    sched = PagedScheduler(eng, slots=3, chunk=2, block_size=8)
    sched.serve(reqs, 2)
    contiguous_equiv = 3 * math.ceil(40 / 8)          # slots x blocks(cache_len)
    assert sched.last_peak_blocks < contiguous_equiv // 2, (
        sched.last_peak_blocks, contiguous_equiv)


def test_auto_mode_falls_back_under_kv_layout_flags(engine):
    """mode="auto" must keep resolving to the contiguous scheduler under the
    kvt/int8 KV-cache flags — the paged pool only speaks the base float
    layout, and auto-mode serving worked with those flags before the paged
    scheduler became the preferred default."""
    from repro.serving.batching import resolve_mode

    assert resolve_mode(engine, "auto") == "paged"
    with flags.overrides(int8_kv_cache=True):
        assert resolve_mode(engine, "auto") == "continuous"
    with flags.overrides(kvt_cache_layout=True):
        assert resolve_mode(engine, "auto") == "continuous"
    assert resolve_mode(engine, "bucketed") == "bucketed"


def test_paged_validates_capacity_and_layout(tiny, engine):
    _, model, params = tiny
    sched = PagedScheduler(engine, slots=2, chunk=2, block_size=8)
    with pytest.raises(ValueError, match="cache slots"):
        sched.serve([Request(0, list(range(38)), max_new=8)], 8)
    with pytest.raises(ValueError, match="layout"):
        with flags.overrides(kvt_cache_layout=True):
            sched.serve([Request(0, [1, 2])], 2)
    rwkv = build(load_config("rwkv6-7b").reduced())
    reng = InferenceEngine(rwkv, rwkv.init(jax.random.PRNGKey(0)), cache_len=16)
    assert not rwkv.supports_paged
    with pytest.raises(ValueError, match="paged"):
        PagedScheduler(reng)
    mla = load_config("minicpm3-4b").reduced()
    assert not build(mla).supports_paged


# ---------------------------------------------------------------------------
# tentpole: kernel parity + sharding rule + snapshot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,softcap", [(None, None), (6, None), (None, 30.0)])
def test_paged_attention_kernel_vs_oracle(window, softcap):
    from repro.kernels.paged_attn import paged_attention_pallas
    from repro.kernels.ref import paged_attention_ref

    rng = np.random.default_rng(0)
    b, kv, g, hd, nb, bs, mb = 3, 2, 4, 16, 13, 8, 3
    q = jnp.asarray(rng.normal(size=(b, kv, g, hd)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(nb, bs, kv, hd)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(nb, bs, kv, hd)).astype(np.float32))
    table = jnp.asarray(
        rng.permutation(np.arange(1, nb))[: b * mb].reshape(b, mb).astype(np.int32))
    pos = jnp.asarray([3, 10, 21], jnp.int32)
    kn = jnp.asarray(rng.normal(size=(b, kv, hd)).astype(np.float32))
    vn = jnp.asarray(rng.normal(size=(b, kv, hd)).astype(np.float32))
    mask = decode_mask(mb * bs, pos, window)
    ref = paged_attention_ref(q, kp, vp, table, pos, kn, vn, mask,
                              scale=hd**-0.5, softcap=softcap)
    pal = paged_attention_pallas(q, kp, vp, table, pos, kn, vn, mask,
                                 scale=hd**-0.5, softcap=softcap, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=2e-5, atol=2e-5)


def test_paged_pool_sharding_never_splits_blocks():
    """`*_pages` leaves: kv heads -> model, block axis ALWAYS whole (blocks
    migrate between requests through the tables)."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import cache_spec

    spec = cache_spec("k_pages", (22, 4096, 16, 32, 128), mesh=MESH16, batch=4096)
    assert spec == P(None, None, None, "model", None)
    # heads not divisible -> fully replicated, block axis still whole
    spec = cache_spec("v_pages", (22, 4096, 16, 3, 128), mesh=MESH16, batch=4096)
    assert spec == P(None, None, None, None, None)


def test_snapshot_restore_carries_block_table(tiny, engine):
    _, model, _ = tiny
    cache = model.init_paged_cache(6, 8, jnp.float32)
    table = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
    snap = engine.snapshot(cache, jnp.asarray([4, 5]), jnp.asarray([7, 9]),
                           block_table=table)
    c2, pos2, toks2, table2 = engine.restore(snap)
    np.testing.assert_array_equal(np.asarray(table2), np.asarray(table))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 cache, c2)
    # contiguous snapshots keep the 3-tuple contract
    assert len(engine.restore(engine.snapshot(cache, pos2, toks2))) == 3
