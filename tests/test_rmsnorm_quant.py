"""Fused RMSNorm+quantize Pallas kernel vs composed oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels.rmsnorm_quant import rmsnorm_quant_pallas, rmsnorm_quant_ref


@pytest.mark.parametrize("m,n,gs", [(8, 128, 32), (64, 512, 256), (32, 2048, 256), (16, 256, 64)])
def test_matches_ref(m, n, gs):
    rng = np.random.default_rng(m + n)
    x = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    q, s = rmsnorm_quant_pallas(x, w, group_size=gs, interpret=True)
    qr, sr = rmsnorm_quant_ref(x, w, group_size=gs)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    # rounding at exactly .5 boundaries may differ by 1 ulp of int8
    assert np.mean(np.asarray(q) != np.asarray(qr)) < 1e-3


def test_block_invariance():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    w = jnp.ones((256,))
    a = rmsnorm_quant_pallas(x, w, group_size=64, block_m=8, interpret=True)
    b = rmsnorm_quant_pallas(x, w, group_size=64, block_m=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


@settings(deadline=None, max_examples=10)
@given(mi=st.integers(1, 4), gs=st.sampled_from([32, 64]), seed=st.integers(0, 2**31 - 1))
def test_property_bounds(mi, gs, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8 * mi, 2 * gs)).astype(np.float32))
    w = jnp.ones((2 * gs,))
    q, s = rmsnorm_quant_pallas(x, w, group_size=gs, interpret=True)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
    assert bool(jnp.all(s >= 0))
