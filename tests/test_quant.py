"""Unit + property tests for the group-wise W8A8 quantization substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.quant import (
    choose_group_size,
    dequantize,
    quantization_error_stats,
    quantize_activation,
    quantize_groupwise,
)

jax.config.update("jax_enable_x64", False)


def test_roundtrip_error_bound():
    """|r_hat - r| <= S/2 per element (half a quantization step)."""
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=(64, 512)).astype(np.float32))
    qt = quantize_groupwise(r, group_size=128)
    err = jnp.abs(dequantize(qt) - r)
    step = jnp.repeat(qt.scales, 128, axis=-1)
    assert bool(jnp.all(err <= step / 2 + 1e-7))


def test_scale_formula_matches_paper():
    """S = 2*max|r|/255 per group (Eq. 1)."""
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    qt = quantize_groupwise(r, group_size=64)
    g = np.asarray(r).reshape(4, 4, 64)
    expect = 2.0 * np.abs(g).max(-1) / 255.0
    np.testing.assert_allclose(np.asarray(qt.scales), expect, rtol=1e-6)


def test_int8_range_full():
    r = jnp.asarray([[1.0, -1.0] * 128])  # absmax 1 -> scale 2/255
    qt = quantize_groupwise(r, group_size=256)
    assert int(qt.qvalues.max()) == 127
    assert int(qt.qvalues.min()) == -127


def test_zero_group_safe():
    r = jnp.zeros((2, 256))
    qt = quantize_groupwise(r, group_size=256)
    assert bool(jnp.all(qt.qvalues == 0))
    assert bool(jnp.all(jnp.isfinite(dequantize(qt))))


def test_pytree_roundtrip():
    r = jnp.ones((8, 128))
    qt = quantize_groupwise(r, group_size=32)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qt2.group_size == 32
    np.testing.assert_array_equal(np.asarray(qt2.qvalues), np.asarray(qt.qvalues))


def test_indivisible_raises():
    with pytest.raises(ValueError):
        quantize_groupwise(jnp.ones((2, 100)), group_size=256)


def test_choose_group_size():
    assert choose_group_size([2048, 5632]) == 256     # TinyLlama dims (paper)
    assert choose_group_size([2048, 1408]) == 128     # deepseek-v2-lite ffn
    assert choose_group_size([2304, 9216]) == 256     # gemma2? 2304/256=9 ok
    with pytest.raises(ValueError):
        choose_group_size([33])


def test_error_stats_sane():
    rng = np.random.default_rng(2)
    r = jnp.asarray(rng.normal(scale=0.02, size=(256, 2048)).astype(np.float32))
    stats = quantization_error_stats(r, group_size=256)
    # paper Table IV: mean 2.65e-4 on TinyLlama weights; same order here
    assert 0 < stats["mean"] < 1e-3
    assert stats["max"] < 0.05
    assert stats["min"] >= 0.0


@settings(deadline=None, max_examples=25)
@given(
    rows=st.integers(1, 8),
    groups=st.integers(1, 4),
    gs=st.sampled_from([32, 64, 128]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_roundtrip(rows, groups, gs, scale, seed):
    """Property: round-trip error bounded by half-step for any shape/scale."""
    rng = np.random.default_rng(seed)
    r = jnp.asarray((rng.normal(size=(rows, groups * gs)) * scale).astype(np.float32))
    qt = quantize_groupwise(r, group_size=gs)
    err = np.abs(np.asarray(dequantize(qt)) - np.asarray(r))
    halfstep = np.repeat(np.asarray(qt.scales), gs, axis=-1) / 2
    assert np.all(err <= halfstep + 1e-6 * scale)


def test_activation_quant_matches_weight_quant():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    a = quantize_activation(x, group_size=128)
    w = quantize_groupwise(x, group_size=128)
    np.testing.assert_array_equal(np.asarray(a.qvalues), np.asarray(w.qvalues))
