"""Serving engine tests: W8A8 vs float baseline, generation invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import quantize_params, quantized_fraction
from repro.core.quant import QuantizedTensor
from repro.models.registry import build, load_config, smoke_batch
from repro.serving.engine import InferenceEngine


def _tiny(arch="tinyllama-1.1b"):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_quantize_params_policy():
    cfg, model, params = _tiny()
    qp = quantize_params(params, cfg.group_size)
    # attention/FFN/embed/classifier quantized; norms not
    assert isinstance(qp["layers"]["attn"]["wqkv"], QuantizedTensor)
    assert isinstance(qp["layers"]["mlp"]["w13"], QuantizedTensor)
    assert isinstance(qp["embed"], QuantizedTensor)
    assert isinstance(qp["classifier"], QuantizedTensor)
    assert not isinstance(qp["layers"]["att_norm"], QuantizedTensor)
    frac = quantized_fraction(qp)
    assert frac > 0.95  # paper: 4.4GB -> 1.1GB, i.e. nearly all bytes int8


def test_quantized_forward_close_to_float():
    cfg, model, params = _tiny()
    batch = smoke_batch(cfg, batch=2, seq=12)
    ref = model.forward(params, batch, remat=False)
    qp = quantize_params(params, cfg.group_size)
    got = model.forward(qp, batch, remat=False)
    # W8A8 logits track fp32 logits closely (paper Table V: +0.57% PPL)
    err = np.abs(np.asarray(got) - np.asarray(ref))
    rel = np.linalg.norm(err) / np.linalg.norm(np.asarray(ref))
    assert rel < 0.06, rel


def test_generate_greedy_deterministic():
    cfg, model, params = _tiny()
    eng = InferenceEngine(model, params, cache_len=24)
    batch = {"tokens": smoke_batch(cfg, batch=2, seq=8)["tokens"]}
    r1 = eng.generate(batch, 8)
    r2 = eng.generate(batch, 8)
    np.testing.assert_array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))
    assert r1.tokens.shape == (2, 8)
    assert bool(jnp.all(r1.tokens >= 0)) and bool(jnp.all(r1.tokens < cfg.vocab_padded))


def test_generate_matches_stepwise_decode():
    """Engine's scanned decode == manual prefill + decode loop."""
    cfg, model, params = _tiny()
    eng = InferenceEngine(model, params, cache_len=16)
    batch = {"tokens": smoke_batch(cfg, batch=1, seq=6)["tokens"]}
    res = eng.generate(batch, 4)

    logits, cache = model.prefill(params, batch, 16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    pos = 6
    for _ in range(3):
        logits, cache = model.decode(params, tok, cache, jnp.int32(pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
        pos += 1
    manual = jnp.stack(toks, axis=1)
    np.testing.assert_array_equal(np.asarray(res.tokens), np.asarray(manual))


def test_quantized_generation_quality():
    """Greedy generations from W8A8 and fp32 agree on most steps for a tiny
    random model (sanity check on end-to-end quantized serving)."""
    cfg, model, params = _tiny()
    fp = InferenceEngine(model, params, cache_len=24)
    q = InferenceEngine(model, params, cache_len=24, quantize=True)
    assert q.quantized_fraction > 0.9
    batch = {"tokens": smoke_batch(cfg, batch=2, seq=8)["tokens"]}
    rf = fp.generate(batch, 6)
    rq = q.generate(batch, 6)
    agree = float(np.mean(np.asarray(rf.tokens) == np.asarray(rq.tokens)))
    assert agree >= 0.5, agree  # random-weight logits are near-uniform; exact
    # agreement is not expected, gross divergence is a bug


def test_top_p_sampler_runs():
    cfg, model, params = _tiny()
    eng = InferenceEngine(model, params, cache_len=16)
    batch = {"tokens": smoke_batch(cfg, batch=2, seq=4)["tokens"]}
    res = eng.generate(batch, 4, sampler="top_p", key=jax.random.PRNGKey(7))
    assert res.tokens.shape == (2, 4)


def test_eos_freezes_sequence():
    cfg, model, params = _tiny()
    eng = InferenceEngine(model, params, cache_len=16, eos_id=0)
    batch = {"tokens": smoke_batch(cfg, batch=1, seq=4)["tokens"]}
    res = eng.generate(batch, 6)
    t = np.asarray(res.tokens)[0]
    hit = np.where(t == 0)[0]
    if hit.size:  # once EOS appears, everything after stays EOS
        assert np.all(t[hit[0]:] == 0)


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-7b", "gemma2-2b"])
def test_engine_other_families(arch):
    cfg, model, params = _tiny(arch)
    eng = InferenceEngine(model, params, cache_len=16, quantize=True)
    batch = {"tokens": smoke_batch(cfg, batch=2, seq=6)["tokens"]}
    res = eng.generate(batch, 4)
    assert res.tokens.shape == (2, 4)
    assert bool(jnp.all(jnp.isfinite(res.logits_last)))


def test_serve_ragged_buckets():
    from repro.serving.batching import Request, bucket_length, serve_ragged

    assert bucket_length(5) == 8 and bucket_length(8) == 8 and bucket_length(9) == 16
    cfg, model, params = _tiny()
    eng = InferenceEngine(model, params, cache_len=40, quantize=True)
    reqs = [Request(0, [1, 2, 3]), Request(1, list(range(10))),
            Request(2, [4, 5]), Request(3, list(range(12)))]
    out = serve_ragged(eng, reqs, 6)
    assert [r.id for r in out] == [0, 1, 2, 3]
    for r in out:
        assert r.tokens.shape == (6,)
        assert (r.tokens >= 0).all() and (r.tokens < cfg.vocab_padded).all()


def test_serve_ragged_matches_direct():
    """A bucketed request decodes identically to a direct uniform batch."""
    from repro.serving.batching import Request, serve_ragged
    import numpy as np

    cfg, model, params = _tiny()
    eng = InferenceEngine(model, params, cache_len=24)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    direct = eng.generate({"tokens": jnp.asarray([prompt], jnp.int32)}, 5)
    ragged = serve_ragged(eng, [Request(0, prompt)], 5)
    np.testing.assert_array_equal(np.asarray(direct.tokens[0]), ragged[0].tokens)
