"""QuantFormat registry: packed int4, mixed precision, ckpt/sharding glue."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import (
    FP8_MAX,
    QuantizedTensor,
    available_formats,
    choose_group_size,
    dequantize,
    get_format,
    largest_pow2_group,
    pack_int3,
    pack_int4,
    quantization_error_stats,
    quantize,
    quantize_fp8,
    quantize_groupwise,
    quantize_int3,
    quantize_int4,
    unpack_int3,
    unpack_int4,
)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(available_formats()) >= {"int8", "int4"}
    f8, f4 = get_format("int8"), get_format("int4")
    assert (f8.bits, f8.pack, f8.qmax) == (8, 1, 127)
    assert (f4.bits, f4.pack, f4.qmax) == (4, 2, 7)
    with pytest.raises(ValueError, match="unknown quant format"):
        get_format("fp3")


def test_int8_via_registry_bit_identical():
    """The registry's int8 path IS quantize_groupwise — same arrays, same
    scales, same fmt aux (the acceptance bar for the redesign)."""
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    a = quantize_groupwise(r, 64)
    b = quantize(r, 64, "int8")
    np.testing.assert_array_equal(np.asarray(a.qvalues), np.asarray(b.qvalues))
    np.testing.assert_array_equal(np.asarray(a.scales), np.asarray(b.scales))
    assert a.fmt == b.fmt == "int8"
    np.testing.assert_array_equal(
        np.asarray(dequantize(a)), np.asarray(dequantize(b))
    )


# ---------------------------------------------------------------------------
# int4 packing
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip_exact():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.integers(-7, 8, size=(16, 64)).astype(np.int8))
    p = pack_int4(q)
    assert p.shape == (16, 32) and p.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(p)), np.asarray(q))


def test_pack_odd_axis_raises():
    with pytest.raises(ValueError, match="even last axis"):
        pack_int4(jnp.zeros((4, 33), jnp.int8))


def test_int4_quantize_shapes_and_range():
    rng = np.random.default_rng(2)
    r = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    qt = quantize_int4(r, 64)
    assert qt.fmt == "int4"
    assert qt.storage_shape == (8, 128)         # packed
    assert qt.shape == qt.logical_shape == (8, 256)
    assert qt.scales.shape == (8, 4)
    vals = np.asarray(unpack_int4(qt.qvalues))
    assert vals.max() <= 7 and vals.min() >= -7
    assert vals.max() == 7 or vals.min() == -7  # full range used per Eq. 1


def test_int4_roundtrip_error_bound():
    """|r_hat - r| <= S/2 per element, S = 2*max|r|/15 per group."""
    rng = np.random.default_rng(3)
    r = jnp.asarray(rng.normal(size=(64, 512)).astype(np.float32))
    qt = quantize_int4(r, 128)
    err = np.abs(np.asarray(dequantize(qt)) - np.asarray(r))
    half = np.repeat(np.asarray(qt.scales), 128, axis=-1) / 2
    assert np.all(err <= half + 1e-6)


def test_int4_zero_group_safe():
    qt = quantize_int4(jnp.zeros((2, 64)), 32)
    assert bool(jnp.all(qt.qvalues == 0))
    assert bool(jnp.all(jnp.isfinite(dequantize(qt))))


def test_int4_groupwise_beats_per_tensor():
    """Group-wise fp32 scales must beat one scale per tensor at 4 bits
    (rows with wildly different magnitudes — the regime PTQ actually sees)."""
    rng = np.random.default_rng(4)
    rows = [rng.normal(size=(1, 512)) * 10.0 ** (i % 5 - 2) for i in range(16)]
    r = np.concatenate(rows).astype(np.float32)
    stats = quantization_error_stats(jnp.asarray(r), 64, "int4")
    s = 2.0 * np.abs(r).max() / 15.0
    naive = np.clip(np.round(r / s), -7, 7) * s
    naive_err = np.abs(naive - r)
    naive_rel = naive_err / np.abs(r)
    assert stats["mean"] < float(naive_err.mean()), (stats["mean"], naive_err.mean())
    # the decisive effect: one per-tensor scale flattens small-magnitude rows
    # to ~100% relative error; per-group scales keep them resolved
    assert stats["rel_mean_pct"] < float(100 * naive_rel.mean()) / 3


def test_int4_error_stats_between_int8_and_naive():
    rng = np.random.default_rng(5)
    r = jnp.asarray((rng.normal(size=(128, 2048)) * 0.02).astype(np.float32))
    e8 = quantization_error_stats(r, 256, "int8")["mean"]
    e4 = quantization_error_stats(r, 256, "int4")["mean"]
    assert e8 < e4 < 30 * e8  # 4-bit costs ~17x mean error, not orders more


# ---------------------------------------------------------------------------
# int3 packing (8 logical values per 3 storage bytes)
# ---------------------------------------------------------------------------

def test_int3_registry_entry():
    assert {"int3", "fp8"} <= set(available_formats())
    f3 = get_format("int3")
    assert (f3.bits, f3.pack, f3.pack_storage, f3.qmax) == (3, 8, 3, 3)
    assert f3.storage_dtype == jnp.uint8 and f3.kind == "int"
    # the bit law the quant-invariants checker enforces
    assert f3.bits * f3.pack == 8 * jnp.dtype(f3.storage_dtype).itemsize * f3.pack_storage


def test_pack_unpack_int3_roundtrip_exact():
    rng = np.random.default_rng(21)
    q = jnp.asarray(rng.integers(-3, 4, size=(16, 64)).astype(np.int8))
    p = pack_int3(q)
    assert p.shape == (16, 24) and p.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_int3(p)), np.asarray(q))


def test_pack_int3_bad_axis_raises():
    with pytest.raises(ValueError, match="divisible by 8"):
        pack_int3(jnp.zeros((4, 28), jnp.int8))
    with pytest.raises(ValueError, match="divide by 3"):
        unpack_int3(jnp.zeros((4, 28), jnp.uint8))


def test_int3_quantize_shapes_and_range():
    rng = np.random.default_rng(22)
    r = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    qt = quantize_int3(r, 64)
    assert qt.fmt == "int3"
    assert qt.storage_shape == (8, 96)          # 8 values per 3 bytes
    assert qt.shape == qt.logical_shape == (8, 256)
    assert qt.scales.shape == (8, 4)
    vals = np.asarray(unpack_int3(qt.qvalues))
    assert vals.max() <= 3 and vals.min() >= -3
    assert vals.max() == 3 or vals.min() == -3  # full range used per Eq. 1


def test_int3_roundtrip_error_bound():
    """|r_hat - r| <= S/2 per element, S = 2*max|r|/7 per group."""
    rng = np.random.default_rng(23)
    r = jnp.asarray(rng.normal(size=(64, 512)).astype(np.float32))
    qt = quantize_int3(r, 128)
    err = np.abs(np.asarray(dequantize(qt)) - np.asarray(r))
    half = np.repeat(np.asarray(qt.scales), 128, axis=-1) / 2
    assert np.all(err <= half + 1e-6)


def test_int3_group_size_must_divide_pack():
    with pytest.raises(ValueError, match="divisible by 8"):
        quantize_int3(jnp.ones((4, 48)), 12)


# ---------------------------------------------------------------------------
# fp8 (e4m3 storage, per-group scale)
# ---------------------------------------------------------------------------

def test_fp8_registry_entry():
    f8 = get_format("fp8")
    assert (f8.bits, f8.pack, f8.pack_storage) == (8, 1, 1)
    assert f8.kind == "float"
    assert f8.storage_dtype == jnp.float8_e4m3fn


def test_fp8_quantize_shapes_and_storage():
    rng = np.random.default_rng(24)
    r = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    qt = quantize_fp8(r, 64)
    assert qt.fmt == "fp8"
    assert qt.qvalues.dtype == jnp.float8_e4m3fn
    assert qt.storage_shape == qt.logical_shape == (8, 256)
    assert qt.scales.shape == (8, 4)
    # group absmax maps onto the e4m3 grid endpoint
    vals = np.abs(np.asarray(qt.qvalues.astype(jnp.float32)))
    assert vals.max() == pytest.approx(FP8_MAX)


def test_fp8_relative_error_follows_magnitude():
    """e4m3 is a float grid: relative error is roughly flat across magnitudes
    (vs int8 whose absolute step is constant within a group)."""
    rng = np.random.default_rng(25)
    r = jnp.asarray(rng.normal(size=(32, 512)).astype(np.float32))
    qt = quantize_fp8(r, 128)
    back = np.asarray(dequantize(qt))
    w = np.asarray(r)
    rel = np.abs(back - w) / np.maximum(np.abs(w), 1e-9)
    # 3 mantissa bits -> worst-case relative step 2^-4 = 6.25% of the value
    assert np.median(rel) < 0.0625


def test_fp8_zero_group_safe():
    qt = quantize_fp8(jnp.zeros((2, 64)), 32)
    assert bool(jnp.all(jnp.isfinite(dequantize(qt))))
    np.testing.assert_array_equal(np.asarray(dequantize(qt)), 0.0)


# ---------------------------------------------------------------------------
# QuantizedTensor aux / accounting
# ---------------------------------------------------------------------------

def test_pytree_roundtrip_preserves_fmt():
    qt = quantize_int4(jnp.ones((8, 128)), 32)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qt2.fmt == "int4" and qt2.group_size == 32
    np.testing.assert_array_equal(np.asarray(qt2.qvalues), np.asarray(qt.qvalues))


def test_bits_per_weight():
    r = jnp.ones((64, 256))
    assert quantize(r, 256, "int8").bits_per_weight() == pytest.approx(8.125)
    assert quantize(r, 256, "int4").bits_per_weight() == pytest.approx(4.125)
    assert quantize(r, 256, "int3").bits_per_weight() == pytest.approx(3.125)
    assert quantize(r, 256, "fp8").bits_per_weight() == pytest.approx(8.125)
    # nbytes is true storage: packed int4 halves the qvalues bytes,
    # int3 stores 3 bytes per 8 weights
    assert quantize(r, 256, "int4").nbytes() == 64 * 128 + 4 * 64
    assert quantize(r, 256, "int3").nbytes() == 64 * 96 + 4 * 64


def test_quantize_under_eval_shape():
    """The dry-run quantizes ShapeDtypeStructs via eval_shape — packed
    formats must trace (pack is pure jnp bit-ops)."""
    out = jax.eval_shape(lambda x: quantize_int4(x, 64), jnp.zeros((32, 256)))
    assert isinstance(out, QuantizedTensor)
    assert out.qvalues.shape == (32, 128) and out.qvalues.dtype == jnp.int8
    assert out.scales.shape == (32, 4)
    out3 = jax.eval_shape(lambda x: quantize_int3(x, 64), jnp.zeros((32, 256)))
    assert out3.qvalues.shape == (32, 96) and out3.qvalues.dtype == jnp.uint8
    out8 = jax.eval_shape(lambda x: quantize_fp8(x, 64), jnp.zeros((32, 256)))
    assert out8.qvalues.dtype == jnp.float8_e4m3fn
    assert out8.qvalues.shape == (32, 256)


# ---------------------------------------------------------------------------
# unified group-size search (satellite: choose_group_size / leaf_group_size)
# ---------------------------------------------------------------------------

def test_largest_pow2_group():
    assert largest_pow2_group(2048, 256, 16) == 256
    assert largest_pow2_group(1408, 256, 16) == 128
    assert largest_pow2_group(1200, 256, 16) == 16
    assert largest_pow2_group(33, 256, 16) is None
    assert largest_pow2_group(48, 256, 32) is None  # floor respected


def test_choose_group_size_uses_shared_search():
    assert choose_group_size([2048, 5632]) == 256
    assert choose_group_size([2048, 1408]) == 128
    with pytest.raises(ValueError):
        choose_group_size([33])
    # same search, policy floor: leaf_group_size delegates to the helper
    from repro.core.policy import leaf_group_size
    assert leaf_group_size("layers/attn/wqkv", jnp.zeros((8, 1200)), 256) == 16
    assert leaf_group_size("layers/attn/wqkv", jnp.zeros((8, 1200 * 2)), 256, tp=1) == 32


# ---------------------------------------------------------------------------
# checkpoint + sharding glue
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_format_mismatch(tmp_path):
    from repro.checkpoint import ckpt

    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(32, 128)).astype(np.float32))
    tree4 = {"attn": {"wo": quantize(w, 32, "int4")}, "norm": jnp.ones((8,))}
    ckpt.save(str(tmp_path), 1, tree4)
    back, step, _ = ckpt.restore(str(tmp_path), tree4)
    assert step == 1 and back["attn"]["wo"].fmt == "int4"
    np.testing.assert_array_equal(
        np.asarray(back["attn"]["wo"].qvalues),
        np.asarray(tree4["attn"]["wo"].qvalues),
    )
    # restoring into an int8-shaped tree must refuse, not reinterpret
    tree8 = {"attn": {"wo": quantize(w, 32, "int8")}, "norm": jnp.ones((8,))}
    with pytest.raises(ValueError, match="quantization mismatch"):
        ckpt.restore(str(tmp_path), tree8)


def test_validate_quant_partition():
    from jax.sharding import Mesh
    from repro.core.policy import quantize_params
    from repro.dist.sharding import validate_quant_partition

    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    params = {"attn": {"wo": jnp.zeros((16, 256), jnp.float32)}}
    qp = quantize_params(params, 64, formats="int4")
    validate_quant_partition(qp, mesh, mode="serve")  # must not raise

    # a hand-built geometry that WOULD split groups: 4-way model axis over a
    # row-parallel packed contraction whose shard holds half a group
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 4}

    bad = {"attn": {"wo": QuantizedTensor(
        qvalues=jnp.zeros((16, 128), jnp.int8),   # packed: 256 logical
        scales=jnp.zeros((16, 2), jnp.float32),   # GS=128 -> 64 bytes/group
        group_size=128, fmt="int4")}}
    with pytest.raises(ValueError, match="splits quantization groups"):
        validate_quant_partition(bad, FakeMesh(), mode="serve")
