"""repro-lint: engine, allowlist, the seven source/runtime checkers, CLI,
and the recompile-guard runtime fixture (scheduler decode loops compile
once).  The four compiled-program xray checkers live in tests/test_xray.py.

Checker tests assert EXACT finding counts and file:line anchors. Fixture
files under tests/analysis_fixtures/ tag every expected finding line with a
``# LINT: <checker-id>`` comment, so the expectations live next to the code
that triggers them and can't drift silently.
"""

import ast
import json
import os
import types

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    AdapterLifecycleChecker,
    HostSyncChecker,
    JitTraceCounter,
    PallasContractChecker,
    QuantInvariantsChecker,
    RecompileChecker,
    RegistryCoverageChecker,
    ShadowCoverageChecker,
    default_checkers,
)
from repro.analysis.__main__ import main as cli_main
from repro.analysis.engine import Allowlist, Finding, run_analysis
from repro.core.quant import QuantFormat, get_format

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIX = "tests/analysis_fixtures"


def fixture_path(name):
    return os.path.join(FIX, name)


def tagged_lines(name, checker_id):
    """Lines in a fixture carrying ``# LINT: <checker-id>``."""
    with open(os.path.join(ROOT, FIX, name), encoding="utf-8") as fh:
        return sorted(i for i, line in enumerate(fh, 1)
                      if f"# LINT: {checker_id}" in line)


def run_one(checker, name):
    findings, _ = run_analysis([checker], [fixture_path(name)], ROOT)
    return findings


def assert_anchored(findings, name, checker_id):
    assert [f.checker for f in findings] == [checker_id] * len(findings)
    assert sorted(f.line for f in findings) == tagged_lines(name, checker_id)
    for f in findings:
        assert f.path == f"{FIX}/{name}"
        assert f.anchor == f"{f.path}:{f.line}"


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_flags_syncs_in_jitted_scopes():
    findings = run_one(HostSyncChecker(), "bad_host_sync.py")
    assert len(findings) == 3
    assert_anchored(findings, "bad_host_sync.py", "host-sync")


def test_host_sync_chunk_loop_budget_and_nested_for():
    checker = HostSyncChecker(loop_files=("*bad_chunk_loop.py",))
    findings = run_one(checker, "bad_chunk_loop.py")
    assert len(findings) == 5
    assert_anchored(findings, "bad_chunk_loop.py", "host-sync")
    msgs = " ".join(f.message for f in findings)
    assert "for-loop" in msgs and "budget" in msgs
    # implicit casts on device values are flagged like .item()
    assert "float(logits_d)" in msgs and "int(total)" in msgs


@pytest.mark.parametrize("name", ["good_host_sync.py", "good_chunk_loop.py"])
def test_host_sync_clean_fixtures(name):
    checker = HostSyncChecker(loop_files=(f"*{name}",))
    assert run_one(checker, name) == []


# ---------------------------------------------------------------------------
# recompile-guard (static half)
# ---------------------------------------------------------------------------

def test_recompile_flags_jit_in_loop_and_unhashable_statics():
    findings = run_one(RecompileChecker(), "bad_recompile.py")
    assert len(findings) == 4
    assert_anchored(findings, "bad_recompile.py", "recompile-guard")
    assert sum("loop" in f.message for f in findings) == 2
    assert sum("unhashable" in f.message for f in findings) == 2


def test_recompile_clean_fixture():
    assert run_one(RecompileChecker(), "good_recompile.py") == []


# ---------------------------------------------------------------------------
# pallas-contract
# ---------------------------------------------------------------------------

def test_pallas_contract_flags_all_defect_classes():
    findings = run_one(PallasContractChecker(), "bad_pallas.py")
    assert len(findings) == 5
    assert_anchored(findings, "bad_pallas.py", "pallas-contract")
    msgs = [f.message for f in findings]
    assert sum("index_map takes" in m for m in msgs) == 1
    assert sum("no divisibility guard" in m for m in msgs) == 1
    assert sum("out_shape has" in m for m in msgs) == 1
    assert sum("VMEM" in m for m in msgs) == 1
    assert sum("num_scalar_prefetch" in m for m in msgs) == 1
    assert [f.severity for f in findings if "VMEM" in f.message] == ["warning"]


def test_pallas_contract_clean_fixture():
    assert run_one(PallasContractChecker(), "good_pallas.py") == []


def test_pallas_contract_clean_on_real_kernels():
    findings, _ = run_analysis([PallasContractChecker()],
                               ["src/repro/kernels"], ROOT)
    assert findings == []


# ---------------------------------------------------------------------------
# quant-invariants
# ---------------------------------------------------------------------------

def test_quant_invariants_flags_inconsistent_format():
    weird = QuantFormat(name="weird", bits=4, storage_dtype=jnp.int8,
                        pack=2, qmax=8, kernel="nope")
    checker = QuantInvariantsChecker(
        formats={"weird": weird}, configs=[], kernel_hooks={"gqmv_int8"})
    msgs = [f.message for f in checker.check_project(ROOT)]
    assert len(msgs) == 3
    assert sum("qmax" in m for m in msgs) == 1
    assert sum("pack_fn" in m for m in msgs) == 1
    assert sum("kernel hook" in m for m in msgs) == 1


def test_quant_invariants_flags_non_pow2_pack():
    odd = QuantFormat(name="odd", bits=8, storage_dtype=jnp.int8,
                      pack=3, qmax=127, kernel="gqmv_int8")
    checker = QuantInvariantsChecker(
        formats={"odd": odd}, configs=[], kernel_hooks={"gqmv_int8"})
    msgs = [f.message for f in checker.check_project(ROOT)]
    assert len(msgs) == 1 and "power of" in msgs[0]


def test_quant_invariants_flags_pack_group_straddle():
    """d_model=16 at tp=1 DOES get quantized (gs=16 is a valid pow2 group),
    but a pack-32 format's storage element spans two shards — the straddle
    branch must fire. Geometries with NO valid group (the old fake-6d) are
    left unquantized by the policy and are rightly skipped now."""
    wide = QuantFormat(name="int1x32", bits=1, storage_dtype=jnp.int8,
                       pack=32, pack_storage=4, qmax=0, kernel="gqmv_int4",
                       pack_fn=lambda q: q, unpack_fn=lambda p: p)
    cfg = types.SimpleNamespace(
        arch_id="fake-16d", group_size=256, d_model=16, q_dim=256,
        kv_dim=256, d_ff=256, vocab_padded=256, moe=None, mla=None, ssm=None)
    checker = QuantInvariantsChecker(
        formats={"int1x32": wide}, configs=[cfg],
        kernel_hooks={"gqmv_int4"})
    findings = list(checker.check_project(ROOT))
    assert len(findings) == 1
    assert "d_model=16" in findings[0].message
    assert "straddle" in findings[0].message


def test_quant_invariants_skips_unquantizable_geometry():
    """No pow2 group >= 16 divides any shard of d_model=6: the PTQ driver
    leaves such leaves unquantized, so there is no packed storage to
    straddle and the checker must stay silent."""
    cfg = types.SimpleNamespace(
        arch_id="fake-6d", group_size=256, d_model=6, q_dim=256, kv_dim=256,
        d_ff=256, vocab_padded=256, moe=None, mla=None, ssm=None)
    checker = QuantInvariantsChecker(
        formats={"int4": get_format("int4")}, configs=[cfg],
        kernel_hooks={"gqmv_int4"})
    assert list(checker.check_project(ROOT)) == []


def test_quant_invariants_clean_on_real_registry():
    assert list(QuantInvariantsChecker().check_project(ROOT)) == []


# ---------------------------------------------------------------------------
# registry-coverage
# ---------------------------------------------------------------------------

def test_registry_coverage_requires_explicit_flags():
    name = "bad_registry.py"
    with open(os.path.join(ROOT, FIX, name), encoding="utf-8") as fh:
        src = fh.read()
    checker = RegistryCoverageChecker(registry_glob=f"*{name}")
    findings = list(checker.check_file(f"{FIX}/{name}", ast.parse(src), src))
    assert len(findings) == 2
    assert_anchored(findings, name, "registry-coverage")
    # the partially-explicit Model() names only the flag it omitted
    assert any("['supports_spec']" in f.message for f in findings)


def _fake_model(**kw):
    base = dict(supports_lengths=False, supports_paged=False,
                supports_spec=False, init_paged_cache=None, decode_paged=None,
                verify=None, commit_verify=None, cache_kind="none",
                insert_slots=None, gather_slots=None)
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_registry_coverage_matrix_cross_check():
    fakes = {
        "arch-a": _fake_model(
            supports_lengths=True, supports_paged=True,
            init_paged_cache=lambda *a: None, decode_paged=lambda *a: None),
        "arch-b": _fake_model(decode_paged=lambda *a: None),
    }
    checker = RegistryCoverageChecker(
        archs=list(fakes), build=fakes.__getitem__,
        matrix_path=f"{FIX}/bad_matrix.py")
    msgs = [f.message for f in checker.check_project(ROOT)]
    assert len(msgs) == 4
    assert sum("dead capability" in m for m in msgs) == 1       # arch-b
    assert sum("untested" in m for m in msgs) == 1              # arch-a paged
    assert sum("unknown arch" in m for m in msgs) == 1
    assert sum("SPEC_ARCHS missing" in m for m in msgs) == 1


def test_registry_coverage_clean_on_real_registry():
    assert list(RegistryCoverageChecker().check_project(ROOT)) == []


# ---------------------------------------------------------------------------
# adapter-lifecycle
# ---------------------------------------------------------------------------

def test_adapter_lifecycle_flags_leaks_and_early_returns():
    findings = run_one(AdapterLifecycleChecker(), "bad_adapter_lifecycle.py")
    assert len(findings) == 4
    assert_anchored(findings, "bad_adapter_lifecycle.py", "adapter-lifecycle")
    msgs = " ".join(f.message for f in findings)
    assert "no on_finish that frees" in msgs
    assert "san_state" in msgs
    assert "never calls end_serve" in msgs
    assert "return inside" in msgs


def test_adapter_lifecycle_clean_fixture():
    assert run_one(AdapterLifecycleChecker(),
                   "good_adapter_lifecycle.py") == []


def test_adapter_lifecycle_clean_on_real_serving():
    findings, _ = run_analysis([AdapterLifecycleChecker()],
                               ["src/repro/serving", "tests"], ROOT)
    assert findings == []


# ---------------------------------------------------------------------------
# shadow-coverage
# ---------------------------------------------------------------------------

def test_shadow_coverage_missing_and_overstating_entries(tmp_path):
    (tmp_path / "matrix.py").write_text(
        "SANITIZED_ARCHS = [\n"
        "    'arch-kv',\n"
        "    'arch-none',\n"
        "    'arch-ghost',\n"
        "]\n")
    (tmp_path / "test_san.py").write_text(
        "from arch_matrix import SANITIZED_ARCHS\n")
    fakes = {
        "arch-kv": _fake_model(cache_kind="kv"),
        "arch-state": _fake_model(cache_kind="state"),
        "arch-none": _fake_model(cache_kind="none"),
    }
    checker = ShadowCoverageChecker(
        archs=list(fakes), build=fakes.__getitem__,
        matrix_path="matrix.py", test_path="test_san.py")
    msgs = [f.message for f in checker.check_project(str(tmp_path))]
    assert len(msgs) == 3
    assert sum("arch-state" in m and "no SANITIZED_ARCHS entry" in m
               for m in msgs) == 1
    assert sum("unknown arch 'arch-ghost'" in m for m in msgs) == 1
    assert sum("arch-none" in m and "overstates" in m for m in msgs) == 1


def test_shadow_coverage_missing_list(tmp_path):
    (tmp_path / "matrix.py").write_text("OTHER = []\n")
    fakes = {"arch-kv": _fake_model(cache_kind="kv")}
    checker = ShadowCoverageChecker(
        archs=list(fakes), build=fakes.__getitem__,
        matrix_path="matrix.py", test_path="test_san.py")
    msgs = [f.message for f in checker.check_project(str(tmp_path))]
    assert len(msgs) == 1 and "SANITIZED_ARCHS missing" in msgs[0]


def test_shadow_coverage_requires_consuming_test(tmp_path):
    (tmp_path / "matrix.py").write_text("SANITIZED_ARCHS = ['arch-kv']\n")
    fakes = {"arch-kv": _fake_model(cache_kind="kv")}
    checker = ShadowCoverageChecker(
        archs=list(fakes), build=fakes.__getitem__,
        matrix_path="matrix.py", test_path="test_san.py")
    msgs = [f.message for f in checker.check_project(str(tmp_path))]
    assert len(msgs) == 1 and "test module missing" in msgs[0]
    # a test module that never reads the ledger is as bad as no module
    (tmp_path / "test_san.py").write_text("def test_nothing(): pass\n")
    msgs = [f.message for f in checker.check_project(str(tmp_path))]
    assert len(msgs) == 1 and "never references" in msgs[0]


def test_shadow_coverage_clean_on_real_registry():
    assert list(ShadowCoverageChecker().check_project(ROOT)) == []


# ---------------------------------------------------------------------------
# engine: findings, allowlist, parse errors
# ---------------------------------------------------------------------------

def test_finding_render_and_severity():
    f = Finding("host-sync", "src/x.py", 12, "boom", col=4)
    assert f.anchor == "src/x.py:12"
    assert f.render() == "src/x.py:12:4: error[host-sync] boom"
    with pytest.raises(ValueError):
        Finding("x", "y.py", 1, "m", severity="fatal")


def test_allowlist_roundtrip(tmp_path):
    p = tmp_path / "allow"
    p.write_text(
        "# comment\n"
        "\n"
        "host-sync src/x.py:12 deliberate admission transfer\n"
        "* other/*.py blanket grandfathering of a legacy dir\n")
    al = Allowlist.load(str(p))
    assert len(al.rules) == 2
    hit = Finding("host-sync", "src/x.py", 12, "m")
    miss = Finding("host-sync", "src/x.py", 13, "m")
    other = Finding("pallas-contract", "other/k.py", 7, "m")
    kept, suppressed = al.filter([hit, miss, other])
    assert kept == [miss]
    assert suppressed == [hit, other]
    assert al.unused() == []


def test_allowlist_requires_justification(tmp_path):
    p = tmp_path / "allow"
    p.write_text("host-sync src/x.py\n")
    with pytest.raises(ValueError, match="justification"):
        Allowlist.load(str(p))


def test_allowlist_unused_rules_reported(tmp_path):
    p = tmp_path / "allow"
    p.write_text("host-sync nowhere/*.py never matches anything\n")
    al = Allowlist.load(str(p))
    al.filter([])
    assert [r.pattern for r in al.unused()] == ["nowhere/*.py"]


def test_parse_failure_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    findings, _ = run_analysis([HostSyncChecker()], [str(bad)], str(tmp_path))
    assert [f.checker for f in findings] == ["parse"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_lists_all_checkers(capsys):
    assert cli_main(["--list"]) == 0
    out = capsys.readouterr().out
    for c in default_checkers():
        assert c.id in out


def test_cli_exits_nonzero_on_bad_fixture(capsys):
    rc = cli_main([fixture_path("bad_recompile.py"), "--root", ROOT,
                   "--select", "recompile-guard"])
    assert rc == 1
    assert "recompile-guard" in capsys.readouterr().out


def test_cli_exits_zero_on_good_fixture():
    assert cli_main([fixture_path("good_recompile.py"), "--root", ROOT,
                     "--select", "recompile-guard"]) == 0


def test_cli_rejects_unknown_checker_id():
    assert cli_main(["--select", "no-such-checker"]) == 2


def test_cli_json_emits_severity_and_col(capsys):
    rc = cli_main([fixture_path("bad_recompile.py"), "--root", ROOT,
                   "--select", "recompile-guard", "--json"])
    assert rc == 1
    out = capsys.readouterr().out
    recs = [json.loads(line) for line in out.splitlines() if line.strip()]
    assert recs
    for r in recs:
        assert set(r) == {"checker", "path", "line", "col", "severity",
                          "message", "anchor"}
        assert r["severity"] in ("error", "warning")
        assert isinstance(r["col"], int)
        assert r["anchor"] == f"{r['path']}:{r['line']}"


def test_cli_clean_on_repo_tree():
    """The acceptance gate: the full default-checker pass (seven source/
    runtime + four xray compiled-program contracts) over the repo tree
    (same invocation as CI) reports nothing."""
    assert cli_main(["--root", ROOT]) == 0


# ---------------------------------------------------------------------------
# recompile-guard, runtime half: decode loops compile once per shape bucket
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def eng():
    from repro.models.registry import build, load_config
    from repro.serving.engine import InferenceEngine

    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return InferenceEngine(model, params, cache_len=40)


@pytest.fixture
def jit_trace_counter():
    with JitTraceCounter() as jc:
        yield jc


# mixed-length trace: prompt lens 2/3/10/12 -> two pad buckets (8 and 16)
MIXED_PROMPTS = [[5, 3], [7, 1, 4], list(range(1, 11)), list(range(2, 14))]
MIXED_BUDGETS = [3, 4, 2, 3]


def _mixed_requests():
    from repro.serving.batching import Request

    return [Request(i, p, max_new=b)
            for i, (p, b) in enumerate(zip(MIXED_PROMPTS, MIXED_BUDGETS))]


def test_slot_scheduler_decode_compiles_once(eng, jit_trace_counter):
    from repro.serving.batching import SlotScheduler

    sched = SlotScheduler(eng, slots=2, chunk=2)
    out = sched.serve(_mixed_requests(), 4)
    assert len(out) == 4 and all(r.length > 0 for r in out)
    jit_trace_counter.assert_traces("decode_chunk", 1)
    # prefill retraces only per padded bucket length (8 and 16)
    jit_trace_counter.assert_traces("prefill_group", 2)


def test_paged_scheduler_decode_compiles_once(eng, jit_trace_counter):
    from repro.serving.paged import PagedScheduler

    sched = PagedScheduler(eng, slots=2, chunk=2, block_size=8)
    out = sched.serve(_mixed_requests(), 4)
    assert len(out) == 4 and all(r.length > 0 for r in out)
    jit_trace_counter.assert_traces("decode_until", 1)
    jit_trace_counter.assert_traces("prefill_group", 2)
