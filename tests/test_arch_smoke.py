"""Per-architecture smoke tests: reduced config, one forward pass + one
prefill/decode round, asserting shapes and finiteness (assignment req (f)).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.models.registry import ARCH_IDS, build, input_specs, load_config, smoke_batch

ALL_ARCHS = ARCH_IDS


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, batch=2, seq=16)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = smoke_batch(cfg, batch=2, seq=8)
    cache_len = 12

    logits, cache = model.prefill(params, batch, cache_len)
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = model.decode(params, tok, cache, jnp.int32(8))
    assert logits2.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache must keep its structure/shape
    jax.tree.map(lambda a, b: np.testing.assert_equal(a.shape, b.shape), cache, cache2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_consistency_with_forward(arch):
    """Greedy decode logits at position s must match the forward pass logits
    at the same position (teacher forcing) -- the core cache invariant."""
    cfg = load_config(arch).reduced()
    if cfg.model_type == "encdec":
        pytest.skip("decoder consistency covered by enc-dec specific test")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = smoke_batch(cfg, batch=1, seq=8)

    full = model.forward(params, batch, remat=False)          # (1, 8, V)
    pre_batch = {k: (v[:, :7] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    logits_p, cache = model.prefill(params, pre_batch, 8)
    # decode the 8th token (index 7)
    tok = batch["tokens"][:, 7]
    logits_d, _ = model.decode(params, tok, cache, jnp.int32(7))
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full[:, 7, :]), rtol=2e-2, atol=2e-2
    )
    # prefill's last logits == forward logits at index 6
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, 6, :]), rtol=2e-2, atol=2e-2
    )


def test_encdec_decode_consistency():
    cfg = load_config("seamless-m4t-large-v2").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = smoke_batch(cfg, batch=1, seq=8)
    full = model.forward(params, batch, remat=False)
    pre = {"frames": batch["frames"], "tokens": batch["tokens"][:, :7]}
    logits_p, cache = model.prefill(params, pre, 8)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, 6, :]),
                               rtol=2e-2, atol=2e-2)
    logits_d, _ = model.decode(params, batch["tokens"][:, 7], cache, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, 7, :]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_input_specs_defined(arch):
    cfg = load_config(arch)
    for shape in SHAPES.values():
        specs = input_specs(cfg, shape)
        assert isinstance(specs, dict) and specs
        for v in jax.tree.leaves(specs):
            assert isinstance(v, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_dims(arch):
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    cfg = load_config(arch)
    expected = {
        "tinyllama-1.1b": (22, 2048, 5632, 32000),
        "pixtral-12b": (40, 5120, 14336, 131072),
        "rwkv6-7b": (32, 4096, 14336, 65536),
        "minicpm3-4b": (62, 2560, 6400, 73448),
        "deepseek-coder-33b": (62, 7168, 19200, 32256),
        "gemma2-2b": (26, 2304, 9216, 256000),
        "internlm2-1.8b": (24, 2048, 8192, 92544),
        "dbrx-132b": (40, 6144, 10752, 100352),
        "deepseek-v2-lite-16b": (27, 2048, 1408, 102400),
        "zamba2-7b": (81, 3584, 14336, 32000),
        "seamless-m4t-large-v2": (24, 1024, 8192, 256206),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expected
