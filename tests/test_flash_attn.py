"""Flash-attention Pallas kernel vs naive softmax oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_pallas


def naive(q, k, v, *, group, scale, causal=True, window=None, softcap=None):
    """(bh,s,hd) x (bkv,t,hd) oracle with GQA broadcast."""
    bh, s, hd = q.shape
    bkv, t, _ = k.shape
    kf = jnp.repeat(k, group, axis=0)
    vf = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("hsd,htd->hst", q, kf).astype(jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= (qp - kp) < window
    scores = jnp.where(ok[None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hst,htd->hsd", attn, vf).astype(q.dtype)


def _mk(bh, bkv, s, t, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(bkv, t, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(bkv, t, hd)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("s,t,hd,bq,bk", [
    (128, 128, 64, 32, 32),
    (256, 256, 32, 64, 128),
    (64, 64, 128, 64, 64),
])
def test_flash_causal_matches_naive(s, t, hd, bq, bk):
    q, k, v = _mk(4, 4, s, t, hd, seed=s + hd)
    got = flash_attention_pallas(q, k, v, group=1, scale=hd**-0.5,
                                 block_q=bq, block_k=bk, interpret=True)
    want = naive(q, k, v, group=1, scale=hd**-0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_gqa_broadcast():
    # 8 q heads over 2 kv heads (group=4), 2 batches -> bh=16, bkv=4
    q, k, v = _mk(16, 4, 64, 64, 32, seed=7)
    got = flash_attention_pallas(q, k, v, group=4, scale=32**-0.5,
                                 block_q=32, block_k=32, interpret=True)
    want = naive(q, k, v, group=4, scale=32**-0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_sliding_window_and_softcap():
    q, k, v = _mk(2, 2, 128, 128, 32, seed=9)
    got = flash_attention_pallas(q, k, v, group=1, scale=32**-0.5, window=32,
                                 softcap=50.0, block_q=32, block_k=32, interpret=True)
    want = naive(q, k, v, group=1, scale=32**-0.5, window=32, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_non_causal():
    q, k, v = _mk(2, 2, 64, 64, 32, seed=11)
    got = flash_attention_pallas(q, k, v, group=1, scale=32**-0.5, causal=False,
                                 block_q=32, block_k=32, interpret=True)
    want = naive(q, k, v, group=1, scale=32**-0.5, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_block_shape_invariance():
    q, k, v = _mk(2, 2, 128, 128, 32, seed=13)
    a = flash_attention_pallas(q, k, v, group=1, scale=0.2, block_q=32,
                               block_k=64, interpret=True)
    b = flash_attention_pallas(q, k, v, group=1, scale=0.2, block_q=128,
                               block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
