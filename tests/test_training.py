"""Training substrate tests: optimizer, loss descent, checkpoint/restart,
gradient compression, data pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.elastic import plan_mesh
from repro.models.registry import build, load_config
from repro.optim import adamw
from repro.optim.compress import compress_leaf, decompress_leaf
from repro.train.loop import LoopConfig, lm_loss, make_train_step, run_loop


def _setup(arch="tinyllama-1.1b"):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    return cfg, model, params, data


def test_lm_loss_basics():
    logits = jnp.zeros((2, 3, 8))
    labels = jnp.array([[1, 2, 3], [4, -1, -1]])
    loss = lm_loss(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


def test_loss_decreases():
    cfg, model, params, data = _setup()
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt_state = adamw.init(params)
    losses = []
    for i in range(12):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i % 2))
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-3


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), 20.0, rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree, extra={"foo": 1})
    out, step, extra = ckpt.restore(d, jax.tree.map(np.asarray, tree))
    assert step == 7 and extra == {"foo": 1}
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_checkpoint_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, {"x": jnp.ones(2) * s})
    ckpt.retain(d, keep=2)
    assert ckpt.latest_step(d) == 4
    assert sorted(os.listdir(d)) == ["step_00000003", "step_00000004"]


def test_run_loop_resume(tmp_path):
    cfg, model, params, data = _setup()
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    lc = LoopConfig(total_steps=4, ckpt_every=2, ckpt_dir=str(tmp_path / "run"),
                    log_every=100)
    p1, _, hist1 = run_loop(model, params, data, opt_cfg, lc, log=lambda s: None)
    # simulate preemption + restart: same call resumes from step 4 checkpoint
    lc2 = LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=str(tmp_path / "run"),
                     log_every=100)
    p2, _, hist2 = run_loop(model, params, data, opt_cfg, lc2, log=lambda s: None)
    assert hist2[0]["step"] == 5  # continued, not restarted
    assert len(hist2) == 2


def test_compress_roundtrip_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    q, s = compress_leaf(g, 64)
    rec = decompress_leaf(q, s, 64)
    err = np.abs(np.asarray(rec - g))
    half = np.repeat(np.asarray(s), 64, axis=-1) / 2
    assert np.all(err <= half + 1e-6)


def test_compressed_psum_unbiased():
    """shard_map over a 1-device axis: compressed psum == plain mean."""
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # moved out of experimental in jax 0.5
        from jax.experimental.shard_map import shard_map
    from repro.optim.compress import compressed_psum

    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(4, 128)).astype(np.float32))}

    def f(grads):
        out, res = compressed_psum(grads, "pod")
        return out, res

    out, res = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()))(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=0.02)
    # residual = quantization error, bounded by half-step
    assert float(jnp.max(jnp.abs(res["w"]))) < 0.02


def test_data_determinism_and_sharding():
    c1 = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    a = SyntheticLM(c1).batch_at(5)
    b = SyntheticLM(c1).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    # host sharding splits the global batch
    h0 = SyntheticLM(DataConfig(100, 8, 4, seed=3, num_hosts=2, host_index=0)).batch_at(5)
    assert h0["tokens"].shape == (2, 8)


def test_plan_mesh_elasticity():
    assert plan_mesh(512).shape == (2, 16, 16)
    assert plan_mesh(256).shape == (16, 16)
    assert plan_mesh(8).shape == (1, 8)
    assert plan_mesh(1).shape == (1, 1)
    # losing a pod: 256 devices -> single-pod plan, same axis names trailing
    assert plan_mesh(256).axes == ("data", "model")
