"""linear / embedding_lookup / split_fused across quantization formats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qlinear import embedding_lookup, linear, split_fused
from repro.core.quant import dequantize, quantize

jax.config.update("jax_enable_x64", False)


def _table(vocab=64, d=128, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(vocab, d)).astype(np.float32))


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_embedding_lookup_parity_full_dequant(fmt):
    """Gather-then-dequant must equal dequant-then-gather exactly — same
    int values, same scales, same multiply."""
    w = quantize(_table(), 32, fmt)
    ids = jnp.asarray([[0, 5, 63], [7, 7, 1]], jnp.int32)
    got = embedding_lookup(w, ids)
    want = jnp.take(dequantize(w), ids, axis=0)
    assert got.shape == (2, 3, 128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_embedding_lookup_float_passthrough():
    w = _table()
    ids = jnp.asarray([1, 2], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(embedding_lookup(w, ids)), np.asarray(jnp.take(w, ids, axis=0))
    )


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_embedding_lookup_dtype(fmt):
    w = quantize(_table(), 32, fmt)
    out = embedding_lookup(w, jnp.asarray([3], jnp.int32), dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_linear_matches_dequant_matmul(fmt):
    rng = np.random.default_rng(1)
    wf = jnp.asarray((rng.normal(size=(48, 256)) * 0.05).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    w = quantize(wf, 64, fmt)
    got = linear(w, x, impl="xla")
    want = x @ dequantize(w).T
    # differs only by activation quantization (same for both paths' weights)
    rel = np.linalg.norm(np.asarray(got) - np.asarray(want)) / np.linalg.norm(want)
    assert rel < 0.02, rel


def test_split_fused_ok():
    y = jnp.arange(12.0).reshape(2, 6)
    a, b = split_fused(y, (2, 4))
    assert a.shape == (2, 2) and b.shape == (2, 4)


def test_split_fused_bad_sizes_raises_value_error():
    """Must raise even under python -O (was a bare assert)."""
    with pytest.raises(ValueError, match="sum to 4"):
        split_fused(jnp.zeros((2, 6)), (2, 2))
    with pytest.raises(ValueError, match="sum to 8"):
        split_fused(jnp.zeros((2, 6)), (4, 4))
