"""Ragged serving: length-aware bucketing + continuous-batching scheduler.

The regression at the heart of this file: a right-padded request must decode
token-for-token identically to its unpadded self (greedy). The seed code
sampled the first token from pad-position logits, attended over pad keys,
and mis-assigned RoPE positions — every length != bucket size was wrong.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import build, load_config, smoke_batch
from repro.serving.batching import (
    Request,
    SlotScheduler,
    resolve_mode,
    serve_bucketed,
    serve_continuous,
    serve_ragged,
)
from repro.serving.engine import InferenceEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(tiny):
    _, model, params = tiny
    return InferenceEngine(model, params, cache_len=40)


def _direct(engine, prompt, n):
    res = engine.generate({"tokens": jnp.asarray([prompt], jnp.int32)}, n)
    return np.asarray(res.tokens[0])


PROMPTS = [[5, 3], [7, 1, 4], list(range(1, 11)), list(range(2, 14))]  # 2,3,10,12


@pytest.mark.parametrize("mode", ["bucketed", "continuous"])
def test_ragged_matches_direct_greedy(engine, mode):
    """Mixed lengths (2, 3, 10, 12): every padded request must decode exactly
    like per-request direct generation."""
    direct = [_direct(engine, p, 6) for p in PROMPTS]
    out = serve_ragged(engine, [Request(i, p) for i, p in enumerate(PROMPTS)],
                       6, mode=mode)
    assert [r.id for r in out] == [0, 1, 2, 3]
    for r, want in zip(out, direct):
        np.testing.assert_array_equal(r.tokens, want)


def test_continuous_slot_reuse_and_budgets(tiny):
    """More requests than slots + per-request budgets: slots are freed at
    each request's own budget and refilled, outputs still match direct."""
    _, model, params = tiny
    engine = InferenceEngine(model, params, cache_len=40)
    budgets = [2, 5, 3, 6, 4]
    reqs = [Request(i, PROMPTS[i % len(PROMPTS)], max_new=budgets[i])
            for i in range(5)]
    out = serve_continuous(engine, reqs, 6, slots=2, chunk=2)
    for r, req in zip(out, reqs):
        want = _direct(engine, req.tokens, req.max_new)
        assert r.tokens.shape == (req.max_new,)
        np.testing.assert_array_equal(r.tokens, want)


def test_bucketed_trims_to_request_budget(engine):
    reqs = [Request(0, PROMPTS[0], max_new=2), Request(1, PROMPTS[1], max_new=5)]
    out = serve_bucketed(engine, reqs, 6)
    assert out[0].tokens.shape == (2,)
    assert out[1].tokens.shape == (5,)
    np.testing.assert_array_equal(out[0].tokens, _direct(engine, PROMPTS[0], 6)[:2])


def test_eos_at_first_token_freezes(tiny):
    """A prompt whose very first sampled token is EOS must not keep
    generating (seed bug: done0 never checked tok0 against eos_id)."""
    _, model, params = tiny
    probe = InferenceEngine(model, params, cache_len=24)
    batch = {"tokens": smoke_batch(load_config("tinyllama-1.1b").reduced(),
                                   batch=1, seq=6)["tokens"]}
    first = int(np.asarray(probe.generate(batch, 1).tokens)[0, 0])
    eng = InferenceEngine(model, params, cache_len=24, eos_id=first)
    toks = np.asarray(eng.generate(batch, 5).tokens)[0]
    assert np.all(toks == first), toks


def test_per_request_position_decode_matches_stepwise(tiny):
    """Vector-pos decode over a ragged prefill == per-request scalar loops."""
    _, model, params = tiny
    prompts = [[5, 3, 9], [7, 1, 4, 4, 2, 8]]
    lengths = np.asarray([len(p) for p in prompts], np.int32)
    pad = max(len(p) for p in prompts)
    toks = np.zeros((2, pad), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p

    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)}, 16
    )
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.asarray(lengths)
    got = [tok]
    for _ in range(3):
        logits, cache = model.decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        got.append(tok)
        pos = pos + 1
    got = np.asarray(jnp.stack(got, axis=1))

    for i, p in enumerate(prompts):
        logits, cache = model.prefill(params, {"tokens": jnp.asarray([p], jnp.int32)}, 16)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        want = [tok]
        for s in range(3):
            logits, cache = model.decode(params, tok, cache, jnp.int32(len(p) + s))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            want.append(tok)
        np.testing.assert_array_equal(got[i], np.asarray(jnp.stack(want, axis=1))[0])


def test_cache_overflow_raises(tiny):
    """prompt_len + max_new_tokens > cache_len must fail loudly (the
    dynamic_update_slice clamp would silently corrupt the last slot)."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=10)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    with pytest.raises(ValueError, match="overflow"):
        eng.generate(batch, 4)
    # ragged: the padded prompt alone must also fit
    with pytest.raises(ValueError, match="overflow"):
        eng.generate({"tokens": jnp.zeros((1, 12), jnp.int32)}, 1,
                     lengths=np.asarray([3], np.int32))
    # scheduler validates per request
    sched = SlotScheduler(eng, slots=2, chunk=2)
    with pytest.raises(ValueError, match="cache"):
        sched.serve([Request(0, list(range(8)))], 4)
    eng.generate(batch, 2)  # within bounds still fine


def test_prng_streams_independent_per_bucket(engine, monkeypatch):
    """Every bucket must get its own folded key (seed bug: one shared key
    made all buckets sample identical step randomness)."""
    seen = []
    orig = engine.generate

    def spy(batch, n, **kw):
        seen.append(np.asarray(kw["key"]))
        return orig(batch, n, **kw)

    monkeypatch.setattr(engine, "generate", spy)
    serve_bucketed(engine, [Request(0, [1, 2]), Request(1, list(range(10)))],
                   4, key=jax.random.PRNGKey(3))
    assert len(seen) == 2 and not np.array_equal(seen[0], seen[1])


@pytest.fixture(scope="module")
def rwkv_engine():
    cfg = load_config("rwkv6-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return InferenceEngine(model, params, cache_len=24)


def test_recurrent_slot_state_continuous(rwkv_engine):
    """rwkv6 serves through the slot-state continuous path: exact-length
    admission groups (no pad token ever enters the recurrence), and the
    continuous, bucketed and direct outputs agree token-for-token."""
    eng = rwkv_engine
    assert not eng.model.supports_lengths
    assert eng.model.cache_kind == "state"
    assert resolve_mode(eng, "auto") == "continuous"
    # the engine's own batch API still refuses ragged lengths — per-slot
    # raggedness is the scheduler's job now
    with pytest.raises(ValueError, match="ragged"):
        eng.generate({"tokens": jnp.zeros((1, 4), jnp.int32)}, 2,
                     lengths=np.asarray([2], np.int32))
    prompts = [[4, 2, 9], [8, 8, 1, 3, 5], [4, 2, 9, 1]]
    reqs = [Request(i, p) for i, p in enumerate(prompts)]
    direct = [_direct(eng, p, 4) for p in prompts]
    for out in (serve_ragged(eng, reqs, 4),               # -> continuous
                serve_continuous(eng, reqs, 4, slots=2, chunk=2),
                serve_bucketed(eng, reqs, 4)):
        for r, want in zip(out, direct):
            np.testing.assert_array_equal(r.tokens, want)


def test_recurrent_slot_reuse_and_budgets(rwkv_engine):
    """More recurrent requests than slots + mixed budgets: slots free at
    each request's own budget and refill, outputs still match direct."""
    eng = rwkv_engine
    prompts = [[4, 2, 9], [8, 8, 1, 3, 5], [7, 7], [1, 2, 3], [9, 9, 9, 2]]
    budgets = [2, 5, 3, 6, 4]
    reqs = [Request(i, p, max_new=b) for i, (p, b) in
            enumerate(zip(prompts, budgets))]
    out = serve_continuous(eng, reqs, 6, slots=2, chunk=2)
    for r, req in zip(out, reqs):
        assert r.tokens.shape == (req.max_new,)
        np.testing.assert_array_equal(
            r.tokens, _direct(eng, req.tokens, req.max_new))


def test_recurrent_unbounded_state_ignores_cache_len(rwkv_engine):
    """rwkv6's state is fully O(1): no KV axis grows with the sequence, so
    the scheduler must serve budgets past cache_len instead of refusing."""
    assert rwkv_engine.unbounded_state
    out = serve_continuous(rwkv_engine, [Request(0, [4, 2, 9])], 30,
                           slots=1, chunk=4)   # 3 + 30 > cache_len=24
    assert out[0].tokens.shape == (30,)


def test_recurrent_bounded_state_overflow_raises():
    """zamba2's shared-attention KV rows are bounded by cache_len: the
    slot-state path must validate capacity like the contiguous one."""
    cfg = load_config("zamba2-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, cache_len=10)
    assert eng.model.cache_kind == "state" and not eng.unbounded_state
    sched = SlotScheduler(eng, slots=2, chunk=2)
    with pytest.raises(ValueError, match="cache"):
        sched.serve([Request(0, list(range(8)))], 4)
    out = sched.serve([Request(0, [3, 1, 4])], 4)    # within bounds fine
    assert out[0].tokens.shape == (4,)


def test_recurrent_snapshot_roundtrip(rwkv_engine):
    """RecurrentAdapter insert -> snapshot is a per-slot state roundtrip."""
    eng = rwkv_engine
    sched = SlotScheduler(eng, slots=3, chunk=2)
    adapter = sched.adapter
    cache = adapter.begin_serve()
    prompt = [4, 2, 9]
    _, rows = eng.model.prefill(
        eng.params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        eng.cache_len)
    cache = adapter.insert(cache, rows, [(1, Request(0, prompt))], len(prompt))
    snap = adapter.snapshot(cache, [1])
    want = jax.device_get(rows)
    for got, ref in zip(jax.tree.leaves(snap), jax.tree.leaves(want)):
        np.testing.assert_array_equal(got, ref)


def test_continuous_refuses_encdec():
    """The refusal moved from recurrent families to the only family with
    neither length-aware KV rows nor O(1) slot state: the encdec."""
    import types

    cfg = load_config("seamless-m4t-large-v2").reduced()
    eng = types.SimpleNamespace(model=build(cfg), cfg=cfg)
    assert eng.model.cache_kind == "none"
    with pytest.raises(ValueError, match="continuous"):
        SlotScheduler(eng)


def test_resolve_mode_messages(engine, rwkv_engine):
    with pytest.raises(ValueError, match="valid modes"):
        resolve_mode(engine, "warp")
    # an explicit unsupported mode lists what the arch can actually run
    with pytest.raises(ValueError, match="continuous, bucketed"):
        resolve_mode(rwkv_engine, "paged")
    assert resolve_mode(engine, "auto") == "paged"


def test_serve_ragged_empty(engine):
    assert serve_ragged(engine, [], 4) == []
