"""Ragged serving: length-aware bucketing + continuous-batching scheduler.

The regression at the heart of this file: a right-padded request must decode
token-for-token identically to its unpadded self (greedy). The seed code
sampled the first token from pad-position logits, attended over pad keys,
and mis-assigned RoPE positions — every length != bucket size was wrong.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import build, load_config, smoke_batch
from repro.serving.batching import (
    Request,
    SlotScheduler,
    serve_bucketed,
    serve_continuous,
    serve_ragged,
)
from repro.serving.engine import InferenceEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(tiny):
    _, model, params = tiny
    return InferenceEngine(model, params, cache_len=40)


def _direct(engine, prompt, n):
    res = engine.generate({"tokens": jnp.asarray([prompt], jnp.int32)}, n)
    return np.asarray(res.tokens[0])


PROMPTS = [[5, 3], [7, 1, 4], list(range(1, 11)), list(range(2, 14))]  # 2,3,10,12


@pytest.mark.parametrize("mode", ["bucketed", "continuous"])
def test_ragged_matches_direct_greedy(engine, mode):
    """Mixed lengths (2, 3, 10, 12): every padded request must decode exactly
    like per-request direct generation."""
    direct = [_direct(engine, p, 6) for p in PROMPTS]
    out = serve_ragged(engine, [Request(i, p) for i, p in enumerate(PROMPTS)],
                       6, mode=mode)
    assert [r.id for r in out] == [0, 1, 2, 3]
    for r, want in zip(out, direct):
        np.testing.assert_array_equal(r.tokens, want)


def test_continuous_slot_reuse_and_budgets(tiny):
    """More requests than slots + per-request budgets: slots are freed at
    each request's own budget and refilled, outputs still match direct."""
    _, model, params = tiny
    engine = InferenceEngine(model, params, cache_len=40)
    budgets = [2, 5, 3, 6, 4]
    reqs = [Request(i, PROMPTS[i % len(PROMPTS)], max_new=budgets[i])
            for i in range(5)]
    out = serve_continuous(engine, reqs, 6, slots=2, chunk=2)
    for r, req in zip(out, reqs):
        want = _direct(engine, req.tokens, req.max_new)
        assert r.tokens.shape == (req.max_new,)
        np.testing.assert_array_equal(r.tokens, want)


def test_bucketed_trims_to_request_budget(engine):
    reqs = [Request(0, PROMPTS[0], max_new=2), Request(1, PROMPTS[1], max_new=5)]
    out = serve_bucketed(engine, reqs, 6)
    assert out[0].tokens.shape == (2,)
    assert out[1].tokens.shape == (5,)
    np.testing.assert_array_equal(out[0].tokens, _direct(engine, PROMPTS[0], 6)[:2])


def test_eos_at_first_token_freezes(tiny):
    """A prompt whose very first sampled token is EOS must not keep
    generating (seed bug: done0 never checked tok0 against eos_id)."""
    _, model, params = tiny
    probe = InferenceEngine(model, params, cache_len=24)
    batch = {"tokens": smoke_batch(load_config("tinyllama-1.1b").reduced(),
                                   batch=1, seq=6)["tokens"]}
    first = int(np.asarray(probe.generate(batch, 1).tokens)[0, 0])
    eng = InferenceEngine(model, params, cache_len=24, eos_id=first)
    toks = np.asarray(eng.generate(batch, 5).tokens)[0]
    assert np.all(toks == first), toks


def test_per_request_position_decode_matches_stepwise(tiny):
    """Vector-pos decode over a ragged prefill == per-request scalar loops."""
    _, model, params = tiny
    prompts = [[5, 3, 9], [7, 1, 4, 4, 2, 8]]
    lengths = np.asarray([len(p) for p in prompts], np.int32)
    pad = max(len(p) for p in prompts)
    toks = np.zeros((2, pad), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p

    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)}, 16
    )
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.asarray(lengths)
    got = [tok]
    for _ in range(3):
        logits, cache = model.decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        got.append(tok)
        pos = pos + 1
    got = np.asarray(jnp.stack(got, axis=1))

    for i, p in enumerate(prompts):
        logits, cache = model.prefill(params, {"tokens": jnp.asarray([p], jnp.int32)}, 16)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        want = [tok]
        for s in range(3):
            logits, cache = model.decode(params, tok, cache, jnp.int32(len(p) + s))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            want.append(tok)
        np.testing.assert_array_equal(got[i], np.asarray(jnp.stack(want, axis=1))[0])


def test_cache_overflow_raises(tiny):
    """prompt_len + max_new_tokens > cache_len must fail loudly (the
    dynamic_update_slice clamp would silently corrupt the last slot)."""
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=10)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    with pytest.raises(ValueError, match="overflow"):
        eng.generate(batch, 4)
    # ragged: the padded prompt alone must also fit
    with pytest.raises(ValueError, match="overflow"):
        eng.generate({"tokens": jnp.zeros((1, 12), jnp.int32)}, 1,
                     lengths=np.asarray([3], np.int32))
    # scheduler validates per request
    sched = SlotScheduler(eng, slots=2, chunk=2)
    with pytest.raises(ValueError, match="cache"):
        sched.serve([Request(0, list(range(8)))], 4)
    eng.generate(batch, 2)  # within bounds still fine


def test_prng_streams_independent_per_bucket(engine, monkeypatch):
    """Every bucket must get its own folded key (seed bug: one shared key
    made all buckets sample identical step randomness)."""
    seen = []
    orig = engine.generate

    def spy(batch, n, **kw):
        seen.append(np.asarray(kw["key"]))
        return orig(batch, n, **kw)

    monkeypatch.setattr(engine, "generate", spy)
    serve_bucketed(engine, [Request(0, [1, 2]), Request(1, list(range(10)))],
                   4, key=jax.random.PRNGKey(3))
    assert len(seen) == 2 and not np.array_equal(seen[0], seen[1])


def test_recurrent_family_exact_length_grouping():
    """rwkv6 has sequential prefill state: continuous mode must refuse, and
    bucketed mode must group by exact length (pads would corrupt the
    recurrence) while still matching direct generation."""
    cfg = load_config("rwkv6-7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, cache_len=24)
    assert not model.supports_lengths
    with pytest.raises(ValueError, match="continuous"):
        SlotScheduler(eng)
    with pytest.raises(ValueError, match="ragged"):
        eng.generate({"tokens": jnp.zeros((1, 4), jnp.int32)}, 2,
                     lengths=np.asarray([2], np.int32))
    prompts = [[4, 2, 9], [8, 8, 1, 3, 5]]
    out = serve_ragged(eng, [Request(i, p) for i, p in enumerate(prompts)], 4)
    for r, p in zip(out, prompts):
        np.testing.assert_array_equal(r.tokens, _direct(eng, p, 4))


def test_serve_ragged_empty(engine):
    assert serve_ragged(engine, [], 4) == []
