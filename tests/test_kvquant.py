"""Quantized KV cache (``kv_quant``): parity, pool accounting, validation.

The tentpole contract: with the KV cache stored int8/fp8 (per-row f32
scale leaves, dequantized inside attention), greedy decode must be
token-identical across ALL THREE paths — direct contiguous generate, the
contiguous slot scheduler, and the paged scheduler — for every supported
kv_quant format. The quantized model is a different model than the float
one (cache rows are rounded), so parity is quantized-vs-quantized; the
float engine is only the accounting baseline.
"""

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import build, load_config
from repro.serving.batching import Request, serve_continuous
from repro.serving.engine import InferenceEngine
from repro.serving.paged import serve_paged

KV_FORMATS = ("int8", "fp8")
PROMPTS = [[5, 3], [7, 1, 4], list(range(1, 11)), list(range(2, 14))]


@pytest.fixture(scope="module")
def tiny():
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _direct(engine, prompt, n, **kw):
    res = engine.generate({"tokens": jnp.asarray([prompt], jnp.int32)}, n, **kw)
    return np.asarray(res.tokens[0])


# ---------------------------------------------------------------------------
# parity: direct == contiguous slots == paged, per kv_quant format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kvq", KV_FORMATS)
def test_kvquant_paged_eq_contiguous_eq_direct(tiny, kvq):
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40, kv_quant=kvq)
    budgets = [2, 6, 3, 5]
    reqs = [Request(i, p, max_new=b)
            for i, (p, b) in enumerate(zip(PROMPTS, budgets))]
    cont = serve_continuous(eng, reqs, 6, slots=2, chunk=2)
    paged = serve_paged(eng, reqs, 6, slots=2, chunk=2, block_size=8)
    for rc, rp, req in zip(cont, paged, reqs):
        want = _direct(eng, req.tokens, req.max_new)
        np.testing.assert_array_equal(rc.tokens, want)
        np.testing.assert_array_equal(rp.tokens, want)
        assert rc.length == rp.length


@pytest.mark.parametrize("arch", ["gemma2-2b", "internlm2-1.8b"])
def test_kvquant_parity_across_gqa_variants(arch):
    """Sliding window + softcap (gemma2) and plain GQA (internlm2) through
    the quantized-pool kernel path."""
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, cache_len=40, kv_quant="int8")
    reqs = [Request(i, p, max_new=4) for i, p in enumerate(PROMPTS[:3])]
    paged = serve_paged(eng, reqs, 4, slots=2, chunk=2, block_size=8)
    for rp, req in zip(paged, reqs):
        np.testing.assert_array_equal(
            rp.tokens, _direct(eng, req.tokens, req.max_new))


def test_kvquant_close_to_float_decode(tiny):
    """int8 KV rows carry ~0.4% relative rounding — greedy tokens on this
    reduced model should mostly agree with the float path (sanity that the
    quantized cache is an approximation, not a different computation)."""
    _, model, params = tiny
    feng = InferenceEngine(model, params, cache_len=40)
    qeng = InferenceEngine(model, params, cache_len=40, kv_quant="int8")
    agree = np.mean([
        np.mean(_direct(feng, p, 6) == _direct(qeng, p, 6)) for p in PROMPTS])
    assert agree >= 0.5, agree


# ---------------------------------------------------------------------------
# cache structure + bytes accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kvq", KV_FORMATS)
def test_kvquant_pool_structure_and_bytes(tiny, kvq):
    cfg, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40, kv_quant=kvq)
    pool = jax.eval_shape(
        lambda: eng.model.init_paged_cache(6, 8, eng.cfg.cdtype()))
    assert set(pool) == {"k_pages", "k_scales", "v_pages", "v_scales"}
    store = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn}[kvq]
    assert pool["k_pages"].dtype == store
    assert pool["k_scales"].dtype == jnp.float32
    # scales are per cached row: pages minus the head_dim axis
    assert pool["k_scales"].shape == pool["k_pages"].shape[:-1]

    def nbytes(tree):
        return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree))

    fpool = jax.eval_shape(lambda: model.init_paged_cache(6, 8, cfg.cdtype()))
    # 1-byte rows + f32/head_dim scale overhead must beat the f32 pool >= 3x
    assert nbytes(fpool) / nbytes(pool) >= 3.0


def test_kvquant_contiguous_cache_structure(tiny):
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40, kv_quant="int8")
    cache = jax.eval_shape(
        lambda: eng.model.init_cache(2, 40, eng.cfg.cdtype()))
    assert set(cache) == {"k_q", "k_s", "v_q", "v_s"}
    assert cache["k_q"].dtype == jnp.int8
    assert cache["k_s"].dtype == jnp.float32
    assert cache["k_s"].shape == cache["k_q"].shape[:-1]


def test_kvquant_scale_leaf_sharding_rule():
    """`*_scales` pool leaves follow their pages: kv heads -> model axis,
    block axis NEVER sharded (blocks migrate through the tables)."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import cache_spec

    mesh = SimpleNamespace(shape={"data": 16, "model": 16},
                           axis_names=("data", "model"))
    spec = cache_spec("k_scales", (22, 4096, 16, 32), mesh=mesh, batch=4096)
    assert spec == P(None, None, None, "model")
    # heads not divisible -> replicated; the block axis must stay whole even
    # though 4096 divides the data axis (the batch-search fallback hazard)
    spec = cache_spec("v_scales", (22, 4096, 16, 3), mesh=mesh, batch=4096)
    assert spec == P(None, None, None, None)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_kvquant_unknown_format_raises(tiny):
    _, model, params = tiny
    with pytest.raises(ValueError, match="unknown kv_quant"):
        InferenceEngine(model, params, cache_len=40, kv_quant="int3")


def test_kvquant_rejects_non_paged_families():
    rwkv = build(load_config("rwkv6-7b").reduced())
    with pytest.raises(ValueError, match="GQA decoder_lm"):
        InferenceEngine(rwkv, rwkv.init(jax.random.PRNGKey(0)),
                        cache_len=16, kv_quant="int8")


def test_kvquant_incompatible_with_spec_decode(tiny):
    _, model, params = tiny
    eng = InferenceEngine(model, params, cache_len=40, kv_quant="int8")
    with pytest.raises(ValueError, match="kv_quant"):
        eng.generate({"tokens": jnp.asarray([PROMPTS[0]], jnp.int32)},
                     4, spec_k=2)
