"""Every arch_matrix.py entry gets a real smoke of its capability.

These parametrize DIRECTLY over the matrix lists, so the ledger can never
name an arch it doesn't test; the registry-coverage checker closes the
other direction (no True flag without a ledger entry). Deeper per-family
behavior lives in test_paged.py / test_spec.py / test_variants.py — this
file pins the capability *surface* for the archs those suites don't sweep
(pixtral-12b, deepseek-coder-33b, dbrx-132b).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from arch_matrix import PAGED_ARCHS, RAGGED_ARCHS, SLOT_STATE_ARCHS, SPEC_ARCHS
from repro.models.registry import build, load_config, smoke_batch
from repro.serving.batching import Request, serve_bucketed, serve_continuous
from repro.serving.engine import InferenceEngine

STEPS = 3


def _setup(arch, b=2, s=8):
    cfg = load_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, batch=b, seq=s)
    batch.pop("labels", None)
    return cfg, model, params, batch


def _row(batch, i, length):
    out = {"tokens": batch["tokens"][i:i + 1, :length]}
    if "patch_embeds" in batch:
        out["patch_embeds"] = batch["patch_embeds"][i:i + 1]
    return out


@pytest.mark.parametrize("arch", RAGGED_ARCHS)
def test_ragged_prefill_matches_per_row(arch):
    """supports_lengths: a ragged right-padded batch generates the same
    greedy tokens as each row served alone at its true length."""
    cfg, model, params, batch = _setup(arch)
    eng = InferenceEngine(model, params, cache_len=8 + STEPS + 1)
    lens = np.asarray([5, 8], np.int32)
    got = np.asarray(eng.generate(batch, STEPS, lengths=lens).tokens)
    for i, n in enumerate(lens):
        want = np.asarray(eng.generate(_row(batch, i, int(n)), STEPS).tokens)
        np.testing.assert_array_equal(got[i:i + 1], want)


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_decode_matches_contiguous(arch):
    """supports_paged: block-table decode over an identity pool is bitwise
    equal to the contiguous decode step."""
    from repro.core import flags
    from repro.models.transformer import contiguous_to_paged

    cfg, model, params, batch = _setup(arch)
    assert model.supports_paged
    # deferred mode: decode appends at pos instead of rolling, the layout
    # contiguous_to_paged's identity block table mirrors (test_paged.py)
    with flags.overrides(deferred_decode_cache=True):
        logits, cache = model.prefill(params, batch, 16)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = jnp.full((2,), batch["tokens"].shape[1], jnp.int32)
        pool, table = contiguous_to_paged(cache, 8)
        for _ in range(2):
            lc, cache = model.decode(params, tok, cache, pos)
            lp, pool = model.decode_paged(params, tok, pool, table, pos)
            np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))
            tok = jnp.argmax(lc, -1).astype(jnp.int32)
            pos = pos + 1


@pytest.mark.parametrize("arch", SPEC_ARCHS)
def test_verify_logits_and_rollback(arch):
    """supports_spec: verify's position-0 logits match a plain decode step,
    and committing zero tokens leaves the cache bit-identical."""
    cfg, model, params, batch = _setup(arch)
    assert model.supports_spec
    logits, cache = model.prefill(params, batch, 16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), batch["tokens"].shape[1], jnp.int32)
    chunk = jnp.concatenate(
        [tok[:, None], jnp.asarray([[3, 5], [2, 4]], jnp.int32)], axis=1)
    lv, rows = model.verify(params, chunk, cache, pos)
    ld, _ = model.decode(params, tok, cache, pos)
    np.testing.assert_allclose(
        np.asarray(lv[:, 0]), np.asarray(ld), rtol=1e-5, atol=1e-5)
    c0 = model.commit_verify(cache, rows, pos, jnp.zeros((2,), jnp.int32))
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(c0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


PARITY_PROMPTS = [[5, 3], [7, 1, 4, 2, 6], [9, 2, 8]]


@pytest.mark.parametrize("arch", RAGGED_ARCHS + SLOT_STATE_ARCHS)
def test_scheduler_parity_continuous_bucketed_direct(arch):
    """The scheduling core's promise, per family: continuous (contiguous
    slots for decoder_lm, slot-state gather/scatter for the recurrent
    archs), bucketed, and per-request direct generation emit identical
    greedy tokens."""
    cfg, model, params, _ = _setup(arch)
    eng = InferenceEngine(model, params, cache_len=16)
    reqs = [Request(i, p) for i, p in enumerate(PARITY_PROMPTS)]
    direct = [
        np.asarray(eng.generate(
            {"tokens": jnp.asarray([p], jnp.int32)}, STEPS).tokens[0])
        for p in PARITY_PROMPTS
    ]
    cont = serve_continuous(eng, reqs, STEPS, slots=2, chunk=2)
    buck = serve_bucketed(eng, reqs, STEPS)
    for c, b, want in zip(cont, buck, direct):
        np.testing.assert_array_equal(c.tokens, want)
        np.testing.assert_array_equal(b.tokens, want)


@pytest.mark.parametrize("arch", SLOT_STATE_ARCHS)
def test_slot_state_insert_gather_roundtrip(arch):
    """cache_kind='state': insert_slots then gather_slots recovers the
    per-request state rows exactly, for every leaf layout (rwkv6's pure
    recurrence, zamba2's mixed SSM + shared-KV + tail tree)."""
    cfg, model, params, _ = _setup(arch)
    assert model.cache_kind == "state"
    _, rows = model.prefill(
        params, {"tokens": jnp.asarray([[5, 3, 7]], jnp.int32)}, 12)
    big = model.init_cache(3, 12, cfg.cdtype())
    slots = jnp.asarray([2], jnp.int32)
    big = model.insert_slots(big, rows, slots)
    back = model.gather_slots(big, slots)
    for got, ref in zip(jax.tree.leaves(back), jax.tree.leaves(rows)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
