"""repro-xray: compiled-program contract checkers (DESIGN.md §14).

Two halves, in the fixture style of test_analysis.py:

* parser/traffic-model units — sub-byte (s4) operand accounting, the
  unpack-fusion normalization (and its no-multiply guard), the
  input_output_alias header parser;
* contract audits — the real serving catalog is CLEAN (the CI acceptance
  gate), and four PLANTED violations (undonated cache, materialized f32
  dequant, bogus nbytes model, unexpected all-gather) are each caught by
  the matching checker with exact checker-id and anchor assertions,
  including through the CLI's ``--select 'xray-*'`` glob path.

The catalog compiles once per process (module-global memoization in
``repro.analysis.xray``); planted programs are built from reduced archs
or synthetic HLO so nothing here re-compiles the full-size rows.
"""

import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import pytest

import repro.analysis.xray as xray
from repro.analysis.__main__ import main as cli_main
from repro.analysis.hlo import (
    Module,
    analyze,
    parse_input_output_aliases,
    shape_bytes,
)
from repro.analysis.xray import (
    XrayProgram,
    _cache_sigs,
    audit_bytes,
    audit_collectives,
    audit_dequant,
    audit_donation,
    catalog,
)

ROOT = __file__.rsplit("/tests/", 1)[0]


# ---------------------------------------------------------------------------
# parser / traffic-model units
# ---------------------------------------------------------------------------

def test_s4_operand_bytes_are_packed():
    """Sub-byte dtypes charge packed bits, not one byte per element: the
    old table said s4 = 1 B/elem and overstated packed-int4 traffic 2x."""
    assert shape_bytes("s4[22,11264,1024]{2,1,0}") == 22 * 11264 * 1024 // 2
    assert shape_bytes("u4[8]") == 4
    assert shape_bytes("s8[4,4]") == 16
    assert shape_bytes("u1[10]") == 2          # ceil(10 / 8)
    assert shape_bytes("bf16[2,3]") == 12


S4_DOT_HLO = """\
HloModule m, entry_computation_layout={(s4[256,256]{1,0}, f32[256]{0})->f32[256]{0}}

ENTRY %main (p0: s4[256,256], p1: f32[256]) -> f32[256] {
  %p0 = s4[256,256]{1,0} parameter(0)
  %p1 = f32[256]{0} parameter(1)
  ROOT %dot.1 = f32[256]{0} dot(s4[256,256]{1,0} %p0, f32[256]{0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_s4_dot_hbm_bytes_pinned():
    """End-to-end pin: a dot reading an s4[256,256] operand is charged
    256*256/2 = 32768 bytes for it (plus f32 vector in + f32 out)."""
    rep = analyze(S4_DOT_HLO)
    assert rep.hbm_bytes == 256 * 256 // 2 + 256 * 4 + 256 * 4


UNPACK_HLO = """\
HloModule m

%unpack (p: s8[128]) -> s32[256] {
  %p = s8[128]{0} parameter(0)
  %sl = s8[128]{0} shift-left(s8[128]{0} %p, s8[128]{0} %p)
  %sra = s8[128]{0} shift-right-arithmetic(s8[128]{0} %sl, s8[128]{0} %sl)
  %cat = s8[256]{0} concatenate(s8[128]{0} %sra, s8[128]{0} %sra), dimensions={0}
  ROOT %cv = s32[256]{0} convert(s8[256]{0} %cat)
}

%dequant (p.0: s8[256]) -> f32[256] {
  %p.1 = s8[256]{0} parameter(0)
  %cv.1 = f32[256]{0} convert(s8[256]{0} %p.1)
  %c.1 = f32[] constant(0.5)
  %b.1 = f32[256]{0} broadcast(f32[] %c.1), dimensions={}
  ROOT %m.1 = f32[256]{0} multiply(f32[256]{0} %cv.1, f32[256]{0} %b.1)
}

ENTRY %main (a: s8[128], b: s8[256]) -> (s32[256], f32[256]) {
  %a = s8[128]{0} parameter(0)
  %b = s8[256]{0} parameter(1)
  %f1 = s32[256]{0} fusion(s8[128]{0} %a), kind=kLoop, calls=%unpack
  %f2 = f32[256]{0} fusion(s8[256]{0} %b), kind=kLoop, calls=%dequant
  ROOT %t = (s32[256]{0}, f32[256]{0}) tuple(s32[256]{0} %f1, f32[256]{0} %f2)
}
"""


def test_unpack_fusion_normalized_but_dequant_is_not():
    """The nibble-decode (slices + shifts + concat, integer out) costs 0
    bytes — consumers charge the packed read.  A fusion with a multiply
    (real dequant arithmetic) must NOT be normalized away."""
    mod = Module(UNPACK_HLO)
    f1, f2 = mod.table["f1"], mod.table["f2"]
    assert mod.is_unpack_fusion(f1)
    assert mod.instr_hbm_bytes(f1) == 0.0
    assert not mod.is_unpack_fusion(f2)
    assert mod.instr_hbm_bytes(f2) > 0.0
    # a consumer reading the unpack fusion resolves to the packed source
    assert mod.effective_operand_bytes("f1") == 128


def test_input_output_alias_header_parser():
    text = ("HloModule jit_f, input_output_alias={ {1}: (2, {}, may-alias), "
            "{0}: (0, {1}, must-alias) }, entry_computation_layout={()->()}\n")
    assert parse_input_output_aliases(text) == [
        ((1,), 2, (), "may-alias"),
        ((0,), 0, (1,), "must-alias"),
    ]
    assert parse_input_output_aliases("HloModule m\n") == []


# ---------------------------------------------------------------------------
# the real catalog is clean (acceptance gate)
# ---------------------------------------------------------------------------

def test_catalog_covers_every_adapter_program():
    names = {p.name for p in catalog()}
    for expect in (
        "tinyllama-1.1b/decode[int8]",
        "tinyllama-1.1b/decode[int4]",
        "tinyllama-1.1b/decode[mixed]",
        "tinyllama-1.1b/contiguous/decode_chunk",
        "tinyllama-1.1b/contiguous/insert_slots",
        "tinyllama-1.1b/contiguous/verify",
        "tinyllama-1.1b/contiguous/prefill",
        "tinyllama-1.1b/paged/decode_until",
        "tinyllama-1.1b/paged/insert",
        "tinyllama-1.1b/paged/verify",
        "deepseek-v2-lite-16b/contiguous/decode_chunk",
        "rwkv6-7b/recurrent/decode_chunk",
    ):
        assert expect in names, f"catalog lost {expect}"


def test_repo_tree_passes_all_xray_audits():
    """The current serving stack holds every compiled-program contract:
    donation, dequant streaming, bytes-per-step, collectives/trip-count."""
    for audit in (audit_donation, audit_dequant, audit_bytes,
                  audit_collectives):
        found = [f for p in catalog() for f in audit(p)]
        assert found == [], "\n".join(f.render() for f in found)


def test_bytes_rows_within_tolerance_with_headroom():
    """Pin the contract margin: every preset's model-vs-HLO delta stays
    within tolerance (regression here means the traffic model drifted)."""
    rows = [p for p in catalog() if p.expected_bytes is not None]
    assert {p.fmt for p in rows} == {"int8", "int4", "mixed", "int3", "fp8",
                                     "mixed3", "int8+kv_int8", "int8+kv_fp8"}
    for p in rows:
        rep = analyze(p.hlo_text)
        delta = abs(rep.hbm_bytes / p.expected_bytes - 1.0)
        assert delta <= xray.BYTES_RTOL, (p.name, delta)


# ---------------------------------------------------------------------------
# planted violations — each caught by the matching checker
# ---------------------------------------------------------------------------

ANCHOR = "tests/test_xray.py"


@pytest.fixture(scope="module")
def reduced():
    from repro.models.registry import build, load_config

    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: model.init_cache(2, 64, cfg.cdtype()))
    return cfg, model, params, cache


@pytest.fixture(scope="module")
def undonated_prog(reduced):
    cfg, model, params, cache = reduced
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    pos = jax.ShapeDtypeStruct((2,), jnp.int32)
    hlo = jax.jit(model.decode).lower(params, tok, cache, pos).compile().as_text()
    return XrayProgram(
        name="planted/undonated-decode", kind="decode", hlo_text=hlo,
        path=ANCHOR, line=1, cache_sigs=_cache_sigs(cache),
        require_alias=True, require_dus=True)


def test_planted_undonated_cache_is_flagged(undonated_prog):
    fs = list(audit_donation(undonated_prog))
    assert len(fs) == 1
    f = fs[0]
    assert f.checker == "xray-donation"
    assert f.anchor == f"{ANCHOR}:1"
    assert "planted/undonated-decode" in f.message
    assert "input_output_alias" in f.message
    assert "%p" in f.message          # names the offending parameter


def test_planted_f32_dequant_materialization_is_flagged():
    def f(q, s, x):
        w = (q.astype(jnp.float32).reshape(256, 8, 128)
             * s[..., None]).reshape(256, 1024)
        return x @ w

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 1024), jnp.int8),
        jax.ShapeDtypeStruct((256, 8), jnp.float32),
        jax.ShapeDtypeStruct((4, 256), jnp.float32)).compile().as_text()
    prog = XrayProgram(
        name="planted/f32-dequant", kind="decode", hlo_text=hlo,
        path=ANCHOR, line=2, cache_sigs=Counter(),
        weight_sigs=frozenset({"256,1024", "1024,256"}))
    fs = list(audit_dequant(prog))
    assert [f.checker for f in fs] == ["xray-dequant"]
    assert fs[0].anchor == f"{ANCHOR}:2"
    assert "planted/f32-dequant" in fs[0].message
    assert "%" in fs[0].message       # names the materializing instruction
    assert "f32[256,1024]" in fs[0].message


def test_planted_bogus_nbytes_model_is_flagged():
    """An nbytes override claiming half the real storage pushes the
    model-vs-HLO delta far beyond tolerance."""
    row = next(p for p in catalog() if p.fmt == "int8")
    bogus = dataclasses.replace(row, name="planted/bogus-nbytes",
                                path=ANCHOR, line=3,
                                expected_bytes=row.expected_bytes / 2)
    fs = list(audit_bytes(bogus))
    assert [f.checker for f in fs] == ["xray-bytes"]
    assert fs[0].anchor == f"{ANCHOR}:3"
    assert "planted/bogus-nbytes" in fs[0].message
    assert "top contributor %" in fs[0].message
    assert list(audit_bytes(row)) == []     # the honest model passes


def test_planted_all_gather_in_decode_is_flagged():
    """Inject an all-gather into a real compiled decode: the sharding
    policy predicts no collectives on this mesh."""
    row = next(p for p in catalog() if p.name.endswith("/decode_chunk"))
    assert "ROOT %tuple" in row.hlo_text
    injected = row.hlo_text.replace(
        "ROOT %tuple",
        "%planted-ag = f32[2,32]{1,0} all-gather(f32[1,32]{1,0} %nothing), "
        "replica_groups={}, dimensions={0}\n  ROOT %tuple", 1)
    prog = dataclasses.replace(row, name="planted/all-gather",
                               path=ANCHOR, line=4, hlo_text=injected)
    fs = list(audit_collectives(prog))
    assert [f.checker for f in fs] == ["xray-collective"]
    assert fs[0].anchor == f"{ANCHOR}:4"
    assert "planted/all-gather" in fs[0].message
    assert "%planted-ag" in fs[0].message
    assert list(audit_collectives(row)) == []   # the real program is clean


def test_trip_count_contract_catches_lost_layer_scan():
    row = next(p for p in catalog() if p.fmt == "int8")
    assert row.num_layers == 22
    wrong = dataclasses.replace(row, name="planted/trip-count",
                                num_layers=23)
    fs = list(audit_collectives(wrong))
    assert [f.checker for f in fs] == ["xray-collective"]
    assert "num_layers=23" in fs[0].message


# ---------------------------------------------------------------------------
# CLI: glob --select, planted catalog -> non-zero exit naming the program
# ---------------------------------------------------------------------------

def test_cli_xray_glob_clean_on_repo_tree():
    """`python -m repro.analysis --select xray-*` exits 0 on the tree."""
    assert cli_main(["--root", ROOT, "--select", "xray-*",
                     "src/repro/analysis/xray.py"]) == 0


def test_cli_xray_glob_fails_on_planted_catalog(monkeypatch, capsys,
                                                undonated_prog):
    monkeypatch.setattr(xray, "_CATALOG", [undonated_prog])
    rc = cli_main(["--root", ROOT, "--select", "xray-*",
                   "src/repro/analysis/xray.py"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "xray-donation" in out
    assert "planted/undonated-decode" in out


def test_cli_select_rejects_matchless_glob():
    assert cli_main(["--select", "no-such-*"]) == 2


def test_cli_select_exact_id_still_works():
    assert cli_main(["--root", ROOT, "--select", "xray-bytes",
                     "src/repro/analysis/xray.py"]) == 0
