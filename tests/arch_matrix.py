"""Capability test matrix: the ledger the registry-coverage checker audits.

One literal list per ``Model`` capability flag. Every arch whose flag is
True MUST appear in the matching list, and every entry here is exercised by
``tests/test_capability_matrix.py`` (which parametrizes directly over these
lists) — so adding a family to the registry with a True flag forces a test,
and listing an arch without the capability fails the lint.

Lists are parsed as AST literals by ``repro.analysis.registry_coverage``;
keep them plain lists of string constants (no comprehensions/imports).
"""

# supports_lengths: ragged right-padded prefill + per-row decode positions.
# All decoder_lm families (GQA and MLA alike).
RAGGED_ARCHS = [
    "tinyllama-1.1b",
    "pixtral-12b",
    "minicpm3-4b",
    "deepseek-coder-33b",
    "gemma2-2b",
    "internlm2-1.8b",
    "dbrx-132b",
    "deepseek-v2-lite-16b",
]

# supports_paged: block-pool KV cache + block-table decode.
# GQA decoder_lm only — the MLA latent cache keeps its contiguous layout.
PAGED_ARCHS = [
    "tinyllama-1.1b",
    "pixtral-12b",
    "deepseek-coder-33b",
    "gemma2-2b",
    "internlm2-1.8b",
    "dbrx-132b",
]

# supports_spec: uncommitted k-token verify + accepted-prefix commit.
# Same layout class as supports_paged.
SPEC_ARCHS = [
    "tinyllama-1.1b",
    "pixtral-12b",
    "deepseek-coder-33b",
    "gemma2-2b",
    "internlm2-1.8b",
    "dbrx-132b",
]

# cache_kind="state": O(1) per-slot recurrent state served through the
# scheduling core's RecurrentAdapter (slot gather/scatter, no paging).
SLOT_STATE_ARCHS = [
    "rwkv6-7b",
    "zamba2-7b",
]

# repro-san sweep: every cache-bearing family (cache_kind kv or state) runs
# the serve-parity sweep under the sanitizer (tests/test_sanitizer.py).
# Audited by the shadow-coverage checker against the live registry.
SANITIZED_ARCHS = [
    "tinyllama-1.1b",
    "pixtral-12b",
    "minicpm3-4b",
    "deepseek-coder-33b",
    "gemma2-2b",
    "internlm2-1.8b",
    "dbrx-132b",
    "deepseek-v2-lite-16b",
    "rwkv6-7b",
    "zamba2-7b",
]
