"""End-to-end serving driver (the paper is an inference paper, so the e2e
example is serving): batched requests through the W8A8 engine vs the fp32
"PS baseline", with tok/s and agreement reporting.

    PYTHONPATH=src python examples/serve_quantized.py [--arch gemma2-2b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build, load_config
from repro.serving.engine import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()

    cfg = load_config(args.arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = args.prompt_len + args.new_tokens

    rng = np.random.default_rng(7)
    requests = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        dtype=jnp.int32)}
    if cfg.model_type == "encdec":
        requests["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))

    results = {}
    for name, quant in (("fp32 (PS baseline)", False), ("W8A8 (LlamaF)", True)):
        eng = InferenceEngine(model, params, cache_len=cache_len, quantize=quant)
        eng.generate(requests, args.new_tokens)          # compile
        t0 = time.perf_counter()
        res = eng.generate(requests, args.new_tokens)
        jax.block_until_ready(res.tokens)
        dt = time.perf_counter() - t0
        toks = args.batch * args.new_tokens
        print(f"{name:20s} {toks/dt:9.1f} tok/s  "
              f"(quantized fraction {eng.quantized_fraction:.2f})")
        results[name] = np.asarray(res.tokens)

    agree = float(np.mean(results["fp32 (PS baseline)"] == results["W8A8 (LlamaF)"]))
    print(f"greedy token agreement fp32 vs W8A8: {agree:.2%}")


if __name__ == "__main__":
    main()
