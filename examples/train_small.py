"""Training example: train a small LM end-to-end with checkpoints, then PTQ
the result and compare quality (ties the training substrate to the paper's
inference pipeline).

Default runs a CPU-friendly model for 120 steps; pass --steps/--dmodel to
scale up (e.g. --dmodel 768 --layers 12 approximates a ~100M model when you
have real hardware).

    PYTHONPATH=src python examples/train_small.py
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.policy import quantize_params
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build, load_config
from repro.optim import adamw
from repro.train.loop import LoopConfig, lm_loss, run_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--dmodel", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        load_config("tinyllama-1.1b").reduced(),
        d_model=args.dmodel, num_layers=args.layers,
        d_ff=args.dmodel * 2, head_dim=args.dmodel // 4,
    )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(l.size for l in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                          ckpt_dir=args.ckpt_dir, log_every=20)
    params, _, history = run_loop(model, params, data, opt_cfg, loop_cfg)
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

    # post-training quantization of the trained weights (paper §III-A)
    qparams = quantize_params(params, cfg.group_size)
    batch = jax.tree.map(jnp.asarray, data.batch_at(10_000))
    nll_f = lm_loss(model.forward(params, batch, remat=False), batch["labels"])
    nll_q = lm_loss(model.forward(qparams, batch, remat=False), batch["labels"])
    print(f"held-out PPL fp32 {jnp.exp(nll_f):.3f} vs W8A8 {jnp.exp(nll_q):.3f} "
          f"({100 * (jnp.exp(nll_q) - jnp.exp(nll_f)) / jnp.exp(nll_f):.2f}% degradation; "
          "paper Table V: +0.57%)")


if __name__ == "__main__":
    main()
