"""Quickstart: quantize a model with the paper's W8A8 scheme and generate.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import quantize_params, quantized_fraction
from repro.core.quant import quantize_groupwise
from repro.kernels import ops
from repro.models.registry import build, load_config
from repro.serving.engine import InferenceEngine


def main():
    # 1. the paper's core op: group-wise quantized matvec (Alg. 1)
    rng = np.random.default_rng(0)
    w = quantize_groupwise(jnp.asarray(rng.normal(size=(256, 512)).astype(np.float32)), 256)
    y = ops.quantized_matmul(jnp.ones((512,)), w)
    print(f"GQMV out shape {y.shape}, int8 weight bytes: {w.nbytes():,}")

    # 2. PTQ a TinyLlama-family model (reduced dims for CPU)
    cfg = load_config("tinyllama-1.1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, cfg.group_size)
    print(f"quantized fraction of bytes: {quantized_fraction(qparams):.3f} "
          "(paper: 4.4GB -> 1.1GB)")

    # 3. generate with the W8A8 engine (greedy, like the paper's eval)
    engine = InferenceEngine(model, params, cache_len=48, quantize=True)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 16)), dtype=jnp.int32)}
    out = engine.generate(prompt, 24)
    print("generated:", np.asarray(out.tokens)[:, :12])


if __name__ == "__main__":
    main()
