"""Cross-architecture PTQ survey: apply the paper's technique to every
assigned architecture (reduced configs) and report quantized byte fraction +
logit fidelity — demonstrating the technique is arch-agnostic (DESIGN.md
§Arch-applicability).

    PYTHONPATH=src python examples/multiarch_compare.py
"""

import jax
import numpy as np

from repro.core.policy import quantize_params, quantized_fraction
from repro.models.registry import ARCH_IDS, build, load_config, smoke_batch


def main():
    print(f"{'arch':24s} {'q-bytes':>8s} {'rel logit err':>14s}")
    for arch in ARCH_IDS:
        cfg = load_config(arch).reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qp = quantize_params(params, cfg.group_size)
        batch = smoke_batch(cfg, batch=2, seq=12)
        ref = np.asarray(model.forward(params, batch, remat=False), np.float32)
        got = np.asarray(model.forward(qp, batch, remat=False), np.float32)
        rel = np.linalg.norm(got - ref) / max(np.linalg.norm(ref), 1e-9)
        print(f"{arch:24s} {quantized_fraction(qp):8.3f} {rel:14.4f}")


if __name__ == "__main__":
    main()
